"""Paper Fig. 11: the recompute–offload–keep (ROK) curve.

For each batch size, run the three placement strategies and plot
(activation peak, model throughput). Claims validated: offload matches
keep's throughput at a lower peak; offload beats recompute on both axes
at matched batch; with a fixed memory budget offload supports ~2x the
batch of keep.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import run_staged
from repro.configs.paper_models import small_bert
from repro.core.rok import RokPoint, pareto_front


def run(batches=(4, 8, 16), seq: int = 128, hidden: int = 384,
        layers: int = 3, steps: int = 3) -> List[RokPoint]:
    cfg = small_bert(hidden, layers)
    points: List[RokPoint] = []
    for b in batches:
        for strategy in ("keep", "offload", "recompute"):
            res = run_staged(cfg, strategy=strategy, batch=b, seq=seq,
                             steps=steps)
            points.append(res.rok_point())
    return points


def main():
    points = run()
    front = set(id(p) for p in pareto_front(points))
    print("name,us_per_call,derived")
    for p in points:
        name = f"fig11/{p.strategy}-b{p.batch_size}"
        print(f"{name},{p.step_time_s*1e6:.0f},"
              f"peak_mb={p.peak_activation_bytes/1e6:.1f}"
              f";tput_gflops={p.throughput_flops_per_s/1e9:.2f}"
              f";pareto={'y' if id(p) in front else 'n'}")
    return points


if __name__ == "__main__":
    main()
