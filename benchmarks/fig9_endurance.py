"""Paper Fig. 9: projected SSD lifespan, per-GPU PCIe write bandwidth and
max activations per GPU for Megatron-scale systems.

Claims validated: every configuration projects > 3 years of SSD life on
4x D7-P5810-class drives; required PCIe write bandwidth <= ~12 GB/s and
*decreases* as the system scales (weak-scaling argument, §2.2/§4.4).
"""
from __future__ import annotations

from repro.core.endurance import project_all


def main():
    rows = project_all()
    print("name,us_per_call,derived")
    for p in rows:
        print(f"fig9/{p.label.replace(' ', '-')},"
              f"{p.t_step_s*1e6:.0f},"
              f"pcie_gb_s={p.pcie_write_gb_s:.1f}"
              f";lifespan_yr={p.lifespan_years:.1f}"
              f";act_per_gpu_gb={p.act_bytes_per_gpu/1e9:.1f}")
    ok_life = all(p.lifespan_years > 3 for p in rows)
    ok_bw = all(p.pcie_write_gb_s <= 15 for p in rows)
    print(f"fig9/claims,0,lifespan_gt_3yr={ok_life};bw_le_15gbs={ok_bw}")
    return rows


if __name__ == "__main__":
    main()
