"""Roofline aggregation (deliverable g): read the dry-run JSONs and emit
the per-(arch x shape x mesh) three-term roofline table.

    compute    = HLO dot FLOPs / (chips x 197 TFLOP/s)
    memory     = HLO HBM bytes / (chips x 819 GB/s)
    collective = wire bytes / (chips x 50 GB/s/link)

All terms are per-device seconds (the HLO module is the per-partition
program). `useful` = MODEL_FLOPS / (HLO FLOPs x chips) — how much of the
compiled compute is algorithmic (remat and attention overhead show up
here). Markdown output feeds EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

DEFAULT_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def load(results_dir: str = DEFAULT_DIR, mesh: Optional[str] = None
         ) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if mesh and rec.get("mesh") != mesh:
            continue
        rows.append(rec)
    return rows


def table(rows: List[Dict], *, markdown: bool = False) -> str:
    out = []
    header = ("arch", "shape", "mesh", "status", "t_comp", "t_mem_lb",
              "t_mem_ub", "t_coll", "dominant", "useful", "dev_GB")
    if markdown:
        out.append("| " + " | ".join(header) + " |")
        out.append("|" + "---|" * len(header))
    else:
        out.append(",".join(header))
    for r in rows:
        if r.get("status") == "skip":
            vals = (r["arch"], r["shape"], r.get("mesh", ""), "skip",
                    "-", "-", "-", "-", "-", "-", "-")
        elif r.get("status") != "ok":
            vals = (r["arch"], r["shape"], r.get("mesh", ""), "ERROR",
                    "-", "-", "-", "-", "-", "-", "-")
        else:
            rl = r["roofline"]
            mem = r["memory_analysis"]
            lb = r.get("hlo", {}).get("hbm_bytes_lb")
            vals = (r["arch"], r["shape"], r["mesh"], "ok",
                    f"{rl['t_compute_s']:.3f}",
                    f"{lb/819e9:.3f}" if lb is not None else "-",
                    f"{rl['t_memory_s']:.3f}",
                    f"{rl['t_collective_s']:.3f}",
                    rl["dominant"],
                    f"{rl['useful_flops_ratio']:.3f}"
                    if rl.get("useful_flops_ratio") else "-",
                    f"{mem['peak_device_bytes']/2**30:.1f}")
        if markdown:
            out.append("| " + " | ".join(str(v) for v in vals) + " |")
        else:
            out.append(",".join(str(v) for v in vals))
    return "\n".join(out)


def summarize(rows: List[Dict]) -> Dict:
    ok = [r for r in rows if r.get("status") == "ok"]
    dom = {}
    for r in ok:
        dom[r["roofline"]["dominant"]] = \
            dom.get(r["roofline"]["dominant"], 0) + 1
    worst = sorted(
        (r for r in ok if r["kind"] == "train"),
        key=lambda r: (r["roofline"]["useful_flops_ratio"] or 0))
    return {"n_ok": len(ok),
            "n_skip": sum(r.get("status") == "skip" for r in rows),
            "n_err": sum(r.get("status") not in ("ok", "skip")
                         for r in rows),
            "dominant_counts": dom,
            "worst_useful": [(r["arch"], r["shape"],
                              r["roofline"]["useful_flops_ratio"])
                             for r in worst[:3]]}


def main():
    rows = load()
    print("name,us_per_call,derived")
    for r in rows:
        if r.get("status") != "ok":
            continue
        rl = r["roofline"]
        lb = r.get("hlo", {}).get("hbm_bytes_lb")
        t_mem_lb = (lb / 819e9) if lb is not None else rl["t_memory_s"]
        bound_ub = max(rl["t_compute_s"], rl["t_memory_s"],
                       rl["t_collective_s"])
        bound_lb = max(rl["t_compute_s"], t_mem_lb,
                       rl["t_collective_s"])
        frac_ub = rl["t_compute_s"] / bound_ub if bound_ub else 0.0
        frac_lb = rl["t_compute_s"] / bound_lb if bound_lb else 0.0
        print(f"roofline/{r['arch']}-{r['shape']}-{r['mesh']},"
              f"{bound_ub*1e6:.0f},"
              f"dominant={rl['dominant']}"
              f";frac_fusion_optimal={frac_lb:.3f}"
              f";frac_conservative={frac_ub:.3f}"
              f";useful={rl['useful_flops_ratio'] or 0:.3f}")
    s = summarize(rows)
    print(f"roofline/summary,0,ok={s['n_ok']};skip={s['n_skip']};"
          f"err={s['n_err']};dominant={s['dominant_counts']}")
    return rows


if __name__ == "__main__":
    main()
