"""Paper Fig. 10: step time + activation memory, TBA offload vs no-offload,
on BERT / GPT / T5 at three (hidden, layers) scenarios.

Claims validated: (1) offloading adds ~no step-time overhead (I/O fully
overlapped / forwarded); (2) activation peak drops 28–47%.
CPU-scale geometry (hidden 256/384/512) — same families, same mechanism.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import RunResult, run_staged
from repro.configs.paper_models import SMALL_SCENARIOS, small_bert, \
    small_gpt, small_t5

FAMILIES = {"bert": small_bert, "gpt": small_gpt, "t5": small_t5}


def run(batch: int = 8, seq: int = 128, steps: int = 3) -> List[dict]:
    rows = []
    for fam, make in FAMILIES.items():
        for hidden, layers in SMALL_SCENARIOS:
            cfg = make(hidden, layers)
            keep = run_staged(cfg, strategy="keep", batch=batch, seq=seq,
                              steps=steps)
            off = run_staged(cfg, strategy="offload", batch=batch,
                             seq=seq, steps=steps)
            rows.append({
                "family": fam, "hidden": hidden, "layers": layers,
                "keep_step_s": keep.step_time_s,
                "offload_step_s": off.step_time_s,
                "overhead_pct": 100 * (off.step_time_s / keep.step_time_s
                                       - 1),
                "keep_peak_mb": keep.peak_activation_bytes / 1e6,
                "offload_peak_mb": off.peak_activation_bytes / 1e6,
                "peak_reduction_pct": 100 * (
                    1 - off.peak_activation_bytes
                    / keep.peak_activation_bytes),
                "bwd_begin_reduction_pct": 100 * (
                    1 - off.backward_begin_bytes
                    / max(keep.backward_begin_bytes, 1)),
                "offloaded_mb": off.bytes_offloaded / 1e6,
                "io_wait_pct": 100 * off.fetch_wait_s
                / max(off.step_time_s, 1e-9),
            })
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        name = f"fig10/{r['family']}-h{r['hidden']}-l{r['layers']}"
        print(f"{name},{r['offload_step_s']*1e6:.0f},"
              f"overhead={r['overhead_pct']:.1f}%"
              f";io_wait={r['io_wait_pct']:.1f}%"
              f";peak_reduction={r['peak_reduction_pct']:.1f}%"
              f";offloaded_mb={r['offloaded_mb']:.1f}")
    return rows


if __name__ == "__main__":
    main()
