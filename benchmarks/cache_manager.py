"""Storage-brain benchmark: CacheManager vs the static `tiered`
backend on the spool datapath workload, emitting ``BENCH_cache.json``.

Paired A/B in alternating rounds on the same payload: both sides run
the staged trainer's spool pattern (forward-ordered async stores of
bf16 residual trees, backward-order fetches with one-ahead prefetch)
over a host-RAM budget sized to hold about half the stream, with a
filesystem SSD tier below. Side A is ``TieredBackend`` (the legacy
static placement: class-blind, FIFO victims, no promotion); side B is
``CacheManager`` at the SAME budget (class-aware victims, hinted reuse
horizon, background promotion). Median-of-ratios cancels background
drift, as in ``spool_datapath.py``.

``--check`` asserts the tentpole's two acceptance bounds and exits
non-zero on violation:

  * throughput: the manager matches or beats static tiered (a small
    tolerance absorbs timer noise on millisecond rounds — the manager
    runs the same data plane, so a real regression shows up well
    beyond it);
  * pinned-host bound: the manager's ``peak_host_bytes`` high-water
    mark never exceeds the configured MemAscend-style budget;

plus bitwise payload parity of every fetched leaf on the manager side.
A mixed-class residency cell (activations + opt_state + kv pages
through one manager) reports where each class landed.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import statistics
import tempfile
import time
from typing import Dict, List

import numpy as np

try:
    from benchmarks.spool_datapath import _residual_stream
except ImportError:      # run as a script: benchmarks/ is sys.path[0]
    from spool_datapath import _residual_stream
from repro.cache import CacheConfig, CacheManager
from repro.core.spool import ActivationSpool
from repro.io import FilesystemBackend, TieredBackend

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_cache.json")


def _spool_round(backend, stream, *, verify: bool = False) -> float:
    """One staged-trainer pass: store forward, fetch backward with
    one-ahead prefetch, drop each stage after its backward use."""
    spool = ActivationSpool(backend, codec="raw", store_threads=2,
                            min_offload_elements=16)
    try:
        t0 = time.perf_counter()
        for key, leaves in stream.items():
            spool.offload(key, leaves)
        spool.wait_io()
        keys = list(stream)
        for i in range(len(keys) - 1, -1, -1):
            if i > 0:
                spool.prefetch(keys[i - 1])
            out = spool.fetch(keys[i])
            if verify:
                for got, want in zip(out, stream[keys[i]]):
                    np.testing.assert_array_equal(np.asarray(got),
                                                  np.asarray(want))
            spool.drop(keys[i])
        spool.wait_io()
        return time.perf_counter() - t0
    finally:
        spool.close()


def ab_rounds(stream, *, rounds: int = 5) -> Dict:
    logical = sum(a.nbytes for ls in stream.values() for a in ls)
    budget = logical // 2               # half the stream fits in RAM
    root = tempfile.mkdtemp(prefix="bench_cache_ab_")
    tiered = TieredBackend(FilesystemBackend(os.path.join(root, "t")),
                           capacity_bytes=budget)
    managed = CacheManager(FilesystemBackend(os.path.join(root, "m")),
                           config=CacheConfig(host_bound_bytes=budget))
    try:
        t = {"tiered": [], "managed": []}
        _spool_round(tiered, stream)    # warm page cache / allocators
        for r in range(rounds):
            t["tiered"].append(_spool_round(tiered, stream))
            t["managed"].append(_spool_round(managed, stream,
                                             verify=(r == 0)))
        med = {k: statistics.median(v) for k, v in t.items()}
        st = managed.cache_stats()
        return {
            "payload_mb": round(logical / 1e6, 2),
            "host_bound_mb": round(budget / 1e6, 2),
            "rounds": rounds,
            "tiered_gb_s": round(logical / med["tiered"] / 1e9, 3),
            "managed_gb_s": round(logical / med["managed"] / 1e9, 3),
            # > 1.0: the manager is faster
            "managed_speedup": round(statistics.median(
                [a / b for a, b in zip(t["tiered"], t["managed"])]), 3),
            "peak_host_bytes": managed.peak_host_bytes,
            "host_bound_bytes": budget,
            "evictions": st["evictions"],
            "promotions": st["promotions"],
            "fallbacks": st["fallbacks"],
            "payload_parity": "bitwise",
        }
    finally:
        tiered.close()
        managed.close()
        shutil.rmtree(root, ignore_errors=True)


def mixed_class_residency(stream) -> Dict:
    """All three tensor classes live in one manager at twice the host
    budget: the brain keeps the nearest-reuse class (activations)
    pinned and demotes kv pages (farthest reuse) first — the placement
    a class-blind tiered backend cannot express."""
    logical = sum(a.nbytes for ls in stream.values() for a in ls)
    root = tempfile.mkdtemp(prefix="bench_cache_mix_")
    m = CacheManager(FilesystemBackend(os.path.join(root, "ssd")),
                     config=CacheConfig(host_bound_bytes=logical // 2))
    try:
        n = len(stream)
        blob = os.urandom(max(1, logical // (4 * n)))
        act = os.urandom(max(1, logical // (2 * n)))
        t0 = time.perf_counter()
        for i in range(n):              # kv/opt arrive FIRST...
            m.write(f"kv{i}_p0", blob)
            m.write(f"opt{i}_m", blob)
        for i in range(n):              # ...yet activations win RAM
            m.write(f"mb0_s{i}", act)
        wall = time.perf_counter() - t0
        res = m.residency()
        return {
            "write_wall_s": round(wall, 4),
            "residency": res,
            "host_mb_by_class": {c: round(b / 1e6, 2)
                                 for c, b in res["host-ram"].items()},
            "ssd_mb_by_class": {c: round(b / 1e6, 2)
                                for c, b in res["ssd"].items()},
        }
    finally:
        m.close()
        shutil.rmtree(root, ignore_errors=True)


def main(argv=()) -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small stream (CI smoke)")
    ap.add_argument("--check", action="store_true",
                    help="assert manager >= tiered throughput and the "
                         "pinned-host bound; non-zero exit on violation")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args(list(argv))

    if args.quick:
        stream = _residual_stream(6, 3, 128 * 1024)       # ~4.5 MB
        rounds = 3
    else:
        stream = _residual_stream(6, 3, 2 * 1024 * 1024)  # ~72 MB
        rounds = 5

    print("name,us_per_call,derived")
    headline = ab_rounds(stream, rounds=rounds)
    mixed = mixed_class_residency(stream)
    print(f"cache_manager/ab,"
          f"{headline['payload_mb'] / max(headline['managed_gb_s'], 1e-9) * 1e3:.0f},"
          f"managed_gb_s={headline['managed_gb_s']}"
          f";tiered_gb_s={headline['tiered_gb_s']}"
          f";speedup={headline['managed_speedup']}"
          f";peak_host_mb={round(headline['peak_host_bytes'] / 1e6, 2)}"
          f";bound_mb={headline['host_bound_mb']}")
    print(f"# mixed-class residency: host={mixed['host_mb_by_class']} "
          f"ssd={mixed['ssd_mb_by_class']}")

    out = {"headline": headline, "mixed_class": mixed}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"# wrote {args.out}")

    if args.check:
        failures = []
        # same data plane underneath, so the manager must keep pace;
        # 10% tolerance absorbs round-to-round fs timing noise on the
        # small --quick stream
        if headline["managed_speedup"] < 0.9:
            failures.append(
                f"manager slower than static tiered: paired speedup "
                f"{headline['managed_speedup']} < 0.9")
        if headline["peak_host_bytes"] > headline["host_bound_bytes"]:
            failures.append(
                f"pinned-host bound violated: peak "
                f"{headline['peak_host_bytes']} > bound "
                f"{headline['host_bound_bytes']}")
        if headline["fallbacks"]:
            failures.append(f"unexpected fallbacks on healthy SSD: "
                            f"{headline['fallbacks']}")
        if failures:
            raise SystemExit("cache-manager check FAILED: "
                             + "; ".join(failures))
        print("# cache check passed: manager >= tiered, peak host "
              "bytes within bound, payload parity bitwise")
    return [headline, mixed]


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
