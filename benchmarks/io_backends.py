"""repro.io backend x codec sweep on a synthetic residual stream.

Drives the ActivationSpool exactly the way the staged trainer does —
offload a forward-ordered stream of residual trees, then fetch them in
backward order with one-ahead prefetch — over every registered storage
backend and codec. Reports measured backend write/read bandwidth, the
fetch wait exposed to the (synthetic) backward pass, and the stored
byte volume (the codec's WAF lever), and emits ``BENCH_io.json``.
"""
from __future__ import annotations

import json
import os
import tempfile
import time
from typing import Dict, List

import numpy as np

from repro.core.spool import ActivationSpool
from repro.io import (FilesystemBackend, HostMemoryBackend, StripedBackend,
                      TieredBackend)

# stream geometry: 8 "modules" x 3 residuals x 1 MiB float32
N_KEYS = 8
N_LEAVES = 3
LEAF_SHAPE = (512, 512)
OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_io.json")

BACKENDS = ["fs", "striped", "mem", "tiered"]
CODECS = ["raw", "zlib"]


def _make_backend(kind: str, root: str):
    if kind == "fs":
        return FilesystemBackend(os.path.join(root, "fs"))
    if kind == "striped":
        return StripedBackend([os.path.join(root, f"ssd{i}")
                               for i in range(4)], chunk_bytes=1 << 18)
    if kind == "mem":
        return HostMemoryBackend()
    if kind == "tiered":
        # budget sized to hold about half the stream in RAM
        stream = N_KEYS * N_LEAVES * int(np.prod(LEAF_SHAPE)) * 4
        return TieredBackend(FilesystemBackend(os.path.join(root, "low")),
                             capacity_bytes=stream // 2)
    raise AssertionError(kind)


def _residual_stream(seed: int = 0) -> Dict[str, List[np.ndarray]]:
    """Half noise, half structured zeros — activations are compressible
    but not trivially so."""
    rng = np.random.default_rng(seed)
    stream = {}
    for k in range(N_KEYS):
        leaves = []
        for j in range(N_LEAVES):
            a = rng.normal(size=LEAF_SHAPE).astype(np.float32)
            a[::2] = 0.0
            leaves.append(a)
        stream[f"mb0_s{k}"] = leaves
    return stream


def run_one(kind: str, codec: str) -> Dict:
    root = tempfile.mkdtemp(prefix=f"bench_io_{kind}_")
    backend = _make_backend(kind, root)
    spool = ActivationSpool(backend, codec=codec,
                            min_offload_elements=16)
    stream = _residual_stream()
    logical = sum(a.nbytes for ls in stream.values() for a in ls)

    t0 = time.perf_counter()
    for key, leaves in stream.items():      # forward: async stores
        spool.offload(key, leaves)
    spool.wait_io()
    t_store = time.perf_counter() - t0

    t0 = time.perf_counter()
    keys = list(stream)
    for i in range(len(keys) - 1, -1, -1):  # backward walk
        if i > 0:
            spool.prefetch(keys[i - 1])     # one-ahead (§3.3.2)
        out = spool.fetch(keys[i])
        assert len(out) == N_LEAVES
        spool.drop(keys[i])
    t_fetch = time.perf_counter() - t0
    io = backend.stats
    rec = {
        "backend": kind, "codec": codec,
        "logical_mb": round(logical / 1e6, 2),
        "stored_mb": round(io.bytes_written / 1e6, 2),
        "compress_ratio": round(logical / io.bytes_written, 3)
        if io.bytes_written else None,
        "store_wall_s": round(t_store, 4),
        "fetch_wall_s": round(t_fetch, 4),
        "write_gb_s": round(io.write_bandwidth / 1e9, 3)
        if io.write_time else None,
        "read_gb_s": round(io.read_bandwidth / 1e9, 3)
        if io.read_time else None,
        "fetch_wait_s": round(spool.stats.fetch_wait_time, 4),
        "tiers": [
            {"name": t.name,
             "write_gb_s": (round(t.write_bw / 1e9, 3)
                            if t.write_bw != float("inf") else None),
             "capacity_bytes": t.capacity_bytes}
            for t in backend.tier_bandwidths()],
    }
    if isinstance(backend, StripedBackend):
        rec["per_device_write_mb"] = [round(b / 1e6, 2)
                                      for b in
                                      backend.per_device_write_bytes()]
    if isinstance(backend, TieredBackend):
        rec["evictions"] = backend.evictions
        rec["bytes_evicted_mb"] = round(backend.bytes_evicted / 1e6, 2)
    spool.close()
    return rec


def main():
    rows = []
    print("name,us_per_call,derived")
    for kind in BACKENDS:
        for codec in CODECS:
            rec = run_one(kind, codec)
            rows.append(rec)
            total_us = (rec["store_wall_s"] + rec["fetch_wall_s"]) * 1e6
            print(f"io/{kind}-{codec},{total_us:.0f},"
                  f"write_gb_s={rec['write_gb_s']}"
                  f";read_gb_s={rec['read_gb_s']}"
                  f";fetch_wait_s={rec['fetch_wait_s']}"
                  f";stored_mb={rec['stored_mb']}")
    with open(OUT_PATH, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"# wrote {OUT_PATH}")
    return rows


if __name__ == "__main__":
    main()
