"""Paper Table 4: measured offloaded bytes vs the analytic model estimate,
plus the implied PCIe write bandwidth to fully overlap.

The paper's finding: estimate within ~8% of measurement; bandwidth need
falls as hidden grows. Here the measurement is the spool's actual write
count on CPU-scale BERTs, and the estimate is
core.endurance.offloaded_bytes_per_step (the llm-analysis extension).
"""
from __future__ import annotations

import dataclasses
from typing import List

from benchmarks.common import MIN_OFFLOAD, run_staged
from repro.configs.paper_models import SMALL_SCENARIOS, small_bert
from repro.core.endurance import offloaded_bytes_per_step


def run(batch: int = 8, seq: int = 128, steps: int = 3) -> List[dict]:
    rows = []
    for hidden, layers in SMALL_SCENARIOS:
        cfg = small_bert(hidden, layers)
        res = run_staged(cfg, strategy="offload", batch=batch, seq=seq,
                         steps=steps)
        cfg32 = dataclasses.replace(cfg, dtype="float32")
        est = offloaded_bytes_per_step(cfg32, batch, seq)
        rows.append({
            "hidden": hidden, "layers": layers,
            "measured_mb": res.bytes_offloaded / 1e6,
            "estimate_mb": est / 1e6,
            "ratio": res.bytes_offloaded / max(est, 1),
            "pcie_write_mb_s": res.bytes_offloaded
            / max(res.step_time_s / 2, 1e-9) / 1e6,
        })
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        print(f"table4/h{r['hidden']}-l{r['layers']},0,"
              f"measured_mb={r['measured_mb']:.1f}"
              f";estimate_mb={r['estimate_mb']:.1f}"
              f";ratio={r['ratio']:.2f}"
              f";write_bw_mb_s={r['pcie_write_mb_s']:.0f}")
    return rows


if __name__ == "__main__":
    main()
