"""Render the EXPERIMENTS.md roofline tables from the dry-run JSONs
(current results/dryrun vs archived results/dryrun_iter0 baselines)."""
from __future__ import annotations

import json
import os
import sys

from benchmarks.roofline import DEFAULT_DIR, load, summarize, table

ITER0 = os.path.join(os.path.dirname(DEFAULT_DIR), "dryrun_iter0")


def perf_delta_table(cells):
    """before/after rows for the hillclimbed cells."""
    out = ["| cell | iter | t_comp | t_mem(lb) | t_mem(ub) | t_coll "
           "| useful | dev GB |",
           "|---|---|---|---|---|---|---|---|"]
    for arch, shape in cells:
        for label, suffix in (("paper-faithful base", "single-paperbase"),
                              ("optimized", "single")):
            slug = f"{arch.replace('.', '_')}__{shape}__{suffix}.json"
            path = os.path.join(DEFAULT_DIR, slug)
            if not os.path.exists(path):
                continue
            r = json.load(open(path))
            if r.get("status") != "ok":
                continue
            rl = r["roofline"]
            lb = r.get("hlo", {}).get("hbm_bytes_lb", 0) / 819e9
            out.append(
                f"| {arch} x {shape} | {label} "
                f"| {rl['t_compute_s']:.2f} | {lb:.2f} "
                f"| {rl['t_memory_s']:.2f} "
                f"| {rl['t_collective_s']:.2f} "
                f"| {rl['useful_flops_ratio']:.3f} "
                f"| {r['memory_analysis']['peak_device_bytes']/2**30:.0f}"
                f" |")
    return "\n".join(out)


def main():
    rows = load(mesh=None)
    print("## Single-pod (16x16 = 256 chips)\n")
    print(table([r for r in rows if r.get("mesh") == "single"],
                markdown=True))
    print("\n## Multi-pod (2x16x16 = 512 chips)\n")
    print(table([r for r in rows if r.get("mesh") == "multi"],
                markdown=True))
    print("\n## Summary\n")
    print("```")
    print(json.dumps(summarize(rows), indent=1))
    print("```")
    print("\n## Hillclimb deltas\n")
    print(perf_delta_table([("qwen2.5-3b", "train_4k"),
                            ("llama4-scout-17b-a16e", "train_4k"),
                            ("kimi-k2-1t-a32b", "train_4k")]))


if __name__ == "__main__":
    main()
