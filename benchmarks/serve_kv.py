"""Paged-KV serving benchmark: paged-vs-dense A/B on the same request
trace at equal device-cache budget, emitting ``BENCH_serve.json``.

Both servers replay the identical synthetic trace (same prompts, same
generation budgets, same slot count, same attention extent). The dense
baseline pins one full-length cache row per slot, so its live
concurrency is structurally capped at the slot count. The paged server
time-slices: quantum preemption evicts a running sequence's KV pages
through the activation spool to storage and prefetches them back under
the other slots' decode compute — live (mid-generation) sequences then
stack up far beyond the device working set.

Reported per side: decode tok/s, slot occupancy, peak/mean live
concurrency, TTFT and inter-token latency percentiles, device bytes,
and page/eviction traffic. ``--check`` asserts the PR's acceptance
claims and exits non-zero on violation:

  * paged sustains >= 2x the dense baseline's concurrent sequences at
    equal device-cache budget (up to the one reserved null page);
  * paged decode logits are bitwise-identical to dense on the trace,
    token for token, through eviction round trips.

``--quick`` shrinks the trace for CI smoke. ``--trace`` writes a
Perfetto trace of the run (kv.* page events over the io.* spool lanes).
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

import numpy as np

from repro import obs
from repro.kvcache import KVCacheConfig
from repro.launch.serve import (build_kv_spool, build_runtime,
                                make_server, synth_requests)

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_serve.json")


def run_side(runtime, kind, args, spool):
    cfg, api, params, settings = runtime
    kvcfg = KVCacheConfig(
        page_tokens=args.page_tokens, max_seq_len=args.cache_len,
        quantum=args.quantum if kind == "paged" else 0,
        prefetch_depth=args.prefetch_depth)
    server = make_server(api, params, settings, kvcfg, kind=kind,
                         n_slots=args.slots, spool=spool,
                         record_logits=True)
    synth_requests(server, args.requests, args.prompt_len,
                   args.max_new, cfg.vocab_size, args.seed)
    report = server.run()
    return server, report


def bitwise_parity(a, b) -> bool:
    """Token ids and every sampled-from logits row, bitwise."""
    sa = {s.rid: s for s in a.finished}
    sb = {s.rid: s for s in b.finished}
    if set(sa) != set(sb):
        return False
    for rid in sa:
        if sa[rid].tokens != sb[rid].tokens:
            return False
        for x, y in zip(sa[rid].logits, sb[rid].logits):
            if not np.array_equal(x, y):
                return False
    return True


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="small-gpt")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--page-tokens", type=int, default=8)
    ap.add_argument("--quantum", type=int, default=6)
    ap.add_argument("--prefetch-depth", type=int, default=2)
    ap.add_argument("--kv-backend", default="fs",
                    choices=("fs", "aio", "mem"))
    ap.add_argument("--kv-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=OUT_PATH)
    ap.add_argument("--trace", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="small trace for CI smoke")
    ap.add_argument("--check", action="store_true",
                    help="assert the acceptance claims; exit 1 on fail")
    args = ap.parse_args()
    if args.quick:
        args.slots, args.requests = 2, 8
        args.prompt_len, args.max_new, args.cache_len = 12, 12, 32
        args.quantum = 3
    if args.trace:
        obs.enable()

    runtime = build_runtime(args.arch, args.seed)
    spool, owned = build_kv_spool(args.kv_backend, args.kv_dir)
    try:
        paged_srv, paged = run_side(runtime, "paged", args, spool)
        dense_srv, dense = run_side(runtime, "dense", args, None)
    finally:
        spool.close()
        for d in owned:
            shutil.rmtree(d, ignore_errors=True)

    parity = bitwise_parity(paged_srv, dense_srv)
    page_bytes = paged_srv.cache.page_bytes
    ratios = {
        "peak_live": paged.peak_live / max(dense.peak_live, 1),
        "mean_live": paged.mean_live / max(dense.mean_live, 1e-9),
        "decode_tok_s": (paged.decode_tok_s
                         / max(dense.decode_tok_s, 1e-9)),
        "device_bytes": paged.device_bytes / max(dense.device_bytes, 1),
    }
    checks = {
        "parity_bitwise": parity,
        # >= 2x sustained concurrent sequences at equal device budget
        "concurrency_2x": (paged.peak_live >= 2 * dense.peak_live
                           and paged.mean_live >= 2 * dense.mean_live),
        # equal budget: paged may exceed dense only by the null page
        "device_budget": (paged.device_bytes
                          <= dense.device_bytes + page_bytes),
        "evictions_happened": paged.kv["pages_evicted"] > 0,
        "spool_balanced": (paged.kv["pages_evicted"]
                           == paged.kv["pages_restored"]),
    }
    doc = {
        "config": {k: getattr(args, k) for k in
                   ("arch", "slots", "requests", "prompt_len",
                    "max_new", "cache_len", "page_tokens", "quantum",
                    "prefetch_depth", "kv_backend", "seed")},
        "page_bytes": page_bytes,
        "paged": paged.as_dict(),
        "dense": dense.as_dict(),
        "ratios": ratios,
        "checks": checks,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)

    print(f"paged: {paged.decode_tok_s:.0f} tok/s, live peak "
          f"{paged.peak_live} mean {paged.mean_live:.1f}, itl p99 "
          f"{paged.itl_p99_ms:.1f}ms, {paged.kv['pages_evicted']} pages"
          f" evicted ({paged.device_bytes >> 10} KiB device)")
    print(f"dense: {dense.decode_tok_s:.0f} tok/s, live peak "
          f"{dense.peak_live} mean {dense.mean_live:.1f}, itl p99 "
          f"{dense.itl_p99_ms:.1f}ms "
          f"({dense.device_bytes >> 10} KiB device)")
    print(f"concurrency x{ratios['peak_live']:.1f} peak / "
          f"x{ratios['mean_live']:.1f} mean at device-budget "
          f"x{ratios['device_bytes']:.3f}; parity={parity}")
    print(f"wrote {args.out}")
    if args.trace:
        print(f"trace -> {obs.write_chrome_trace(args.trace, obs.get_tracer())}")
    if args.check:
        failed = [k for k, v in checks.items() if not v]
        if failed:
            print(f"CHECK FAILED: {failed}", file=sys.stderr)
            return 1
        print("all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
