"""Zero-copy data-plane benchmark: backend x codec sweep + the PR 3
join-and-write baseline, emitting ``BENCH_spool.json``.

Drives the ActivationSpool the way the staged trainer does — offload a
forward-ordered stream of bf16 residual trees, then fetch in backward
order with one-ahead prefetch — over every registered storage backend
and codec, PLUS a faithful reconstruction of the pre-vectored store
path (``b"".join`` the serde parts, buffered ``open().write`` through
the page cache) as the baseline the tentpole is measured against.

Reported per cell: store/fetch throughput, measured backend write/read
bandwidth, host copies-per-byte (the data plane's zero-copy claim as a
number), aligned-pool hit rate (the zero-allocation claim), fetch wait
exposed to the synthetic backward pass, and the codec's size ratio on
realistic bf16 residuals.

The headline ``speedup_vs_join`` is a *paired* A/B on the same
directory and payload, in alternating rounds (so background drift hits
both sides), with **delivered-bytes semantics**: buffered paths are
timed through ``os.sync()`` because their burst number is page-cache
memcpy, not storage — the data has not reached the device, and sustained
training eventually pays writeback inside the store path (exactly the
mirage ROADMAP's O_DIRECT item calls out). O_DIRECT writes are durable
as issued, so they are timed as-is. Burst (cache-absorbed) numbers are
reported alongside for transparency.

``--quick`` shrinks the stream for CI smoke; ``--check`` asserts the
data-plane invariants (vectored fs path <= 1 host copy per stored
byte — it actually runs at 0) and exits non-zero on violation.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time
from typing import Dict, List

import numpy as np

from repro import obs
from repro.core.spool import ActivationSpool
from repro.io import (AioBackend, FilesystemBackend, HostMemoryBackend,
                      StorageBackend, StripedBackend, TieredBackend)
from repro.obs import overlap as obs_overlap
from repro.obs import tracer as obs_tracer

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_spool.json")
OPT_OUT_PATH = os.path.join(os.path.dirname(__file__),
                            "BENCH_optoverlap.json")

BACKENDS = ["fs", "striped", "mem", "tiered", "aio"]
CODECS = ["raw", "zlib", "byteplane"]


class LegacyJoinFsBackend(StorageBackend):
    """The PR 3 store path, preserved for comparison: no vectored write
    (the base class joins the part list — one full payload copy), and a
    buffered ``open().write`` through the page cache (a second kernel
    copy plus dirty-page throttling). No `size`/`readinto` either, so
    loads fall back to whole-blob `read`."""

    kind = "fs-legacy-join"

    def __init__(self, directory: str):
        super().__init__()
        self.directory = directory
        os.makedirs(directory, exist_ok=True)

    def _path(self, key: str) -> str:
        return os.path.join(self.directory, f"{key}.act")

    def _write(self, key: str, data: bytes) -> None:
        with open(self._path(key), "wb") as f:
            f.write(data)

    def _read(self, key: str) -> bytes:
        with open(self._path(key), "rb") as f:
            return f.read()

    def _delete(self, key: str) -> None:
        try:
            os.unlink(self._path(key))
        except OSError:
            pass


def _make_backend(kind: str, root: str, stream_bytes: int):
    if kind == "fs":
        return FilesystemBackend(os.path.join(root, "fs"))
    if kind == "striped":
        return StripedBackend([os.path.join(root, f"ssd{i}")
                               for i in range(4)], chunk_bytes=1 << 20)
    if kind == "mem":
        return HostMemoryBackend()
    if kind == "tiered":
        # budget sized to hold about half the stream in RAM
        return TieredBackend(FilesystemBackend(os.path.join(root, "low")),
                             capacity_bytes=stream_bytes // 2)
    if kind == "aio":
        return AioBackend(os.path.join(root, "aio"))
    if kind == "legacy":
        return LegacyJoinFsBackend(os.path.join(root, "legacy"))
    raise AssertionError(kind)


def _residual_stream(n_keys: int, n_leaves: int, leaf_elems: int,
                     seed: int = 0) -> Dict[str, List[np.ndarray]]:
    """bf16 post-activation residuals: magnitudes cluster (compressible
    exponent plane), mantissas are noise — the codec's real workload."""
    import ml_dtypes
    rng = np.random.default_rng(seed)
    stream = {}
    for k in range(n_keys):
        leaves = []
        for _ in range(n_leaves):
            a = rng.standard_normal(leaf_elems).astype(np.float32)
            a[a < 0] *= 0.01            # GELU-ish one-sided squash
            leaves.append(a.astype(ml_dtypes.bfloat16))
        stream[f"mb0_s{k}"] = leaves
    return stream


def ab_rounds(stream, *, rounds: int = 5) -> Dict:
    """Paired legacy-vs-vectored store bursts, alternating per round,
    delivered-bytes semantics (see module docstring). Medians of
    per-round ratios cancel the background drift that makes one-shot
    disk numbers on shared machines meaningless."""
    import statistics

    from repro.io import encode_parts, serialize_parts
    parts_per_key = {k: encode_parts(serialize_parts(ls), "raw")
                     for k, ls in stream.items()}
    logical = sum(sum(len(p) for p in parts)
                  for parts in parts_per_key.values())
    root = tempfile.mkdtemp(prefix="bench_dp_ab_")
    legacy = LegacyJoinFsBackend(os.path.join(root, "legacy"))
    fs = FilesystemBackend(os.path.join(root, "fs"))
    aio = AioBackend(os.path.join(root, "aio"))
    try:
        def burst(backend, sync: bool) -> float:
            t0 = time.perf_counter()
            for k, parts in parts_per_key.items():
                backend.write_parts(k, parts)
            if sync:
                os.sync()       # delivered, not parked in page cache
            return time.perf_counter() - t0

        t = {"legacy": [], "legacy_burst": [], "fs": [], "aio": []}
        for _ in range(rounds):
            t["legacy_burst"].append(burst(legacy, sync=False))
            os.sync()
            t["legacy"].append(burst(legacy, sync=True))
            t["fs"].append(burst(fs, sync=True))
            t["aio"].append(burst(aio, sync=False))   # O_DIRECT: durable

        med = {k: statistics.median(v) for k, v in t.items()}
        gbs = {k: round(logical / med[k] / 1e9, 3) for k in med}
        ratio = {
            "fs_vectored": round(statistics.median(
                [l / n for l, n in zip(t["legacy"], t["fs"])]), 3),
            "aio_pooled": round(statistics.median(
                [l / n for l, n in zip(t["legacy"], t["aio"])]), 3),
        }
        return {
            "payload_mb": round(logical / 1e6, 2),
            "rounds": rounds,
            "delivered_gb_s": {"legacy_join": gbs["legacy"],
                               "fs_vectored": gbs["fs"],
                               "aio_pooled": gbs["aio"]},
            "legacy_burst_gb_s": gbs["legacy_burst"],
            "speedup_vs_join": ratio,
            "o_direct": aio.direct,
        }
    finally:
        for b in (legacy, fs, aio):
            b.close()
        shutil.rmtree(root, ignore_errors=True)


def run_one(kind: str, codec: str, stream, *, repeats: int = 1,
            store_threads: int = 1, traced: bool = True) -> Dict:
    logical = sum(a.nbytes for ls in stream.values() for a in ls)
    root = tempfile.mkdtemp(prefix=f"bench_dp_{kind}_")
    backend = _make_backend(kind, root, logical)
    spool = ActivationSpool(backend, codec=codec,
                            store_threads=store_threads,
                            min_offload_elements=16)
    # cell-local tracer so the overlap column comes from THIS cell's
    # events only; the previous process tracer (if any) is restored
    prev_tracer = obs_tracer._TRACER
    cell_tracer = None
    if traced:
        obs_tracer._TRACER = cell_tracer = obs_tracer.Tracer()
    try:
        t_store = t_fetch = 0.0
        for _ in range(repeats):
            t0 = time.perf_counter()
            for key, leaves in stream.items():   # forward: async stores
                spool.offload(key, leaves)
            spool.wait_io()
            t_store += time.perf_counter() - t0

            t0 = time.perf_counter()
            keys = list(stream)
            for i in range(len(keys) - 1, -1, -1):   # backward walk
                if i > 0:
                    spool.prefetch(keys[i - 1])      # one-ahead (§3.3.2)
                out = spool.fetch(keys[i])
                assert len(out) == len(stream[keys[i]])
                spool.drop(keys[i])
            spool.wait_io()
            t_fetch += time.perf_counter() - t0
        io = backend.stats
        dp = spool.data_plane_stats()
        rec = {
            "backend": kind, "codec": codec,
            "logical_mb": round(logical / 1e6, 2),
            "stored_mb": round(io.bytes_written / 1e6 / repeats, 2),
            "compress_ratio": round(logical * repeats
                                    / io.bytes_written, 3)
            if io.bytes_written else None,
            "store_wall_s": round(t_store / repeats, 4),
            "store_gb_s": round(logical * repeats / t_store / 1e9, 3),
            "fetch_wall_s": round(t_fetch / repeats, 4),
            "fetch_gb_s": round(logical * repeats / t_fetch / 1e9, 3),
            "fetch_wait_s": round(spool.stats.fetch_wait_time
                                  / repeats, 4),
            "write_gb_s": round(io.write_bandwidth / 1e9, 3)
            if io.write_time else None,
            "read_gb_s": round(io.read_bandwidth / 1e9, 3)
            if io.read_time else None,
            "copies_per_byte": round(dp["backend"]["copies_per_byte"],
                                     3),
            "pool_hit_rate": dp["pool"]["hit_rate"],
            "pool_bytes_allocated": dp["pool"]["bytes_allocated"],
        }
        if cell_tracer is not None:
            ana = obs_overlap.analyze(cell_tracer.snapshot(),
                                      cell_tracer.counters())
            rec["io_hidden_frac"] = round(ana["io_hidden_frac"], 3)
            rec["stall_queue_s"] = round(ana["stall_queue_s"]
                                         / repeats, 4)
        if isinstance(backend, AioBackend):
            rec["o_direct"] = backend.direct
        return rec
    finally:
        spool.close()
        obs_tracer._TRACER = prev_tracer
        shutil.rmtree(root, ignore_errors=True)


def tracing_overhead(stream, *, rounds: int = 5) -> Dict:
    """Paired traced-vs-untraced A/B of the full store+fetch loop on the
    mem backend (no device time, so any tracer cost is maximally
    visible). Alternating rounds + median-of-ratios cancel background
    drift; the --check bound asserts the median overhead <= 2% (with a
    small absolute floor for timer noise on millisecond rounds)."""
    import statistics

    def one_round(traced: bool) -> float:
        prev = obs_tracer._TRACER
        obs_tracer._TRACER = obs_tracer.Tracer() if traced else None
        spool = ActivationSpool(HostMemoryBackend(), codec="raw",
                                store_threads=1, min_offload_elements=16)
        try:
            t0 = time.perf_counter()
            for key, leaves in stream.items():
                spool.offload(key, leaves)
            spool.wait_io()
            keys = list(stream)
            for i in range(len(keys) - 1, -1, -1):
                if i > 0:
                    spool.prefetch(keys[i - 1])
                spool.fetch(keys[i])
                spool.drop(keys[i])
            return time.perf_counter() - t0
        finally:
            spool.close()
            obs_tracer._TRACER = prev

    one_round(False)                    # warm allocators / page cache
    base, traced = [], []
    for _ in range(rounds):
        base.append(one_round(False))
        traced.append(one_round(True))
    ratios = [t / b for t, b in zip(traced, base)]
    med_base = statistics.median(base)
    med_traced = statistics.median(traced)
    return {
        "rounds": rounds,
        "untraced_s": round(med_base, 5),
        "traced_s": round(med_traced, 5),
        "median_ratio": round(statistics.median(ratios), 4),
        "overhead_frac": round(statistics.median(ratios) - 1.0, 4),
    }


def bench_opt_overlap(*, quick: bool = False, check: bool = False,
                      out: str = OPT_OUT_PATH) -> Dict:
    """End-to-end step-time A/B: the serial schedule of per-layer
    optimizer updates (``opt_overlap="sync"`` — same kernels, same SSD
    moment traffic, drained at the step barrier) vs the eager schedule
    (``opt_overlap=True`` — the same work hidden under backward).

    A synthetic profile makes the comparison mean something on a fast
    box: an undelayed run calibrates the compute step time, then the
    fault wrapper's write/read delays price each moment transfer at 15%
    of the step, so the serial arm's drain exposes the reads and update
    compute between steps while the overlapped arm's obs rows measure
    how much of the identical traffic stayed hidden.

    The legacy fused path (``host_offload="opt_state"``) rides along as
    ``fused_ref`` for context only: its fetch lands ~1 ms after the
    stage, while the store is still in flight, so tensor forwarding
    always upgrades the in-memory reference and the backend is never
    read — a RAM-resident baseline, not the DRAM-constrained regime
    SSD offload targets (the moments must round-trip for real).

    Emits ``BENCH_optoverlap.json``. ``--check`` asserts the overlapped
    step is no slower than the serial one, that >= 80% of the opt-state
    I/O was hidden, and that per-step losses are bitwise identical
    across all three arms (the tentpole's correctness bar)."""
    import dataclasses
    import statistics

    from repro.configs.base import SpoolIoConfig
    from repro.configs.paper_models import small_gpt
    from repro.io import FaultInjectingBackend
    from repro.optim.optimizers import adamw
    from repro.resilience import unwrap_chain
    from repro.session import TrainSession

    # compute must dwarf the bridge's fixed per-stage costs (queue hops,
    # per-leaf dispatch) or the A/B measures overhead, not overlap —
    # hence a real token budget even in --quick
    steps = 4 if quick else 6
    batch, seq = (8, 128) if quick else (8, 256)
    cfg = dataclasses.replace(small_gpt(128, 2), dtype="float32")
    tmp = tempfile.mkdtemp(prefix="bench_optoverlap_")

    def arm(name: str, *, host_offload: str, opt_overlap,
            delay: float, traced: bool = True) -> Dict:
        io = SpoolIoConfig(backend="fault:mem",
                           host_offload=host_offload)
        sess = TrainSession(
            cfg, engine="jit", io=io,
            optimizer=adamw(1e-3, clip_norm=None),
            opt_overlap=opt_overlap or None,
            lr=1e-3, batch_size=batch, seq_len=seq, seed=3, ckpt_every=0,
            min_offload_elements=2 ** 8,
            trace=(os.path.join(tmp, f"{name}.trace.json")
                   if traced else None))
        try:
            for b in unwrap_chain(sess.spool.backend):
                if isinstance(b, FaultInjectingBackend):
                    b.write_delay = b.read_delay = delay
            result = sess.run(steps)
            # reports[0] is the compile step: its obs row carries the
            # first jit of the per-leaf update kernel inside
            # engine.opt_update/opt_join, which is one-time cost, not
            # exposure — skip it like the step-time median does
            times = [r.step_time for r in result.reports[1:]]  # skip jit
            rows = [r.obs for r in result.reports[1:] if r.obs]
            busy = sum(r.get("opt_io_busy_s", 0.0) for r in rows)
            waited = sum(r.get("opt_exposed_wait_s", 0.0) for r in rows)
            exposed = sum(r.get("opt_exposed_io_s", 0.0) for r in rows)
            return {
                "arm": name,
                "median_step_s": round(statistics.median(times), 4),
                "opt_io_busy_s": round(busy, 4),
                "opt_exposed_wait_s": round(waited, 4),
                "opt_exposed_io_s": round(exposed, 4),
                "opt_hidden_frac": (round(1.0 - min(exposed, busy)
                                          / busy, 4) if busy else None),
                "losses": [float(l) for l in result.losses],
                "bridge": (sess._opt_bridge.stats()
                           if sess._opt_bridge is not None else None),
            }
        finally:
            sess.close()

    try:
        # phase 1: undelayed fused run calibrates compute step time
        cal = arm("calibrate", host_offload="opt_state",
                  opt_overlap=False, delay=0.0, traced=False)
        t_step = cal["median_step_s"]
        # phase 2: price each moment transfer at 15% of the step so the
        # serial drain exposes a meaningful fraction of the step time
        delay = 0.15 * t_step
        serial = arm("serial", host_offload="none",
                     opt_overlap="sync", delay=delay)
        overlapped = arm("overlapped", host_offload="none",
                         opt_overlap=True, delay=delay)
        fused = arm("fused_ref", host_offload="opt_state",
                    opt_overlap=False, delay=delay, traced=False)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    rec = {
        "t_step_calibrated_s": round(t_step, 4),
        "transfer_delay_s": round(delay, 4),
        "steps": steps,
        "serial": serial,
        "overlapped": overlapped,
        "fused_ref": fused,
        "speedup": round(serial["median_step_s"]
                         / overlapped["median_step_s"], 3),
        "losses_bitwise_equal": (serial["losses"] == overlapped["losses"]
                                 == fused["losses"]),
    }
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"# opt-overlap A/B ({steps} steps, transfer delay "
          f"{delay*1e3:.0f} ms): serial {serial['median_step_s']}s/step "
          f"(opt hidden {serial['opt_hidden_frac']}), overlapped "
          f"{overlapped['median_step_s']}s/step (opt hidden "
          f"{overlapped['opt_hidden_frac']}), speedup {rec['speedup']}x,"
          f" fused RAM-resident ref {fused['median_step_s']}s/step,"
          f" losses bitwise equal: {rec['losses_bitwise_equal']}")
    print(f"# wrote {out}")

    if check:
        failures = []
        if overlapped["median_step_s"] > serial["median_step_s"]:
            failures.append(
                f"overlapped step {overlapped['median_step_s']}s slower "
                f"than serial {serial['median_step_s']}s")
        hidden = overlapped["opt_hidden_frac"] or 0.0
        if hidden < 0.8:
            failures.append(f"opt I/O hidden fraction {hidden} < 0.8")
        if not rec["losses_bitwise_equal"]:
            failures.append(f"losses diverged: {serial['losses']} vs "
                            f"{overlapped['losses']}")
        if failures:
            raise SystemExit("opt-overlap check FAILED: "
                             + "; ".join(failures))
        print("# opt-overlap check passed: overlapped <= serial, >=80% "
              "of opt I/O hidden, losses bitwise identical")
    return rec


def main(argv=()) -> List[Dict]:
    # default (): benchmarks.run calls main() with no args and must not
    # inherit ITS sys.argv (e.g. the module-selection word)
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small stream (CI smoke)")
    ap.add_argument("--check", action="store_true",
                    help="assert data-plane invariants; non-zero exit "
                         "on violation")
    ap.add_argument("--out", default=OUT_PATH)
    ap.add_argument("--opt-overlap", action="store_true",
                    help="run ONLY the serial-vs-overlapped optimizer "
                         "step A/B and write BENCH_optoverlap.json")
    ap.add_argument("--opt-out", default=OPT_OUT_PATH)
    args = ap.parse_args(list(argv))

    if args.opt_overlap:
        bench_opt_overlap(quick=args.quick, check=args.check,
                          out=args.opt_out)
        return []

    if args.quick:
        stream = _residual_stream(6, 3, 128 * 1024)     # ~4.5 MB
        repeats = 2
    else:
        stream = _residual_stream(6, 3, 2 * 1024 * 1024)  # ~72 MB
        repeats = 3

    rows = []
    print("name,us_per_call,derived")

    def emit(rec):
        rows.append(rec)
        total_us = (rec["store_wall_s"] + rec["fetch_wall_s"]) * 1e6
        print(f"spool_datapath/{rec['backend']}-{rec['codec']},"
              f"{total_us:.0f},"
              f"store_gb_s={rec['store_gb_s']}"
              f";copies_per_byte={rec['copies_per_byte']}"
              f";pool_hit_rate={rec['pool_hit_rate']}"
              f";fetch_wait_s={rec['fetch_wait_s']}"
              f";io_hidden_frac={rec.get('io_hidden_frac')}")

    emit(run_one("legacy", "raw", stream, repeats=repeats))
    for kind in BACKENDS:
        for codec in CODECS:
            os.sync()       # level the page-cache field between cells
            emit(run_one(kind, codec, stream, repeats=repeats))

    by = {(r["backend"], r["codec"]): r for r in rows}
    headline = ab_rounds(stream, rounds=3 if args.quick else 5)
    overhead = tracing_overhead(stream, rounds=3 if args.quick else 5)
    print(f"# tracing overhead (mem backend, paired medians): "
          f"{overhead['overhead_frac']*100:+.2f}% "
          f"({overhead['untraced_s']}s untraced -> "
          f"{overhead['traced_s']}s traced)")
    summary = {
        "headline": headline,
        "speedup_vs_join": headline["speedup_vs_join"],
        "tracing_overhead": overhead,
        "byteplane_vs_zlib": {
            "ratio": round(by[("fs", "byteplane")]["compress_ratio"]
                           / by[("fs", "zlib")]["compress_ratio"], 3),
            "store_speed": round(by[("fs", "byteplane")]["store_gb_s"]
                                 / by[("fs", "zlib")]["store_gb_s"], 3),
        },
    }
    print(f"# delivered GB/s: {headline['delivered_gb_s']} "
          f"(legacy burst-into-cache: "
          f"{headline['legacy_burst_gb_s']} GB/s)")
    print(f"# speedup_vs_join (delivered, paired medians): "
          f"{headline['speedup_vs_join']}  "
          f"byteplane_vs_zlib: {summary['byteplane_vs_zlib']}")
    with open(args.out, "w") as f:
        json.dump({"cells": rows, "summary": summary}, f, indent=1)
    print(f"# wrote {args.out}")

    if args.check:
        failures = []
        for cell in ("fs", "striped"):
            cpb = by[(cell, "raw")]["copies_per_byte"]
            if cpb > 1.0:
                failures.append(f"{cell}/raw copies_per_byte={cpb} > 1")
        aio_cpb = by[("aio", "raw")]["copies_per_byte"]
        if aio_cpb > 1.0:
            failures.append(f"aio/raw copies_per_byte={aio_cpb} > 1 "
                            "(one staging copy allowed)")
        for (b, c), r in by.items():
            if r["pool_hit_rate"] is not None and \
                    r["pool_bytes_allocated"] > 4 * r["logical_mb"] * 1e6:
                failures.append(f"{b}/{c} pool churn: allocated "
                                f"{r['pool_bytes_allocated']} bytes")
        # tracing must stay within 2% of untraced step time (ISSUE 6
        # acceptance bound). Millisecond-scale rounds make the ratio
        # alone noisy, so a 2 ms absolute delta also passes — on any
        # real step (hundreds of ms) only the 2% bound matters.
        delta_s = overhead["traced_s"] - overhead["untraced_s"]
        if overhead["median_ratio"] > 1.02 and delta_s > 0.002:
            failures.append(
                f"tracing overhead {overhead['overhead_frac']*100:.2f}%"
                f" (+{delta_s*1e3:.2f} ms) exceeds the 2% bound")
        if failures:
            raise SystemExit("data-plane check FAILED: "
                             + "; ".join(failures))
        print("# data-plane check passed: vectored path <= 1 "
              "copy/byte, pool reuse bounded, tracing overhead <= 2%")
    return rows


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
