"""Shared benchmark plumbing: StagedTrainer runs over the paper's model
families at CPU-runnable scale, with exact activation-peak accounting."""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core.rok import RokPoint, model_flops_per_step
from repro.core.staged import StagedTrainer
from repro.models.api import build_model
from repro.models.transformer import RunSettings
from repro.optim.optimizers import sgd

# small models keep every CPU benchmark < ~1 min; the paper's filter would
# keep these residuals resident, so benches lower it (same mechanism).
MIN_OFFLOAD = 2 ** 12


@dataclass
class RunResult:
    strategy: str
    batch: int
    step_time_s: float
    peak_activation_bytes: int
    backward_begin_bytes: int
    bytes_offloaded: int
    bytes_forwarded: int
    loss: float
    n_params: int
    tokens: int
    fetch_wait_s: float = 0.0

    def rok_point(self) -> RokPoint:
        return RokPoint(self.strategy, self.batch,
                        self.peak_activation_bytes, self.step_time_s,
                        model_flops_per_step(self.n_params, self.tokens))


def run_staged(cfg, *, strategy: str, batch: int, seq: int,
               steps: int = 3, seed: int = 0,
               bandwidth_limit: Optional[float] = None) -> RunResult:
    """Train `steps` steps; report the median of the post-warmup steps."""
    cfg = dataclasses.replace(cfg, dtype="float32")
    api = build_model(cfg)
    # FA semantics (q/k/v-only attention residuals) to match the paper's
    # FlashAttention-2 substrate; interpret mode executes the Pallas
    # kernel body on CPU.
    settings = RunSettings(attn_impl="pallas_interpret",
                           attn_chunk=max(seq, 64),
                           param_dtype="float32")
    opt = sgd(1e-3)
    trainer = StagedTrainer(api, settings, opt, strategy=strategy,
                            min_offload_elements=MIN_OFFLOAD,
                            bandwidth_limit=bandwidth_limit)
    params = api.init(jax.random.key(seed))
    opt_state = opt.init(params)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    rng = np.random.default_rng(seed)

    def batch_of(step):
        toks = rng.integers(0, cfg.vocab_size, (batch, seq + 1))
        b = {"tokens": jax.numpy.asarray(toks[:, :-1], jax.numpy.int32),
             "labels": jax.numpy.asarray(toks[:, 1:], jax.numpy.int32)}
        if cfg.family == "encdec":
            b["enc_tokens"] = b["tokens"]
        return b

    reports = []
    for step in range(steps):
        params, opt_state, rep = trainer.train_step(params, opt_state,
                                                    [batch_of(step)])
        reports.append(rep)
    trainer.close()
    post = reports[1:] or reports
    med = sorted(post, key=lambda r: r.step_time)[len(post) // 2]
    off = reports[-1].stats
    return RunResult(
        strategy=strategy, batch=batch, step_time_s=med.step_time,
        peak_activation_bytes=max(r.peak_activation_bytes for r in post),
        backward_begin_bytes=max(r.backward_begin_bytes for r in post),
        bytes_offloaded=off.bytes_offloaded // max(len(reports), 1),
        bytes_forwarded=off.bytes_forwarded,
        loss=post[-1].loss, n_params=n_params, tokens=batch * seq,
        fetch_wait_s=off.fetch_wait_time / max(len(reports), 1))
