"""Benchmark harness entrypoint: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run           # all
    PYTHONPATH=src python -m benchmarks.run fig10     # one

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import sys
import time

MODULES = ["fig9_endurance", "table4_offload", "fig10_overhead",
           "fig11_rok", "io_backends", "spool_datapath",
           "cache_manager", "roofline"]


def main() -> None:
    want = sys.argv[1:] or MODULES
    for name in want:
        mod = name if name in MODULES else next(
            (m for m in MODULES if m.startswith(name)), None)
        if mod is None:
            raise SystemExit(f"unknown benchmark {name!r}; "
                             f"known: {MODULES}")
        print(f"# === benchmarks.{mod} ===", flush=True)
        t0 = time.time()
        __import__(f"benchmarks.{mod}", fromlist=["main"]).main()
        print(f"# {mod} took {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
