"""Quickstart: one front door for training — `TrainSession` resolves the
config, picks the engine, owns the activation spool, and streams unified
per-step reports.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

from repro.configs import get_config, reduced
from repro.session import AdaptivePolicy, SpoolIoConfig, TrainSession


def main():
    # any of the 10 assigned architectures works here; reduced() shrinks
    # it to CPU scale while keeping the family (GQA + QKV-bias for qwen).
    cfg = dataclasses.replace(reduced(get_config("qwen2.5-3b")),
                              dtype="float32")

    with TrainSession(
            cfg, engine="staged",
            policy=AdaptivePolicy(),            # paper §3.3.3 planner
            io=SpoolIoConfig(backend="fs", codec="raw"),
            optimizer="adamw", lr=1e-3,
            batch_size=4, seq_len=64,
            min_offload_elements=2 ** 12) as sess:

        def show(rep):
            print(f"step {rep.step - 1} loss={rep.loss:.4f} "
                  f"step_time={rep.step_time:.2f}s "
                  f"act_peak={rep.peak_activation_bytes/1e6:.1f}MB "
                  f"offloaded={rep.stats.bytes_offloaded/1e6:.1f}MB "
                  f"forwarded={rep.stats.bytes_forwarded/1e6:.1f}MB")

        result = sess.run(5, on_report=show)
        rep = result.reports[-1]
        if rep.plan:
            print(f"adaptive plan: offload modules "
                  f"0..{rep.plan.last_offloaded} of "
                  f"{len(rep.plan.offload)} "
                  f"(required {rep.plan.required_bw/1e6:.0f} MB/s of "
                  f"{rep.plan.write_bw/1e6:.0f} MB/s measured)")


if __name__ == "__main__":
    main()
