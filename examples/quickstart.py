"""Quickstart: build a model from a config, run the TBA offloading
trainer for a few steps, inspect what the spool did.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.staged import StagedTrainer
from repro.models.api import build_model
from repro.models.transformer import RunSettings
from repro.optim.optimizers import adamw


def main():
    # any of the 10 assigned architectures works here; reduced() shrinks
    # it to CPU scale while keeping the family (GQA + QKV-bias for qwen).
    cfg = dataclasses.replace(reduced(get_config("qwen2.5-3b")),
                              dtype="float32")
    api = build_model(cfg)
    settings = RunSettings(attn_impl="xla", attn_chunk=64,
                           param_dtype="float32")
    opt = adamw(1e-3)

    trainer = StagedTrainer(api, settings, opt, strategy="offload",
                            min_offload_elements=2 ** 12)
    params = api.init(jax.random.key(0))
    opt_state = opt.init(params)

    rng = np.random.default_rng(0)
    B, S = 4, 64
    for step in range(5):
        toks = rng.integers(0, cfg.vocab_size, (B, S + 1))
        batch = {"tokens": jax.numpy.asarray(toks[:, :-1]),
                 "labels": jax.numpy.asarray(toks[:, 1:])}
        params, opt_state, rep = trainer.train_step(params, opt_state,
                                                    [batch])
        print(f"step {step} loss={rep.loss:.4f} "
              f"step_time={rep.step_time:.2f}s "
              f"act_peak={rep.peak_activation_bytes/1e6:.1f}MB "
              f"offloaded={rep.stats.bytes_offloaded/1e6:.1f}MB "
              f"forwarded={rep.stats.bytes_forwarded/1e6:.1f}MB")
    if rep.plan:
        print(f"adaptive plan: offload modules 0..{rep.plan.last_offloaded}"
              f" of {len(rep.plan.offload)} "
              f"(required {rep.plan.required_bw/1e6:.0f} MB/s of "
              f"{rep.plan.write_bw/1e6:.0f} MB/s measured)")
    trainer.close()


if __name__ == "__main__":
    main()
