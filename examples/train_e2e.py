"""End-to-end training driver example: a ~4M-param GPT on the synthetic
Markov corpus with the fault-tolerant TrainLoop — async checkpoints,
resume, metrics, straggler watchdog. Scale up with --arch gpt-124m for
the ~100M-parameter run (same code path).

    PYTHONPATH=src python examples/train_e2e.py --steps 300
"""
import argparse
import os
import tempfile

import jax

from repro.launch.train import main as train_main
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="small-gpt")
    ap.add_argument("--engine", default="jit",
                    choices=["jit", "staged"])
    args, _ = ap.parse_known_args()
    ckpt = tempfile.mkdtemp(prefix="e2e_ckpt_")
    metrics = os.path.join(ckpt, "metrics.jsonl")
    sys.argv = ["train", "--arch", args.arch, "--engine", args.engine,
                "--steps", str(args.steps), "--batch", "8",
                "--seq", "128", "--ckpt", ckpt, "--ckpt-every", "100",
                "--metrics", metrics]
    train_main()
    import json
    lines = [json.loads(l) for l in open(metrics)]
    print(f"\nloss: step 1 = {lines[0]['loss']:.3f}  ->  "
          f"step {lines[-1]['step']} = {lines[-1]['loss']:.3f}")
    print(f"checkpoints in {ckpt}: "
          f"{[d for d in sorted(os.listdir(ckpt)) if d.startswith('step')]}")


if __name__ == "__main__":
    main()
