"""Batched serving example: prefill + continuous decode on a reduced
qwen2.5 (GQA) with a synthetic request queue.

    PYTHONPATH=src python examples/serve_batched.py
"""
import sys

from repro.launch.serve import main as serve_main


def main():
    sys.argv = ["serve", "--arch", "qwen2.5-3b:reduced", "--requests",
                "16", "--batch", "4", "--prompt-len", "32",
                "--max-new", "16", "--cache-len", "64"]
    serve_main()


if __name__ == "__main__":
    main()
