"""Reproduce the paper's Fig. 11 ROK curve on a CPU-scale BERT: sweep
batch size x {keep, offload, recompute} and print the curve points +
Pareto front.

    PYTHONPATH=src:. python examples/rok_sweep.py
"""
from benchmarks.common import run_staged
from repro.configs.paper_models import small_bert
from repro.core.rok import pareto_front


def main():
    cfg = small_bert(384, 3)
    points = []
    for batch in (4, 8, 16):
        for strategy in ("keep", "offload", "recompute"):
            r = run_staged(cfg, strategy=strategy, batch=batch, seq=128,
                           steps=3)
            p = r.rok_point()
            points.append(p)
            print(f"B={batch:3d} {strategy:9s} "
                  f"peak={p.peak_activation_bytes/1e6:7.1f}MB "
                  f"throughput={p.throughput_flops_per_s/1e9:6.2f} GFLOP/s")
    print("\nPareto front (memory -> throughput):")
    for p in pareto_front(points):
        print(f"  {p.strategy:9s} B={p.batch_size:3d} "
              f"peak={p.peak_activation_bytes/1e6:7.1f}MB "
              f"tput={p.throughput_flops_per_s/1e9:6.2f} GFLOP/s")


if __name__ == "__main__":
    main()
