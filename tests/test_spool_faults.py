"""Fault-injection tests for the spool stack (`repro.io.faults`).

A `FaultInjectingBackend` wraps any registered backend and injects
write failures, short reads and delayed completion, driving the
recovery paths that healthy hardware only exercises by accident:
failed-store-then-fetch tensor forwarding, lease cleanup on exception,
truncated-blob surfacing with pool-lease release, cancellation /
forwarding under slow stores, and the aio backend's wait-for-sibling-
segments contract on a failed submission.
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.core.spool import ActivationSpool
from repro.io import (BACKENDS, AioBackend, FaultInjectingBackend,
                      FilesystemBackend, HostMemoryBackend,
                      backend_from_spec)

MIN_OFF = 4


def _tree(rng, n=4096):
    return {"a": rng.normal(size=(n,)).astype(np.float32),
            "b": rng.normal(size=(n, 2)).astype(np.float32)}


def _tree_bytes(tree):
    return sum(a.nbytes for a in tree.values())


def _assert_tree_equal(a, b):
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def _spool(backend, **kw):
    kw.setdefault("min_offload_elements", MIN_OFF)
    kw.setdefault("store_threads", 1)
    kw.setdefault("load_threads", 1)
    return ActivationSpool(backend, **kw)


# ------------------------------------------------------------ factory

def test_fault_backend_registered_and_spec_constructible():
    assert "fault" in BACKENDS
    bk = backend_from_spec("fault@2:mem")
    assert isinstance(bk, FaultInjectingBackend)
    assert isinstance(bk.inner, HostMemoryBackend)
    assert bk.zero_copy_read            # mirrors the inner backend
    with pytest.raises(OSError):
        bk.write("k", b"x" * 64)
    with pytest.raises(OSError):
        bk.write("k", b"x" * 64)
    bk.write("k", b"x" * 64)            # third write succeeds
    assert bk.injected["write_failures"] == 2
    assert bk.read("k") == b"x" * 64
    bk.close()


def test_fault_spec_wraps_fs_and_owns_tmpdir(tmp_path):
    bk = backend_from_spec(f"fault:fs:{tmp_path}/inner")
    assert isinstance(bk.inner, FilesystemBackend)
    assert bk.directory == f"{tmp_path}/inner"
    bk.write("k", b"payload")
    assert bk.read("k") == b"payload"
    bk.close()


# ----------------------------------------- failed-store recovery paths

def test_failed_store_then_fetch_forwards_in_memory():
    """A store that dies on the device (ENOSPC-style) must not lose the
    step: fetch forwards the still-referenced arrays instead of chasing
    a blob that never landed."""
    bk = FaultInjectingBackend(HostMemoryBackend(), fail_writes=1,
                               write_exc=OSError(28, "No space left"))
    spool = _spool(bk)
    rng = np.random.default_rng(0)
    tree = _tree(rng)
    with spool.step("mb0") as tx:
        tx.offload(0, tree)
        spool.wait_io()                  # store failed; arrays resident
        out = tx.fetch(0)
        _assert_tree_equal(tree, out)
        tx.drop(0)                       # delete of unwritten key: no-op
    assert spool.stats.bytes_forwarded == _tree_bytes(tree)
    assert bk.injected["write_failures"] == 1
    assert bk.inner.stats.num_writes == 0
    assert not spool._records
    spool.close()


def test_failed_store_peek_then_fetch_counts_one_forwarding():
    """Peek-then-fetch of one failed store is ONE forwarding event (the
    fwd_counted regression), even through the injector. Three armed
    failures defeat the default 3-attempt retry, so the store really
    fails (a single transient failure is ridden out since resilience)."""
    bk = FaultInjectingBackend(HostMemoryBackend(), fail_writes=3)
    spool = _spool(bk)
    rng = np.random.default_rng(1)
    tree = _tree(rng)
    with spool.step("mb0") as tx:
        tx.offload(0, tree)
        spool.wait_io()
        _assert_tree_equal(tree, tx.peek(0))
        _assert_tree_equal(tree, tx.fetch(0))
        tx.drop(0)
    assert spool.stats.bytes_forwarded == _tree_bytes(tree)
    assert spool.stats.store_retries == 2    # attempts 2 and 3
    spool.close()


def test_lease_dropped_on_exception_mid_step():
    """An exception between offload and fetch must not strand records:
    the transaction's close() drops everything, including blobs whose
    (delayed) store is still in flight when the step aborts."""
    bk = FaultInjectingBackend(HostMemoryBackend(), write_delay=0.2)
    spool = _spool(bk)
    rng = np.random.default_rng(2)
    with pytest.raises(RuntimeError, match="step exploded"):
        with spool.step("mb0") as tx:
            tx.offload(0, _tree(rng))
            tx.offload(1, _tree(rng))
            raise RuntimeError("step exploded")
    spool.wait_io()
    assert not spool._records            # every record dropped
    # an orphaned in-flight write is deleted when it lands; nothing may
    # survive on the backend
    assert len(bk.inner._blobs) == 0
    spool.close()
    # the lease itself was released: the step id is reusable
    spool2 = _spool(FaultInjectingBackend(HostMemoryBackend()))
    with spool2.step("mb0"):
        pass
    spool2.close()


def test_short_read_surfaces_error_and_releases_pool(tmp_path):
    """A truncated blob (torn write / bad device) must surface as a
    load error at fetch — not a hang, not a corrupt tree — and the
    pooled load buffer must go back to the pool."""
    bk = FaultInjectingBackend(FilesystemBackend(str(tmp_path)),
                               short_reads=1, short_by=8)
    spool = _spool(bk)
    rng = np.random.default_rng(3)
    tree = _tree(rng)
    with spool.step("mb0") as tx:
        tx.offload(0, tree)
        spool.wait_io()                  # store landed; memory released
        with pytest.raises(RuntimeError, match="spool load failed"):
            tx.fetch(0)
        tx.drop(0)
    assert bk.injected["short_reads"] == 1
    # the failed load's pool lease was released, not leaked
    pstats = spool.pool.stats()
    assert pstats["free_bytes"] == pstats["bytes_allocated"]
    # the spool stays usable: a healthy record round-trips after the
    # failure (the worker survived the poisoned job)
    with spool.step("mb1") as tx:
        tx.offload(0, tree)
        spool.wait_io()
        _assert_tree_equal(tree, tx.fetch(0))
        tx.drop(0)
    spool.close()


def test_delayed_store_completion_forwarding_and_cancel():
    """Slow stores widen the forwarding windows: a fetch racing a
    QUEUED store cancels it, one racing a RUNNING store forwards and
    lets the write land — counters must account for both exactly."""
    bk = FaultInjectingBackend(HostMemoryBackend(), write_delay=0.3)
    spool = _spool(bk)                   # store_threads=1: 2nd job queues
    rng = np.random.default_rng(4)
    t0, t1 = _tree(rng), _tree(rng)
    with spool.step("mb0") as tx:
        tx.offload(0, t0)                # worker picks up, sleeps
        time.sleep(0.05)                 # let the worker reach RUNNING
        tx.offload(1, t1)                # still QUEUED behind it
        _assert_tree_equal(t1, tx.fetch(1))   # queued -> cancel+forward
        _assert_tree_equal(t0, tx.fetch(0))   # running -> forward
        tx.drop(0)
        tx.drop(1)
    spool.wait_io()
    assert spool.stats.bytes_forwarded == _tree_bytes(t0) + _tree_bytes(t1)
    assert spool.stats.stores_canceled >= 1
    assert spool.stats.num_stores + spool.stats.stores_canceled == 2
    spool.close()


# ------------------------------------------------- aio sibling waits

@pytest.mark.skipif(not hasattr(os, "pwritev"), reason="needs pwritev")
def test_aio_failed_segment_waits_for_sibling_writes(tmp_path,
                                                     monkeypatch):
    """When one of a blob's concurrent segments fails, the aio backend
    must wait for every sibling pwritev to finish before closing the
    fd — closing early would let the OS recycle the descriptor under a
    still-running write (cross-blob corruption)."""
    backend = AioBackend(str(tmp_path), queue_depth=4, direct=False)
    events = []
    fds = set()
    lock = threading.Lock()
    real_pwritev, real_close = os.pwritev, os.close

    def slow_pwritev(fd, bufs, offset):
        with lock:
            fds.add(fd)
        if offset == 0:
            raise OSError(5, "injected segment failure")
        time.sleep(0.25)
        n = real_pwritev(fd, bufs, offset)
        with lock:
            events.append(("pwritev_done", fd, time.monotonic()))
        return n

    def traced_close(fd):
        with lock:
            if fd in fds:
                events.append(("close", fd, time.monotonic()))
        return real_close(fd)

    monkeypatch.setattr(os, "pwritev", slow_pwritev)
    monkeypatch.setattr(os, "close", traced_close)
    payload = os.urandom(1 << 20)        # 4 x 256 KiB segments
    with pytest.raises(OSError):
        backend.write("blob", payload)
    monkeypatch.undo()
    closes = {fd: t for ev, fd, t in events if ev == "close"}
    done = [(fd, t) for ev, fd, t in events if ev == "pwritev_done"]
    assert done, "sibling segments never ran"
    for fd, t in done:
        assert fd in closes, "fd never closed"
        assert t <= closes[fd], \
            "fd closed while a sibling pwritev was still running"
    backend.close()


def test_fault_injection_through_spool_store_path_keeps_worker_alive():
    """Armed at runtime: a burst of failures mid-training must not kill
    the store workers — later steps keep spooling normally."""
    bk = FaultInjectingBackend(HostMemoryBackend())
    spool = _spool(bk)
    rng = np.random.default_rng(5)
    ok = _tree(rng)
    with spool.step("s0") as tx:
        tx.offload(0, ok)
        spool.wait_io()
        _assert_tree_equal(ok, tx.fetch(0))
        tx.drop(0)
    bk.arm_write_failures(3, key_substr="s1")  # defeats 3-try retry
    bad = _tree(rng)
    with spool.step("s1") as tx:
        tx.offload(0, bad)
        spool.wait_io()
        _assert_tree_equal(bad, tx.fetch(0))   # forwarded
        tx.drop(0)
    with spool.step("s2") as tx:               # healthy again
        tx.offload(0, ok)
        spool.wait_io()
        _assert_tree_equal(ok, tx.fetch(0))
        tx.drop(0)
    assert bk.injected["write_failures"] == 3
    assert spool.stats.store_retries == 2
    assert spool.stats.num_stores == 2
    spool.close()
