"""Multi-device tests (8 forced host devices, one subprocess — tests and
benches must see 1 device in-process, per the dry-run contract).

Checks inside the subprocess:
  1. DP x TP sharded loss == single-device loss (GSPMD correctness);
  2. MoE expert-parallel shard_map path == local path;
  3. GPipe pipeline (shard_map + ppermute) fwd and grads == sequential;
  4. int8 error-feedback compressed gradient mean ~= exact psum mean,
     with error feedback shrinking the *accumulated* bias;
  5. the dry-run's make_train_step compiles on an (2,4) mesh (regression
     for the offload-policy/SPMD interplay).
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.models.api import build_model
from repro.models.transformer import RunSettings
from repro.models.moe import MoESettings, apply_moe, init_moe
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import make_step
from repro.parallel.sharding import MeshAxes, param_specs, with_sharding
from repro.parallel.pipeline import pipeline_apply, pipeline_loss_fn
from repro.parallel.compress import (compressed_mean_grads,
                                     exact_mean_grads, init_error_state)
from repro.optim.optimizers import adamw

assert jax.device_count() == 8
mesh = make_test_mesh((2, 4), ("data", "model"))
axes = MeshAxes(dp=("data",), tp="model")

# ---------------- 1. DP x TP loss equivalence ----------------
cfg = dataclasses.replace(
    reduced(get_config("qwen2.5-3b"), layers=2, d_model=64, heads=4,
            d_ff=128, vocab=512), dtype="float32")
api = build_model(cfg)
params = api.init(jax.random.key(0))
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, 512, (8, 32)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, 512, (8, 32)), jnp.int32)}

plain = RunSettings(attn_impl="xla", attn_chunk=32, param_dtype="float32")
loss_1dev, _ = jax.jit(lambda p, b: api.loss(p, b, plain))(params, batch)

specs = param_specs(cfg, params, mesh, axes)
p_sh = jax.device_put(params, jax.tree.map(
    lambda s: NamedSharding(mesh, s), specs,
    is_leaf=lambda x: isinstance(x, P)))
b_sh = jax.device_put(batch, NamedSharding(mesh, P("data", None)))
dist = RunSettings(attn_impl="xla", attn_chunk=32, param_dtype="float32",
                   mesh=mesh, tp_axis="model", dp_axes=("data",))
with mesh:
    loss_8dev, _ = jax.jit(lambda p, b: api.loss(p, b, dist))(p_sh, b_sh)
np.testing.assert_allclose(float(loss_1dev), float(loss_8dev),
                           rtol=1e-4, atol=1e-5)
print("PASS dp_tp_loss")

# ---------------- 2. MoE EP == local ----------------
D, F, E, K = 32, 64, 8, 2
moe_p = init_moe(jax.random.key(1), D, F, E, jnp.float32)
x = jnp.asarray(rng.normal(size=(8, 16, D)), jnp.float32)
ms = MoESettings(E, K, capacity_factor=8.0)       # no drops either path
y_local, aux_l = apply_moe(moe_p, x, ms)
x_sh = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
with mesh:
    y_ep, aux_e = jax.jit(lambda p, x: apply_moe(
        p, x, ms, mesh=mesh, ep_axis="model", dp_axes=("data",)))(
        moe_p, x_sh)
np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_ep),
                           rtol=2e-4, atol=2e-5)
print("PASS moe_ep")

# ---------------- 3. pipeline == sequential ----------------
pmesh = make_test_mesh((4,), ("pipe",))
S_, M, mb, Dp = 4, 8, 2, 16
ws = jnp.asarray(rng.normal(size=(S_, Dp, Dp)) * 0.3, jnp.float32)

def stage_fn(w, x):
    return jnp.tanh(x @ w)

x_mb = jnp.asarray(rng.normal(size=(M, mb, Dp)), jnp.float32)
with pmesh:
    y_pipe = pipeline_apply(stage_fn, ws, x_mb, pmesh)
y_seq = x_mb
for s in range(S_):
    y_seq = jnp.tanh(y_seq @ ws[s])
np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_seq),
                           rtol=1e-5, atol=1e-5)

loss_fn = pipeline_loss_fn(stage_fn, lambda y, aux: jnp.sum(y * aux),
                           pmesh)
aux = jnp.ones_like(x_mb)
with pmesh:
    g_pipe = jax.jit(jax.grad(loss_fn))(ws, x_mb, aux)
def seq_loss(ws, x_mb, aux):
    y = x_mb
    for s in range(S_):
        y = jnp.tanh(y @ ws[s])
    return jnp.sum(y * aux)
g_seq = jax.grad(seq_loss)(ws, x_mb, aux)
np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_seq),
                           rtol=1e-4, atol=1e-5)
print("PASS pipeline")

# ---------------- 4. compressed gradient mean ----------------
gmesh = make_test_mesh((8,), ("data",))
grads = {"w": jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)}
err = init_error_state(grads)
with gmesh:
    exact = exact_mean_grads(grads, gmesh, "data")
    comp, err1 = compressed_mean_grads(grads, err, gmesh, "data")
rel = float(jnp.abs(comp["w"] - exact["w"]).max()
            / jnp.abs(exact["w"]).max())
assert rel < 0.05, rel
# error feedback: same grads repeatedly -> the accumulated mean of the
# compressed estimates converges to the exact mean
acc = jnp.zeros_like(exact["w"])
e = init_error_state(grads)
N = 16
for _ in range(N):
    with gmesh:
        c, e = compressed_mean_grads(grads, e, gmesh, "data")
    acc = acc + c["w"] / N
rel_acc = float(jnp.abs(acc - exact["w"]).max()
                / jnp.abs(exact["w"]).max())
assert rel_acc < rel, (rel_acc, rel)
print("PASS compress")

# ---------------- 5. train-step compiles with offload policy ----------
bundle = make_step(api, mesh, axes, ShapeConfig("t", 32, 8, "train"),
                   optimizer=adamw(), activation_policy="offload")
with mesh:
    co = jax.jit(bundle.fn, out_shardings=bundle.out_shardings).lower(
        *bundle.args).compile()
assert co.memory_analysis() is not None
print("PASS dryrun_step")
print("ALL_OK")
"""


@pytest.mark.slow
def test_multidevice_suite():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    for marker in ("PASS dp_tp_loss", "PASS moe_ep", "PASS pipeline",
                   "PASS compress", "PASS dryrun_step", "ALL_OK"):
        assert marker in r.stdout, (marker, r.stdout, r.stderr[-2000:])


# ------------------------------------------------------------------
# Sharded activation offload: the jit engine's spool hooks under SPMD
# (repro.core.hooks shard_map path). Ground truth for ISSUE 5:
#   * DP x TP (2,4) mesh, host_offload="activations": every device
#     streams only its local residual shard through the spool under
#     shard-qualified lease keys;
#   * losses equal the same-mesh no-offload run up to XLA fusion noise
#     (the hook wrapping recompiles a differently fused program; the
#     residual bytes themselves round-trip exactly) and the
#     single-device baseline at the same rtol the dp_tp_loss
#     equivalence check uses — a tp-sharded program reorders float
#     reductions, so bitwise-vs-one-device is not a property GSPMD has
#     even without offload;
#   * two sharded-offload runs ARE bitwise identical — the async
#     spool/callback threading injects no nondeterminism;
#   * replica dedupe: a dp-only hook sharding on the same mesh stores
#     one copy per replica group and counts fetches down by the
#     tp-replica count.
# ------------------------------------------------------------------

SCRIPT_SHARDED_OFFLOAD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import numpy as np
import jax

from repro.configs.base import SpoolIoConfig
from repro.configs.paper_models import small_gpt
from repro.launch.mesh import make_test_mesh
from repro.models.transformer import RunSettings
from repro.parallel.sharding import MeshAxes
from repro.session import TrainSession

assert jax.device_count() == 8
cfg = dataclasses.replace(small_gpt(128, 2), dtype="float32")
kw = dict(optimizer="adamw", lr=1e-3, batch_size=4, seq_len=32, seed=3,
          ckpt_every=0, min_offload_elements=256)
io = SpoolIoConfig(backend="mem", host_offload="activations")


def keep_settings():
    return RunSettings(attn_impl="xla", attn_chunk=32,
                       activation_policy="keep", param_dtype="float32")


def run(mesh=None, offload=False, mesh_axes=None):
    with TrainSession(cfg, engine="jit",
                      settings=None if offload else keep_settings(),
                      mesh=mesh, mesh_axes=mesh_axes,
                      io=io if offload else None, **kw) as s:
        r = s.run(3)
        shards = (s._hook_bridge.stats_by_shard()
                  if s._hook_bridge is not None else {})
        leftover = dict(s.spool._records) if s.spool is not None else {}
        stats = dataclasses.replace(s.spool.stats) if s.spool else None
        return r.losses, shards, leftover, stats


base, _, _, _ = run()
mesh = make_test_mesh((2, 4), ("data", "model"))
mesh_keep, _, _, _ = run(mesh)
offl, shards, leftover, stats = run(mesh, offload=True)
offl2, _, _, _ = run(mesh, offload=True)

# offload transparency on the mesh (fusion-noise tolerance) and GSPMD
# correctness vs one device (same rtol as the dp_tp_loss check above)
np.testing.assert_allclose(offl, mesh_keep, rtol=1e-5)
np.testing.assert_allclose(offl, base, rtol=1e-4)
assert offl == offl2, (offl, offl2)          # bitwise deterministic
print("PASS sharded_parity")

# every device streamed its own shard; all leases consumed
assert sorted(shards) == list(range(8)), sorted(shards)
for k, v in shards.items():
    assert v["offloads"] == 6 and v["fetches"] == 6, (k, v)   # 3x2
    assert v["bytes_in"] == v["bytes_out"] > 0, (k, v)
assert not leftover, leftover
assert stats.num_stores > 0 and stats.bytes_offloaded > 0
print("PASS shard_accounting")

# replica dedupe: hooks shard over dp only -> the tp axis replicates,
# one store per replica group, fetches counted down by tp size
offl_dp, shards_dp, leftover_dp, _ = run(
    mesh, offload=True, mesh_axes=MeshAxes(dp=("data",), tp=None))
np.testing.assert_allclose(offl_dp, mesh_keep, rtol=1e-5)
assert sorted(shards_dp) == [0, 1], sorted(shards_dp)
for k, v in shards_dp.items():
    assert v["offloads"] == 6, (k, v)            # one store per group
    assert v["fetches"] == 24, (k, v)            # 4 tp replicas x 6
    assert v["replica_skips"] == 18, (k, v)      # 3 skipped writers x 6
assert not leftover_dp, leftover_dp
print("PASS replica_dedupe")
print("ALL_OK_SHARDED")
"""


@pytest.mark.slow
def test_sharded_activation_offload_parity():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT_SHARDED_OFFLOAD],
                       env=env, capture_output=True, text=True,
                       timeout=1200)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    for marker in ("PASS sharded_parity", "PASS shard_accounting",
                   "PASS replica_dedupe", "ALL_OK_SHARDED"):
        assert marker in r.stdout, (marker, r.stdout, r.stderr[-2000:])
