"""Per-kernel validation: sweep shapes/dtypes and assert_allclose against
the pure-jnp oracles (interpret mode executes kernel bodies on CPU).
Gradients flow through the custom_vjp wrappers and are checked against
direct autodiff of the oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.attention import attend_chunked
from repro.models.mamba2 import ssd_chunked
from repro.models.rglru import rglru_scan_xla

RNG = np.random.default_rng(42)


def _rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


# ------------------------------------------------------ flash attention

ATTN_CASES = [
    # (B, Sq, Skv, Hq, Hkv, D, causal, window, cap)
    (1, 128, 128, 4, 4, 32, True, 0, 0.0),      # MHA causal
    (2, 64, 64, 4, 2, 32, True, 0, 0.0),        # GQA
    (2, 64, 64, 4, 1, 32, True, 0, 0.0),        # MQA
    (1, 128, 128, 2, 2, 64, True, 32, 0.0),     # sliding window
    (1, 64, 64, 2, 2, 32, True, 0, 30.0),       # logit softcap (gemma2)
    (2, 64, 64, 4, 4, 32, False, 0, 0.0),       # bidirectional (BERT)
    (1, 96, 96, 2, 2, 32, True, 0, 0.0),        # non-multiple of block
    (1, 16, 16, 2, 2, 128, True, 0, 0.0),       # short seq, wide head
]


@pytest.mark.parametrize(
    "B,Sq,Skv,Hq,Hkv,D,causal,window,cap", ATTN_CASES)
def test_flash_attention_fwd(B, Sq, Skv, Hq, Hkv, D, causal, window, cap):
    q = _rand((B, Sq, Hq, D))
    k = _rand((B, Skv, Hkv, D))
    v = _rand((B, Skv, Hkv, D))
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              logit_cap=cap, interpret=True)
    want = ref.attention_reference(q, k, v, causal=causal, window=window,
                                   logit_cap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 3e-2)])
def test_flash_attention_dtypes(dtype, tol):
    q = _rand((2, 64, 4, 32), dtype)
    k = _rand((2, 64, 2, 32), dtype)
    v = _rand((2, 64, 2, 32), dtype)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.attention_reference(q, k, v, causal=True)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_matches_production_xla_path():
    """Kernel == oracle == production chunked path (three-way check)."""
    q = _rand((2, 64, 4, 32))
    k = _rand((2, 64, 2, 32))
    v = _rand((2, 64, 2, 32))
    a = ops.flash_attention(q, k, v, causal=True, interpret=True)
    b = attend_chunked(q, k, v, causal=True, chunk=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_grads():
    q = _rand((1, 32, 2, 16))
    k = _rand((1, 32, 2, 16))
    v = _rand((1, 32, 2, 16))

    def f_k(q, k, v):
        return ops.flash_attention(q, k, v, causal=True,
                                   interpret=True).sum()

    def f_r(q, k, v):
        return ref.attention_reference(q, k, v, causal=True).sum()

    gk = jax.grad(f_k, (0, 1, 2))(q, k, v)
    gr = jax.grad(f_r, (0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------ SSD scan

SSD_CASES = [
    # (B, S, H, P, N, chunk)
    (1, 64, 2, 16, 8, 16),
    (2, 128, 3, 32, 16, 32),
    (1, 256, 1, 64, 128, 128),     # production-like head geometry
    (2, 96, 2, 16, 8, 32),         # S not multiple of chunk -> shrinks
]


@pytest.mark.parametrize("B,S,H,P,N,chunk", SSD_CASES)
def test_ssd_scan_fwd(B, S, H, P, N, chunk):
    xh = _rand((B, S, H, P))
    a = -jnp.abs(_rand((B, S, H), scale=0.2))
    Bs = _rand((B, S, N))
    Cs = _rand((B, S, N))
    y, st = ops.ssd_scan(xh, a, Bs, Cs, chunk=chunk, interpret=True)
    yr, sr = ref.ssd_reference(xh, a, Bs, Cs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr),
                               rtol=2e-4, atol=2e-4)


def test_ssd_scan_matches_production_chunked():
    B, S, H, P, N = 2, 128, 2, 16, 8
    xh = _rand((B, S, H, P))
    a = -jnp.abs(_rand((B, S, H), scale=0.2))
    Bs = _rand((B, S, N))
    Cs = _rand((B, S, N))
    y1, s1 = ops.ssd_scan(xh, a, Bs, Cs, chunk=32, interpret=True)
    y2, s2 = ssd_chunked(xh, a, Bs, Cs, 32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


def test_ssd_scan_grads():
    B, S, H, P, N = 1, 64, 2, 8, 4
    xh = _rand((B, S, H, P))
    a = -jnp.abs(_rand((B, S, H), scale=0.2))
    Bs = _rand((B, S, N))
    Cs = _rand((B, S, N))

    gk = jax.grad(lambda *t: ops.ssd_scan(
        *t, chunk=16, interpret=True)[0].sum(), (0, 1, 2, 3))(
        xh, a, Bs, Cs)
    gr = jax.grad(lambda *t: ref.ssd_reference(*t)[0].sum(),
                  (0, 1, 2, 3))(xh, a, Bs, Cs)
    for x, y in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------- RG-LRU scan

RGLRU_CASES = [
    (1, 64, 16, 256, 512),
    (2, 128, 32, 32, 16),          # width split into blocks
    (1, 100, 8, 256, 512),         # S=100 -> chunk shrinks to divisor
]


@pytest.mark.parametrize("B,S,W,chunk,blk_w", RGLRU_CASES)
def test_rglru_scan_fwd(B, S, W, chunk, blk_w):
    la = -jnp.abs(_rand((B, S, W), scale=0.5))
    x = _rand((B, S, W))
    h = ops.rglru_scan(la, x, interpret=True)
    hr = ref.rglru_reference(la, x)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=1e-5, atol=1e-5)


def test_rglru_matches_production_associative_scan():
    la = -jnp.abs(_rand((2, 64, 16), scale=0.5))
    x = _rand((2, 64, 16))
    h1 = ops.rglru_scan(la, x, interpret=True)
    h2 = rglru_scan_xla(la, x)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-4, atol=1e-4)


def test_rglru_grads():
    la = -jnp.abs(_rand((1, 32, 8), scale=0.5))
    x = _rand((1, 32, 8))
    gk = jax.grad(lambda a, b: ops.rglru_scan(
        a, b, interpret=True).sum(), (0, 1))(la, x)
    gr = jax.grad(lambda a, b: ref.rglru_reference(a, b).sum(),
                  (0, 1))(la, x)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4)
