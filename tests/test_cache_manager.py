"""Tests for the unified cache manager (`repro.cache`): the
class-aware placement brain over pinned-host-RAM / SSD, the shared
`reuse_horizon` helper, the `plan_residency` predictor, and — the
fault-injection centerpiece — migration under a failing SSD tier,
which must degrade to host-RAM residency with no data loss, clean
lease teardown, and exact byte accounting.
"""
import time

import numpy as np
import pytest

from repro.cache import (CacheConfig, CacheManager, PlacementEngine,
                         plan_residency, reuse_horizon)
from repro.core.adaptive import ModuleProfile
from repro.core.policies import AdaptivePolicy
from repro.core.spool import ActivationSpool
from repro.io import (BACKENDS, FaultInjectingBackend,
                      FilesystemBackend, HostMemoryBackend,
                      backend_from_spec)

KB = 1 << 10


def _blob(rng, n=6 * KB):
    return rng.integers(0, 256, n, dtype=np.uint8).tobytes()


def _mgr(lower=None, bound=16 * KB, **cfg_kw):
    cfg_kw.setdefault("host_bound_bytes", bound)
    return CacheManager(lower if lower is not None
                        else HostMemoryBackend(),
                        config=CacheConfig(**cfg_kw).validate())


# ------------------------------------------------------------ registry

def test_managed_registered_and_spec_constructible():
    assert "managed" in BACKENDS
    bk = backend_from_spec("managed:16kb,mem")
    assert isinstance(bk, CacheManager)
    assert isinstance(bk.lower, HostMemoryBackend)
    assert bk.capacity_bytes == 16 * KB
    bk.write("k", b"x" * KB)
    assert bk.read("k") == b"x" * KB
    bk.close()


def test_classification_longest_prefix_wins():
    m = _mgr()
    assert m.classify("mb0_s1") == "activation"
    assert m.classify("opt3_moments") == "opt_state"
    assert m.classify("kv12_p4") == "kv_page"
    m.register_class("special", prefix="opt_special", distance=9.0)
    assert m.classify("opt_special_x") == "special"
    assert m.classify("opt3_moments") == "opt_state"
    m.register_class("special")          # idempotent re-registration
    assert m.classify("opt_special_x") == "special"
    m.close()


# ------------------------------------------- placement and accounting

def test_bound_respected_and_accounting_exact():
    """A healthy SSD tier: the host-RAM bound holds, every byte is on
    exactly one tier, and the per-tier sums reconcile with the blobs."""
    rng = np.random.default_rng(0)
    m = _mgr(bound=16 * KB, promote=False)
    blobs = {f"mb0_s{i}": _blob(rng) for i in range(5)}
    for k, b in blobs.items():
        m.write(k, b)
    assert m.resident_bytes <= m.capacity_bytes
    upper, lowered = m.engine.tier_items()
    assert set(upper) | set(lowered) == set(blobs)
    assert not set(upper) & set(lowered)
    total = sum(len(b) for b in blobs.values())
    assert sum(upper.values()) + sum(lowered.values()) == total
    st = m.cache_stats()
    assert st["host_bytes"] + st["ssd_bytes"] == total
    assert st["host_peak_bytes"] <= m.capacity_bytes
    for k, b in blobs.items():           # every blob readable bitwise
        assert m.read(k) == b
    m.close()


def test_victim_is_farthest_reuse_class():
    """Belady's choice by class: the kv page (distance 3x) is demoted
    before either activation, regardless of store order."""
    rng = np.random.default_rng(1)
    m = _mgr(bound=16 * KB, promote=False)
    m.write("mb0_s0", _blob(rng))
    m.write("kv7_p0", _blob(rng))
    m.write("mb0_s1", _blob(rng))        # overflow: one victim needed
    res = m.residency()
    assert res["ssd"] == {"kv_page": 6 * KB}
    assert res["host-ram"] == {"activation": 12 * KB}
    m.close()


def test_hinted_keys_survive_eviction():
    """A key on the hinted reuse horizon is never the victim — the
    next-farthest unhinted blob is demoted instead."""
    rng = np.random.default_rng(2)
    m = _mgr(bound=16 * KB, promote=False)
    m.write("mb0_s0", _blob(rng))
    m.write("kv7_p0", _blob(rng))
    m.hint_next(["kv7_p0"])              # imminent refill
    m.write("mb0_s1", _blob(rng))
    upper, lowered = m.engine.tier_items()
    assert "kv7_p0" in upper
    assert "mb0_s0" in lowered           # the unhinted activation paid
    m.close()


def test_hint_promotes_lowered_blob_back_to_host():
    """hint_next on a lowered key triggers background promotion once
    the slow (measured) lower tier prices the move as a win and the
    budget has headroom."""
    rng = np.random.default_rng(3)
    slow = FaultInjectingBackend(HostMemoryBackend(), write_delay=0.02)
    m = _mgr(lower=slow, bound=16 * KB, promote_depth=2)
    blobs = {f"mb0_s{i}": _blob(rng) for i in range(4)}
    for k, b in blobs.items():
        m.write(k, b)
    _, lowered = m.engine.tier_items()
    assert lowered                       # something spilled
    victim = next(iter(lowered))
    for k in list(blobs):                # free headroom for promotion
        if k != victim:
            m.delete(k)
    m.hint_next([victim])
    deadline = time.monotonic() + 5.0
    while m.engine.promotions == 0 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert m.engine.promotions == 1
    assert m.engine.bytes_promoted == len(blobs[victim])
    upper, lowered = m.engine.tier_items()
    assert victim in upper and not lowered
    assert m.read(victim) == blobs[victim]
    m.close()


def test_measured_distances_rerank_victims():
    """AdaptivePolicy's profile feed: once activations measure FARTHER
    reuse than kv pages, the activation becomes the victim."""
    rng = np.random.default_rng(4)
    m = _mgr(bound=16 * KB, promote=False)
    pol = AdaptivePolicy()
    pol.attach_cache_manager(m)
    pol.on_profile([ModuleProfile("l0", 6 * KB, 2.0),
                    ModuleProfile("l1", 6 * KB, 2.0)], 1e9)
    # t_step = 4s * (1 + bwd_factor): activation 0.5x, kv 3x of that —
    # kv still farther; now flip the table by hand like a serving-side
    # recency feed would
    assert m._distances["kv_page"] > m._distances["activation"]
    m.hint_class_distance("kv_page", 0.1)
    m.write("mb0_s0", _blob(rng))
    m.write("kv7_p0", _blob(rng))
    m.write("mb0_s1", _blob(rng))
    _, lowered = m.engine.tier_items()
    assert set(lowered) == {"mb0_s0"}
    m.close()


# ------------------------------------- failing SSD tier (the satellite)

def test_failing_ssd_falls_back_to_host_residency():
    """Every demotion into a dead SSD tier must re-admit the blob to
    host RAM: no data loss, nothing on the SSD, accounting exact."""
    rng = np.random.default_rng(5)
    ssd = FaultInjectingBackend(
        HostMemoryBackend(), fail_writes=10_000,
        write_exc=OSError(5, "Input/output error"))
    m = _mgr(lower=ssd, bound=16 * KB, promote=False)
    blobs = {f"mb0_s{i}": _blob(rng) for i in range(5)}
    for k, b in blobs.items():
        m.write(k, b)
    total = sum(len(b) for b in blobs.values())
    # all five blobs are host-resident (over budget — degraded mode)
    upper, lowered = m.engine.tier_items()
    assert set(upper) == set(blobs) and not lowered
    assert sum(upper.values()) == total == m.resident_bytes
    assert m.peak_host_bytes >= total
    st = m.cache_stats()
    assert st["fallbacks"] >= 3          # the three overflow victims
    assert st["bytes_fallback"] >= 3 * 6 * KB
    assert st["ssd_bytes"] == 0
    assert len(ssd.inner._blobs) == 0    # nothing ever landed on SSD
    for k, b in blobs.items():
        assert m.read(k) == b
    m.close()


def test_transient_ssd_failure_exact_fallback_accounting():
    """Exactly one armed write failure -> exactly one fallback, with
    byte-exact counters, and later demotions succeed again."""
    rng = np.random.default_rng(6)
    ssd = FaultInjectingBackend(HostMemoryBackend())
    m = _mgr(lower=ssd, bound=16 * KB, promote=False)
    m.write("mb0_s0", _blob(rng))
    m.write("mb0_s1", _blob(rng))
    ssd.arm_write_failures(1)
    m.write("mb0_s2", _blob(rng))        # victim's demotion fails
    assert m.engine.fallbacks == 1
    assert m.engine.bytes_fallback == 6 * KB
    assert m.engine.evictions == 0
    m.write("mb0_s3", _blob(rng))        # SSD healthy again
    _, lowered = m.engine.tier_items()
    assert m.engine.evictions >= 1 and lowered
    assert ssd.injected["write_failures"] == 1
    m.close()


def test_oversize_blob_with_failing_ssd_stays_in_ram():
    """An over-budget blob normally bypasses RAM straight to SSD; with
    the SSD down it is held in RAM instead of lost."""
    rng = np.random.default_rng(7)
    ssd = FaultInjectingBackend(HostMemoryBackend(), fail_writes=1)
    m = _mgr(lower=ssd, bound=8 * KB, promote=False)
    big = _blob(rng, 32 * KB)
    m.write("mb0_s0", big)
    assert m.engine.fallbacks == 1
    assert m.engine.bytes_fallback == 32 * KB
    assert m.resident_bytes == 32 * KB   # over budget, by design
    assert m.read("mb0_s0") == big
    m.delete("mb0_s0")
    assert m.resident_bytes == 0
    m.close()


def test_spool_leases_drop_cleanly_over_failing_ssd(tmp_path):
    """The full lease contract through the manager with a dead SSD
    tier: residuals offload, fetch back bitwise, and the transaction's
    close leaves neither spool records nor manager residency behind."""
    rng = np.random.default_rng(8)
    ssd = FaultInjectingBackend(
        FilesystemBackend(str(tmp_path / "ssd")), fail_writes=10_000)
    m = _mgr(lower=ssd, bound=8 * KB, promote=False)
    spool = ActivationSpool(m, min_offload_elements=4,
                            store_threads=1, load_threads=1)
    trees = {s: {"r": rng.normal(size=(2048,)).astype(np.float32)}
             for s in range(3)}
    with spool.step("mb0") as tx:
        for s, t in trees.items():
            tx.offload(s, t)
        spool.wait_io()
        for s in reversed(range(3)):     # backward-order fetch
            out = tx.fetch(s)
            np.testing.assert_array_equal(out["r"], trees[s]["r"])
            tx.drop(s)
    assert not spool._records            # lease fully dropped
    upper, lowered = m.engine.tier_items()
    assert not upper and not lowered     # manager accounting empty
    assert m.resident_bytes == 0
    spool.close()


# --------------------------------------------------- metrics / planning

def test_metrics_delta_diffs_monotonic_counters():
    rng = np.random.default_rng(9)
    m = _mgr(bound=16 * KB, promote=False)
    for i in range(3):
        m.write(f"mb0_s{i}", _blob(rng))
    block, snap = m.metrics_delta(None)
    assert block["evictions"] == m.engine.evictions >= 1
    ev0 = m.engine.evictions
    m.write("mb0_s3", _blob(rng))
    m.read("mb0_s3")
    block, _ = m.metrics_delta(snap)
    assert block["evictions"] == m.engine.evictions - ev0
    assert block["host_hits"] == 1
    # gauges pass through, not diffed
    assert block["host_bytes"] == m.engine.resident_bytes
    assert block["host_bound_bytes"] == 16 * KB
    m.close()


def test_plan_residency_fills_host_by_reuse_distance():
    plan = plan_residency(
        {"activation": 6, "opt_state": 6, "kv_page": 6},
        host_bound_bytes=10)
    assert plan["activation"] == {"host_ram_bytes": 6, "ssd_bytes": 0}
    assert plan["opt_state"] == {"host_ram_bytes": 4, "ssd_bytes": 2}
    assert plan["kv_page"] == {"host_ram_bytes": 0, "ssd_bytes": 6}
    zero = plan_residency({"activation": 5}, host_bound_bytes=0)
    assert zero["activation"] == {"host_ram_bytes": 0, "ssd_bytes": 5}
    flipped = plan_residency(
        {"activation": 6, "kv_page": 6}, host_bound_bytes=6,
        distances={"kv_page": 0.1})
    assert flipped["kv_page"]["host_ram_bytes"] == 6
    assert flipped["activation"]["ssd_bytes"] == 6


def test_reuse_horizon_prefix_semantics():
    assert reuse_horizon(range(3, -1, -1)) == [3]
    assert reuse_horizon(range(3, -1, -1), depth=2) == [3, 2]
    assert reuse_horizon(range(1, -1, -1), depth=5) == [1, 0]
    assert reuse_horizon([], depth=3) == []
    assert reuse_horizon(["a", "b"], depth=0) == []


def test_placement_engine_fifo_default_matches_tiered():
    """Without a victim_fn the engine is the legacy tiered policy:
    FIFO front-pop, no class awareness."""
    eng = PlacementEngine(HostMemoryBackend(), HostMemoryBackend(),
                          capacity_bytes=2 * KB)
    eng.put("a", KB, lambda t: t.write("a", b"x" * KB))
    eng.put("b", KB, lambda t: t.write("b", b"y" * KB))
    eng.put("c", KB, lambda t: t.write("c", b"z" * KB))
    upper, lowered = eng.tier_items()
    assert set(lowered) == {"a"} and list(upper) == ["b", "c"]
    assert eng.read("a") == b"x" * KB
