"""Unit tests for the ActivationSpool: async store/load roundtrip, tensor
forwarding, dedup, store cancellation, the wait_io barrier, and the
simulated-bandwidth mode used by the ROK sweeps."""
import os
import tempfile
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.spool import ActivationSpool


def _spool(**kw):
    d = tempfile.mkdtemp(prefix="spool_test_")
    kw.setdefault("min_offload_elements", 16)
    return ActivationSpool(d, **kw), d


def _tree(seed=0, n=3, shape=(64, 64)):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.normal(size=shape), jnp.float32)
            for _ in range(n)]


def test_roundtrip_exact():
    spool, d = _spool()
    tree = _tree()
    spool.offload("k0", tree)
    spool.wait_io()
    out = spool.fetch("k0")
    for a, b in zip(tree, out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    spool.drop("k0")
    assert not os.path.exists(os.path.join(d, "k0.act"))
    spool.close()


def test_bf16_roundtrip():
    spool, _ = _spool()
    tree = [jnp.ones((32, 32), jnp.bfloat16) * 1.5]
    spool.offload("k", tree)
    spool.wait_io()
    out = spool.fetch("k")
    assert out[0].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out[0], np.float32),
                                  np.asarray(tree[0], np.float32))
    spool.close()


def test_forwarding_when_store_in_flight():
    """fetch() during a slow store must forward the in-memory reference
    (paper §3.3.2) and cancel queued writes (§3.3.3 feature 1)."""
    spool, _ = _spool(bandwidth_limit=1e6, store_threads=1)  # ~1 MB/s
    t1 = _tree(1)
    t2 = _tree(2)
    spool.offload("a", t1)          # occupies the single store thread
    spool.offload("b", t2)          # waits in queue
    out = spool.fetch("b")          # must forward, not wait for disk
    for a, b in zip(t2, out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert spool.stats.bytes_forwarded > 0
    assert spool.stats.stores_canceled >= 1
    spool.wait_io()
    spool.close()


def test_dedup_same_buffer_written_once():
    spool, _ = _spool()
    x = jnp.ones((128, 128), jnp.float32)
    spool.offload("k1", [x, x])     # same buffer twice
    spool.wait_io()
    assert spool.stats.bytes_deduped >= x.size * 4
    out = spool.fetch("k1")
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out[1]))
    spool.close()


def test_parameters_never_offloaded():
    spool, _ = _spool()
    p = jnp.ones((64, 64), jnp.float32)
    spool.register_parameters({"w": p})
    spool.offload("k", [p, jnp.zeros((64, 64), jnp.float32)])
    spool.wait_io()
    out = spool.fetch("k")
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(p))
    spool.close()


def test_small_tensors_stay_in_memory():
    spool, _ = _spool(min_offload_elements=10**6)
    t = _tree(shape=(8, 8))
    spool.offload("k", t)
    spool.wait_io()
    assert spool.stats.bytes_offloaded == 0   # all below the threshold
    out = spool.fetch("k")
    for a, b in zip(t, out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    spool.close()


def test_keep_then_fetch():
    spool, _ = _spool()
    t = _tree()
    spool.keep("k", t)
    out = spool.fetch("k")
    for a, b in zip(t, out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    spool.drop("k")
    assert spool.tracker.current == 0
    spool.close()


def test_prefetch_then_fetch():
    spool, _ = _spool()
    t = _tree()
    spool.offload("k", t)
    spool.wait_io()
    spool.prefetch("k")
    spool.wait_io()
    out = spool.fetch("k")
    for a, b in zip(t, out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    spool.close()


def test_tracker_reflects_offload_lifecycle():
    spool, _ = _spool()
    t = _tree(shape=(256, 256))
    nbytes = sum(x.size * 4 for x in t)
    spool.offload("k", t)
    spool.wait_io()                 # store done -> device bytes released
    assert spool.tracker.current == 0
    spool.fetch("k")                # reloaded -> resident again
    assert spool.tracker.current == nbytes
    spool.drop("k")
    assert spool.tracker.current == 0
    spool.close()


def test_step_lease_roundtrip_and_keys():
    """The transaction derives the seed's exact key shape and owns drop
    bookkeeping."""
    spool, d = _spool()
    t = _tree()
    with spool.step("mb0") as tx:
        assert tx.key(3) == "mb0_s3"
        tx.offload(3, t)
        spool.wait_io()
        assert os.path.exists(os.path.join(d, "mb0_s3.act"))
        out = tx.fetch(3)
        for a, b in zip(t, out):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        tx.drop(3)
    assert not os.path.exists(os.path.join(d, "mb0_s3.act"))
    assert spool.tracker.current == 0
    spool.close()


def test_step_lease_drops_leftovers_on_exception():
    """An exception mid-step must not leak records, memory accounting,
    or backend blobs (the seed's hand-rolled protocol leaked all
    three)."""
    spool, d = _spool()
    with pytest.raises(RuntimeError, match="boom"):
        with spool.step("mb0") as tx:
            tx.offload(0, _tree(0))
            tx.keep(1, _tree(1))
            spool.wait_io()
            raise RuntimeError("boom")
    assert spool.tracker.current == 0
    assert not spool._records
    assert not os.path.exists(os.path.join(d, "mb0_s0.act"))
    # the lease is released: the same step id can be leased again
    with spool.step("mb0") as tx:
        tx.keep(0, _tree())
        tx.fetch(0)
    spool.close()


def test_step_lease_collision_and_unknown_stage():
    spool, _ = _spool()
    tx = spool.step("s")
    with pytest.raises(RuntimeError):
        spool.step("s")             # double lease of a live step id
    with pytest.raises(KeyError):
        tx.fetch(0)                 # never recorded
    tx.prefetch(0)                  # unknown stage: silently ignored
    tx.close()
    tx.close()                      # idempotent
    spool.step("s").close()         # released after close
    spool.close()


def test_peek_does_not_cancel_pending_store():
    """A non-consuming fetch (checkpoint materialization) must leave a
    queued store alive so the blob still lands; and a consuming fetch
    after a cancel must forward the still-resident arrays instead of
    chasing a blob that was never written."""
    spool, d = _spool(bandwidth_limit=1e6, store_threads=1)  # ~1 MB/s
    t1, t2 = _tree(1), _tree(2)
    with spool.step("opt") as tx:
        spool.offload("blocker", t1)    # occupies the single store thread
        tx.offload(0, t2)               # waits in queue
        out = tx.peek(0)                # forwarded, NOT cancelled
        for a, b in zip(t2, out):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert spool.stats.stores_canceled == 0
        spool.wait_io()                 # the store still landed
        assert os.path.exists(os.path.join(d, "opt_s0.act"))
        out2 = tx.fetch(0)              # consuming fetch finds the blob
        for a, b in zip(t2, out2):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    spool.drop("blocker")
    spool.close()


def test_prefetch_after_cancelled_store_skips_ghost_load():
    """Regression: prefetch on a record whose store was cancelled (its
    arrays still resident) used to enqueue a load for a blob that was
    never written — a ghost read that buried the backend error on the
    load job. CANCELED-with-arrays is in-memory: no load."""
    spool, _ = _spool(bandwidth_limit=1e6, store_threads=1)
    spool.offload("a", _tree(1))    # occupies the single store thread
    t = _tree(2)
    spool.offload("b", t)           # queued
    spool.fetch("b")                # forwards + cancels the write
    assert spool.stats.stores_canceled == 1
    spool.prefetch("b")             # must NOT enqueue a load
    assert spool._records["b"]["load_job"] is None
    out = spool.fetch("b")          # forwards the resident arrays
    for a, b in zip(t, out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    spool.wait_io()
    assert spool.stats.num_loads == 0
    spool.close()


def test_refetch_after_cancel_forwards_resident_arrays():
    spool, _ = _spool(bandwidth_limit=1e6, store_threads=1)
    spool.offload("a", _tree(1))        # occupies the single store thread
    t = _tree(2)
    spool.offload("b", t)               # queued
    spool.fetch("b")                    # forwards + cancels the write
    assert spool.stats.stores_canceled == 1
    out = spool.fetch("b")              # must forward again, not raise
    for a, b in zip(t, out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    spool.wait_io()
    spool.close()


def test_close_joins_workers_and_is_idempotent():
    spool, _ = _spool()
    spool.offload("k", _tree())
    threads = list(spool._threads)
    assert threads
    spool.close()
    assert all(not t.is_alive() for t in threads)
    spool.close()                   # second close: no-op
    with pytest.raises(RuntimeError):
        spool.step("late")          # no leases on a closed spool


def test_bandwidth_limit_enforced():
    spool, _ = _spool(bandwidth_limit=2e6)
    t = [jnp.ones((512, 512), jnp.float32)]   # 1 MB
    t0 = time.perf_counter()
    spool.offload("k", t)
    spool.wait_io()
    dt = time.perf_counter() - t0
    assert dt >= 0.4, dt            # >= nbytes / bw
    spool.close()


# ------------------------------------------- data-plane stat regressions


def test_write_bandwidth_zero_before_first_store():
    """Regression: SpoolStats.write_bandwidth returned inf before any
    store completed, and dryrun/roofline reports printed infinite
    bandwidth."""
    spool, _ = _spool()
    assert spool.stats.write_bandwidth == 0.0
    spool.offload("k", _tree())
    spool.wait_io()
    assert 0.0 < spool.stats.write_bandwidth < float("inf")
    spool.close()


class _FailingWriteBackend:
    """Minimal backend whose writes always fail (ENOSPC-style)."""

    def __init__(self):
        from repro.io import HostMemoryBackend
        self._inner = HostMemoryBackend()
        self.stats = self._inner.stats
        self.kind = "failing"

    def write_parts(self, key, parts):
        raise OSError(28, "No space left on device")

    write = write_parts

    def __getattr__(self, name):
        return getattr(self._inner, name)


def test_failed_store_forwarding_counted_once():
    """Regression: the failed-store forwarding branch ignored the
    fwd_counted flag, so a peek-then-fetch of a failed store inflated
    bytes_forwarded."""
    spool = ActivationSpool(_FailingWriteBackend(),
                            min_offload_elements=16)
    tree = _tree()
    nbytes = sum(np.asarray(x).nbytes for x in tree)
    spool.offload("k", tree)
    spool.wait_io()                      # store fails, arrays retained
    out1 = spool.fetch("k", cancel_pending=False)     # peek
    out2 = spool.fetch("k")                           # fetch
    for a, b in zip(out1, out2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert spool.stats.bytes_forwarded == nbytes, \
        "peek-then-fetch of a failed store must count ONE forwarding"
    spool.drop("k")
    spool.close()


def test_pooled_load_lease_reused_across_steps():
    """Steady state of the pooled load path: the same aligned buffer
    serves successive loads (hit rate climbs), and dropped records
    release their leases back to the pool."""
    spool, _ = _spool()
    for step in range(4):
        spool.offload(f"s{step}", _tree(seed=step))
        spool.wait_io()
        out = spool.fetch(f"s{step}")
        assert len(out) == 3
        spool.drop(f"s{step}")
    stats = spool.pool.stats()
    assert stats["hits"] >= 2, stats     # buffers really got reused
    assert spool.pool.free_bytes > 0     # leases returned after drop
    spool.close()


def test_data_plane_stats_shape():
    spool, _ = _spool()
    spool.offload("k", _tree())
    spool.wait_io()
    spool.fetch("k")
    spool.drop("k")
    dp = spool.data_plane_stats()
    assert set(dp) == {"backend", "pool"}
    assert dp["backend"]["copies_per_byte"] == 0.0   # vectored fs path
    assert 0.0 <= dp["pool"]["hit_rate"] <= 1.0
    spool.close()


def test_decoding_codec_releases_lease_before_drop():
    """zlib/byteplane decodes own fresh memory, so the pooled read
    buffer must go back to the pool at load time, not sit pinned on the
    record until drop()."""
    spool, _ = _spool(codec="zlib")
    spool.offload("k", _tree())
    spool.wait_io()
    spool.prefetch("k")
    spool.wait_io()                       # load done, record not dropped
    assert spool.pool.free_bytes > 0, \
        "lease should be recycled as soon as the decode detaches"
    out = spool.fetch("k")
    assert len(out) == 3
    spool.drop("k")
    spool.close()
