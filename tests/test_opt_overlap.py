"""Eager per-layer optimizer updates overlapped with backward.

The contract under test (repro.optim.overlap + the session/CLI
``opt_overlap`` knob): streaming the optimizer update under backward —
per-layer moment leases on the spool, updates on a side worker — must
change NOTHING about the training math. Losses, final params, and the
full final optimizer state are bitwise-identical to the serial fused
path, in every mode (eager worker / "sync" drain), for every optimizer
with a per-leaf kernel (adamw, sgd, sgd+momentum), on a single device
and on a forced-host-device mesh (subprocess, per the dry-run
contract). The staged engine updates per stage already and must reject
the knob rather than half-support it.

Also covered: the resilience ladder (an armed opt-moment read failure
mid-backward is absorbed by the spool's load retries and the run still
matches the clean one bit-for-bit), the write-back skip policy (a
fully label-masked batch has zero grads, so unchanged moments keep
their lease instead of rewriting the backend), and the obs lane (opt
I/O lands in opt_io_busy_s/opt_hidden_frac with engine.opt_update
spans in the trace, not in the activation metrics).
"""
import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.configs.base import SpoolIoConfig
from repro.configs.paper_models import small_gpt
from repro.optim.optimizers import adamw, sgd
from repro.session import TrainSession

CFG = dataclasses.replace(small_gpt(128, 2), dtype="float32")
STEPS = 3
N_STAGES = 2            # small_gpt(_, 2): two scanned decoder layers


def _run(mode, *, optimizer=None, backend="mem", trace=None,
         loader=None, arm_reads=0):
    """One jit-engine session; mode is "serial" (fused update +
    host_offload staging), "sync", or True (eager worker)."""
    io = SpoolIoConfig(
        backend=backend,
        host_offload="opt_state" if mode == "serial" else "none")
    sess = TrainSession(
        CFG, engine="jit", io=io,
        optimizer=(optimizer if optimizer is not None
                   else adamw(1e-3, clip_norm=None)),
        opt_overlap=None if mode == "serial" else mode,
        lr=1e-3, batch_size=2, seq_len=32, seed=3, ckpt_every=0,
        min_offload_elements=2 ** 8, trace=trace, loader=loader)
    try:
        if arm_reads:
            from repro.io import FaultInjectingBackend
            from repro.resilience import unwrap_chain
            for b in unwrap_chain(sess.spool.backend):
                if isinstance(b, FaultInjectingBackend):
                    b.arm_read_failures(arm_reads, key_substr="opt")
        res = sess.run(STEPS)
        bridge = sess._opt_bridge
        opt = (bridge.materialize()
               if bridge is not None and bridge.seeded
               else sess.state.opt_state)
        moments = lambda t: (None if t is None else
                             [np.asarray(x).tobytes()
                              for x in jax.tree.leaves(t)])
        return {
            "losses": [float(l) for l in res.losses],
            "params": [np.asarray(x).tobytes()
                       for x in jax.tree.leaves(sess.state.params)],
            "mu": moments(opt.mu),
            "nu": moments(opt.nu),
            "opt_step": int(opt.step),
            "bridge": bridge.stats() if bridge is not None else None,
            "load_retries": (sess.spool.stats.load_retries
                             if sess.spool is not None else 0),
            "opt_skipped_bytes": (sess.spool.stats.opt_skipped_bytes
                                  if sess.spool is not None else 0),
            "obs": [r.obs for r in res.reports],
        }
    finally:
        sess.close()


def _assert_bitwise(a, b):
    assert a["losses"] == b["losses"], (a["losses"], b["losses"])
    assert a["params"] == b["params"]
    assert a["mu"] == b["mu"]
    assert a["nu"] == b["nu"]
    assert a["opt_step"] == b["opt_step"]


@pytest.fixture(scope="module")
def serial_run():
    return _run("serial")


@pytest.fixture(scope="module")
def eager_run(tmp_path_factory):
    trace = str(tmp_path_factory.mktemp("optov") / "trace.json")
    out = _run(True, trace=trace)
    out["trace"] = trace
    return out


# ------------------------------------------------------------- parity

def test_eager_matches_serial_bitwise(serial_run, eager_run):
    """The tentpole bar: per-step losses, final params, and the full
    final optimizer state are bit-for-bit the serial path's."""
    _assert_bitwise(serial_run, eager_run)
    assert eager_run["bridge"]["opt_updates"] == STEPS * N_STAGES
    assert eager_run["bridge"]["opt_fetched_bytes"] > 0
    assert eager_run["bridge"]["opt_staged_bytes"] > 0


def test_sync_mode_matches_serial_bitwise(serial_run):
    """"sync" drains the same taps/kernels at the join barrier — the
    serial schedule of the identical per-layer pipeline."""
    _assert_bitwise(serial_run, _run("sync"))


@pytest.mark.parametrize("make_opt", [
    pytest.param(lambda: sgd(1e-3, momentum=0.9), id="sgd-momentum"),
    pytest.param(lambda: sgd(1e-3), id="sgd-plain"),
])
def test_sgd_parity(make_opt):
    """Momentum streams a single-moment payload; plain sgd has no
    moment leases at all (the bridge only reorders the update)."""
    serial = _run("serial", optimizer=make_opt())
    eager = _run(True, optimizer=make_opt())
    _assert_bitwise(serial, eager)
    assert eager["bridge"]["opt_updates"] == STEPS * N_STAGES


# ------------------------------------------------- resilience ladder

def test_opt_fetch_failure_rides_retry_ladder(serial_run):
    """An opt-moment read that fails mid-backward is retried by the
    spool's load workers (retry_attempts=3 default); the run completes
    and still matches the clean serial run bit-for-bit."""
    faulted = _run(True, backend="fault:mem", arm_reads=2)
    _assert_bitwise(serial_run, faulted)
    assert faulted["load_retries"] >= 1, faulted["load_retries"]


# ------------------------------------------------- write-back policy

class _MaskedLoader:
    """Every label masked (-1): the loss is 0 over 0 tokens, grads are
    exactly zero, and adamw moments stay at their seeded zeros."""

    def __init__(self, batch, seq):
        self._batch = {
            "tokens": np.ones((batch, seq), np.int32),
            "labels": np.full((batch, seq), -1, np.int32)}

    def __iter__(self):
        return self

    def __next__(self):
        return dict(self._batch)

    def state_dict(self):
        return {}

    def load_state_dict(self, state):
        pass


def test_unchanged_moments_skip_writeback():
    out = _run(True, loader=_MaskedLoader(2, 32))
    assert out["losses"] == [0.0] * STEPS
    assert out["bridge"]["opt_stage_skips"] == STEPS * N_STAGES
    assert out["bridge"]["opt_skipped_bytes"] > 0
    assert out["opt_skipped_bytes"] == out["bridge"]["opt_skipped_bytes"]
    # nothing was re-staged after seeding: every lease was kept
    assert out["bridge"]["opt_staged_bytes"] == 0


# ------------------------------------------------------ obs lane

def test_obs_attributes_opt_lane(eager_run):
    """Per-step rows carry the opt lane, and the trace has the worker
    and update spans the analyzer classifies on."""
    rows = [r for r in eager_run["obs"][1:] if r]   # skip compile step
    assert rows and any(r["opt_io_busy_s"] > 0 for r in rows)
    assert all(0.0 <= r["opt_hidden_frac"] <= 1.0 for r in rows)
    names = {e["name"] for e in
             json.load(open(eager_run["trace"]))["traceEvents"]
             if e.get("ph") == "X"}
    for want in ("engine.opt_update", "engine.opt_join", "opt.fetch",
                 "opt.stage"):
        assert want in names, (want, sorted(names))


# ------------------------------------------------------------- gates

def test_staged_engine_rejects_overlap():
    with pytest.raises(ValueError, match="jit-engine"):
        TrainSession(CFG, engine="staged", opt_overlap=True,
                     io=SpoolIoConfig(backend="mem"))


def test_clipped_optimizer_rejected():
    with pytest.raises(ValueError, match="clip"):
        TrainSession(CFG, engine="jit", opt_overlap=True,
                     io=SpoolIoConfig(backend="mem"),
                     optimizer=adamw(1e-3, clip_norm=1.0),
                     batch_size=2, seq_len=32)


# ------------------------------------------------------- mesh parity

SCRIPT_MESH = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import numpy as np
import jax

from repro.configs.base import SpoolIoConfig
from repro.configs.paper_models import small_gpt
from repro.launch.mesh import make_test_mesh
from repro.optim.optimizers import adamw
from repro.session import TrainSession

cfg = dataclasses.replace(small_gpt(128, 2), dtype="float32")

def run(mode):
    io = SpoolIoConfig(
        backend="mem",
        host_offload="opt_state" if mode == "serial" else "none")
    sess = TrainSession(cfg, engine="jit", io=io,
                        optimizer=adamw(1e-3, clip_norm=None),
                        opt_overlap=None if mode == "serial" else mode,
                        lr=1e-3, batch_size=8, seq_len=64, seed=3,
                        ckpt_every=0, min_offload_elements=2 ** 10,
                        mesh=make_test_mesh((2, 4), ("data", "model")))
    res = sess.run(2)
    bridge = sess._opt_bridge
    opt = (bridge.materialize() if bridge is not None and bridge.seeded
           else sess.state.opt_state)
    out = ([float(l) for l in res.losses],
           [np.asarray(x).tobytes()
            for x in jax.tree.leaves(sess.state.params)],
           [np.asarray(x).tobytes()
            for x in jax.tree.leaves((opt.mu, opt.nu))])
    sess.close()
    return out

serial = run("serial")
eager = run(True)
assert serial[0] == eager[0], ("losses", serial[0], eager[0])
assert serial[1] == eager[1], "params diverged"
assert serial[2] == eager[2], "moments diverged"
print("ALL_OK_OPT_MESH")
"""


def test_mesh_parity_subprocess():
    """DP x TP mesh (8 forced host devices in a subprocess, per the
    dry-run contract): eager overlap stays bitwise-identical when the
    grad taps fire per shard."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT_MESH],
                       env=env, capture_output=True, text=True,
                       timeout=1200)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "ALL_OK_OPT_MESH" in r.stdout, (r.stdout, r.stderr[-2000:])
