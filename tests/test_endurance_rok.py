"""Validation of the paper-model layers: Table 4 analytic estimate against
the paper's published numbers, Fig. 9 claims, and ROK curve mechanics."""
import dataclasses

import pytest

from repro.configs.paper_models import bert
from repro.core.endurance import (analytic_bytes_per_token_per_layer,
                                  offloaded_bytes_per_step, project_all)
from repro.core.rok import (RokPoint, dominates, model_flops_per_step,
                            pareto_front)

# paper Table 4 (BERT, batch 16, seq 1024, fp16, TP=2): paper's own model
# estimates in GB
PAPER_TABLE4 = {(8192, 4): 11.13, (12288, 3): 12.6, (16384, 2): 11.5}


@pytest.mark.parametrize("hl,paper_gb", PAPER_TABLE4.items())
def test_table4_estimate_matches_paper(hl, paper_gb):
    h, L = hl
    cfg = dataclasses.replace(bert(h, L), dtype="float16")
    est_gb = offloaded_bytes_per_step(cfg, 16, 1024, tp=2) / 1e9
    # within 10% of the paper's own llm-analysis estimate
    assert abs(est_gb - paper_gb) / paper_gb < 0.10, (est_gb, paper_gb)


def test_fig9_claims():
    rows = project_all()
    assert all(p.lifespan_years > 3 for p in rows)
    assert all(p.pcie_write_gb_s <= 15 for p in rows)
    # weak scaling: the largest Megatron system needs less bandwidth than
    # the smallest
    mega = [p for p in rows if "Megatron" in p.label]
    assert mega[-1].pcie_write_gb_s < mega[0].pcie_write_gb_s


def test_analytic_counts_scale_with_tp():
    cfg = dataclasses.replace(bert(8192, 4), dtype="float16")
    b1 = analytic_bytes_per_token_per_layer(cfg, tp=1)
    b2 = analytic_bytes_per_token_per_layer(cfg, tp=2)
    assert b2 < b1 and b2 > b1 / 2     # sharded parts halve, x/norm don't


def test_rok_pareto_and_dominance():
    keep = RokPoint("keep", 16, 100, 1.0, model_flops_per_step(1e6, 1024))
    off = RokPoint("offload", 16, 60, 1.0,
                   model_flops_per_step(1e6, 1024))
    rec = RokPoint("recompute", 16, 70, 1.4,
                   model_flops_per_step(1e6, 1024))
    assert dominates(off, keep)
    assert dominates(off, rec)
    front = pareto_front([keep, off, rec])
    assert front == [off]


def test_model_flops_independent_of_strategy():
    f = model_flops_per_step(10e6, 2048)
    assert f == 6.0 * 10e6 * 2048
