"""Tests for the jit engine's per-layer activation offloading
(repro.core.hooks): correctness vs the no-offload baseline, tensor
forwarding under an io_callback fetch racing the store, one
AdaptivePolicy profile driving both engines, the staged engine's
backward-prefetch off-by-one regression, and the SPMD bridge
machinery — shard planning, per-shard lease keying under concurrent
host-callback threads, and the replica-countdown consume protocol."""
import dataclasses
import tempfile
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs.base import SpoolIoConfig
from repro.configs.paper_models import small_gpt
from repro.core.hooks import HookBridge, plan_shards, run_splits
from repro.core.policies import (AdaptivePolicy, JitOffloadPlan,
                                 SpoolPolicy, local_shard_fraction)
from repro.core.spool import ActivationSpool, SpoolStepTransaction
from repro.core.staged import StagedTrainer
from repro.io import FilesystemBackend, HostMemoryBackend
from repro.models.transformer import RunSettings
from repro.session import TrainSession

MIN_OFF = 2 ** 8


def _cfg(hidden=128, layers=2):
    return dataclasses.replace(small_gpt(hidden, layers), dtype="float32")


def _session(engine, **kw):
    kw.setdefault("optimizer", "adamw")
    kw.setdefault("lr", 1e-3)
    kw.setdefault("batch_size", 2)
    kw.setdefault("seq_len", 32)
    kw.setdefault("seed", 3)
    kw.setdefault("ckpt_every", 0)
    kw.setdefault("min_offload_elements", MIN_OFF)
    return TrainSession(_cfg(), engine=engine, **kw)


def _keep_settings():
    return RunSettings(attn_impl="xla", attn_chunk=256,
                       activation_policy="keep", param_dtype="float32")


# ------------------------------------------------- jit activations mode

@pytest.fixture(scope="module")
def jit_baseline():
    """No-offload jit run (residuals kept on device): 3 steps."""
    with _session("jit", settings=_keep_settings()) as sess:
        result = sess.run(3)
        return {"losses": result.losses, "params": result.state.params}


@pytest.fixture(scope="module")
def hooked_baseline():
    """SAME-COMPILE bitwise reference for the activation-offload path.

    The hooked step is a different XLA program than the keep-settings
    one (the io_callbacks change fusion decisions in the backward), so
    comparing hooked losses against `jit_baseline` bitwise is comparing
    two compiles — after the first optimizer update the params carry
    ~1-ulp fusion noise and step>=1 losses legitimately differ in the
    last bit (the old flaky assertion). The invariant offloading must
    actually guarantee is *placement transparency*: the same compiled
    program must produce bitwise-identical results no matter which
    backend holds the residuals or how stores race fetches. This mem-
    backend hooked run is the reference for that comparison."""
    with _session("jit", io=SpoolIoConfig(
            backend="mem", host_offload="activations")) as sess:
        result = sess.run(3)
        return {"losses": result.losses, "params": result.state.params}


def test_jit_activations_matches_no_offload_baseline(jit_baseline,
                                                     hooked_baseline):
    """host_offload="activations" must be math-transparent: bitwise
    equal to the same-compile hooked reference across backends
    (placement transparency), equal to the no-offload jit baseline up
    to cross-compile fusion noise, and real residual bytes must land on
    the backend."""
    with _session("jit", io=SpoolIoConfig(
            backend="fs", host_offload="activations")) as sess:
        result = sess.run(3)
        stats = dataclasses.replace(sess.spool.stats)
        io_writes = sess.spool.backend.stats.num_writes
        leftover = dict(sess.spool._records)
    # same compiled program, different residual placement: bitwise
    assert result.losses == hooked_baseline["losses"]
    for a, b in zip(jax.tree.leaves(hooked_baseline["params"]),
                    jax.tree.leaves(result.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # vs the keep-settings compile: NOT asserted bitwise — a different
    # XLA program fuses the backward differently, so updated params
    # (and every loss computed from them) may differ in the last ulp.
    # The tolerance covers that compile noise, nothing more.
    np.testing.assert_allclose(result.losses, jit_baseline["losses"],
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(jit_baseline["params"]),
                    jax.tree.leaves(result.state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    # per-segment residuals really landed on the configured backend
    assert stats.bytes_offloaded > 0
    assert io_writes > 0
    assert stats.num_stores > 0
    # every step lease was consumed: no records strand on the spool
    assert not leftover


def test_jit_vs_staged_parity_with_activations():
    """Same arch/seed through one front door: the staged (TBA) engine
    and the jit engine with per-layer activation offloading train to
    matching losses."""
    with _session("staged") as sess:
        staged = sess.run(3).losses
    with _session("jit", io=SpoolIoConfig(
            backend="mem", host_offload="activations")) as sess:
        hooked = sess.run(3).losses
    assert np.all(np.isfinite(staged)) and np.all(np.isfinite(hooked))
    np.testing.assert_allclose(staged, hooked, rtol=5e-3)


def test_forwarding_under_fetch_racing_store(hooked_baseline):
    """A backward io_callback fetch that catches the store still queued
    or in flight must forward the in-memory reference (§3.3.2) — and
    the math stays exact either way: bitwise against the same-compile
    hooked reference (see `hooked_baseline` for why not the keep one)."""
    with _session("jit", io=SpoolIoConfig(
            backend="fs", store_threads=1, bandwidth_limit=2e6,
            host_offload="activations")) as sess:
        result = sess.run(2)
        stats = dataclasses.replace(sess.spool.stats)
    assert stats.bytes_forwarded > 0
    assert result.losses == hooked_baseline["losses"][:2]  # bitwise


def test_activations_mode_cli_flag_roundtrip():
    io = SpoolIoConfig(backend="mem", host_offload="activations")
    assert io.validate() is io
    with pytest.raises(AssertionError):
        SpoolIoConfig(host_offload="everything").validate()


def test_activations_with_non_spool_settings_rejected():
    """host_offload="activations" + explicit settings that never engage
    the hooks must raise, not silently train with zero offload."""
    with pytest.raises(ValueError, match="activation_policy"):
        TrainSession(_cfg(), engine="jit", settings=_keep_settings(),
                     io=SpoolIoConfig(backend="mem",
                                      host_offload="activations"))


def test_encdec_spools_encoder_and_decoder_residuals():
    """Cross-attention segments close over the encoder states; the
    hooks must thread them as an explicit custom_vjp input (carry) or
    trace-time differentiation fails — and both streams' residuals
    should hit the backend."""
    from repro.configs.paper_models import small_t5
    cfg = dataclasses.replace(small_t5(), dtype="float32")
    rng = np.random.default_rng(0)

    def batches():
        return [{"tokens": rng.integers(0, 100, (2, 16)),
                 "enc_tokens": rng.integers(0, 100, (2, 16)),
                 "labels": rng.integers(0, 100, (2, 16))}
                for _ in range(2)]

    with TrainSession(cfg, engine="jit", seed=0, ckpt_every=0,
                      loader=batches(), min_offload_elements=2 ** 6,
                      io=SpoolIoConfig(backend="mem",
                                       host_offload="activations")) as s:
        hooked = s.run(2)
        stats = dataclasses.replace(s.spool.stats)
    assert np.all(np.isfinite(hooked.losses))
    assert stats.num_stores > 0
    rng = np.random.default_rng(0)       # same batch stream
    with TrainSession(cfg, engine="jit", seed=0, ckpt_every=0,
                      loader=batches(),
                      settings=RunSettings(
                          attn_impl="xla", attn_chunk=256,
                          activation_policy="keep",
                          param_dtype="float32")) as s:
        base = s.run(2)
    np.testing.assert_allclose(hooked.losses, base.losses, rtol=1e-5)


# --------------------------------------- one policy, both engines

def test_adaptive_plan_drives_both_engines():
    """Profile once on the staged engine, then translate the same plan
    into jit RunSettings via plan_for_jit()."""
    pol = AdaptivePolicy()
    with pytest.raises(RuntimeError):
        pol.plan_for_jit()          # no profile digested yet
    with _session("staged", policy=pol) as sess:
        staged_losses = sess.run(2).losses
    assert pol.plan is not None
    jplan = pol.plan_for_jit()
    assert isinstance(jplan, JitOffloadPlan)
    assert len(jplan.spool_stages) == 2          # one entry per layer
    assert jplan.write_bw == pol.plan.write_bw

    settings = jplan.apply(_keep_settings())
    if jplan.activation_policy == "spool":
        assert settings.spool_stages == jplan.spool_stages
        with _session("jit", settings=settings, io=SpoolIoConfig(
                backend="mem", host_offload="activations")) as sess:
            jit_losses = sess.run(2).losses
            assert sess.spool.stats.num_stores > 0
    else:                            # plan kept everything on device
        assert settings.spool_stages is None
        with _session("jit", settings=settings) as sess:
            jit_losses = sess.run(2).losses
    assert np.all(np.isfinite(staged_losses))
    np.testing.assert_allclose(staged_losses, jit_losses, rtol=5e-3)


def test_run_splits_groups_contiguous_choices():
    assert run_splits([True, True, False, True]) == [
        (0, 2, True), (2, 3, False), (3, 4, True)]
    assert run_splits([False, False]) == [(0, 2, False)]
    assert run_splits([]) == []


def test_partial_spool_stages_mask():
    """A mixed keep/offload plan splits the scanned stack but must not
    change the math."""
    settings = dataclasses.replace(
        _keep_settings(), activation_policy="spool",
        spool_stages=(True, False))
    with _session("jit", settings=settings, io=SpoolIoConfig(
            backend="mem", host_offload="activations")) as sess:
        masked = sess.run(2)
        stats = dataclasses.replace(sess.spool.stats)
    with _session("jit", settings=_keep_settings()) as sess:
        base = sess.run(2)
    assert masked.losses == base.losses            # bitwise
    assert stats.num_stores > 0                    # layer 0 still spools


# ----------------------------------------- SPMD bridge machinery

def _mesh_or_skip(shape, names):
    if jax.device_count() < int(np.prod(shape)):
        pytest.skip("needs forced host devices")
    from repro.launch.mesh import make_test_mesh
    return make_test_mesh(shape, names)


def test_plan_shards_specs_and_replica_factorization():
    """Leaf spec choice: leading dim over dp when divisible, innermost
    other divisible dim over tp; axes sharding nothing become replica
    axes (their devices hold identical bytes)."""
    from jax.sharding import PartitionSpec as P
    mesh = _mesh_or_skip((1,), ("data",))

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 2, "model": 4}

    sds = [jax.ShapeDtypeStruct((8, 32, 128), np.float32),  # dp + tp
           jax.ShapeDtypeStruct((8, 32, 3), np.float32),    # tp on seq
           jax.ShapeDtypeStruct((8, 3, 3), np.float32),     # dp only
           jax.ShapeDtypeStruct((), np.float32)]            # scalar
    plan = plan_shards(FakeMesh(), ("data",), "model", sds)
    assert plan.specs[0] == P("data", None, "model")
    assert plan.specs[1] == P("data", "model", None)   # innermost
    assert plan.specs[2] == P("data", None, None)      # divisible dim
    assert plan.specs[3] == P()
    assert plan.writer_axes == ("data", "model")
    assert plan.replica_axes == ()
    assert plan.n_shards == 8 and plan.n_replicas == 1
    local = plan.local_sds(sds)
    assert local[0].shape == (4, 32, 32)
    assert local[1].shape == (4, 8, 3)
    assert local[2].shape == (4, 3, 3)

    # batch indivisible by dp, no tp -> nothing shards, whole mesh is
    # one replica group
    plan2 = plan_shards(FakeMesh(), ("data",), None,
                        [jax.ShapeDtypeStruct((3, 5), np.float32)])
    assert plan2.writer_axes == ()
    assert plan2.replica_axes == ("data", "model")
    assert plan2.n_shards == 1 and plan2.n_replicas == 8


def test_local_shard_fraction_and_scaled_jit_plan():
    """plan_for_jit(shard_fraction=...) re-plans against the LOCAL
    per-shard byte volume: a smaller fraction can only offload more
    layers, and the planned required_bw scales with the bytes."""
    from repro.core.adaptive import ModuleProfile

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 2}

    assert local_shard_fraction(None) == 1.0
    assert local_shard_fraction(FakeMesh(), ("data",)) == 0.25

    pol = AdaptivePolicy()
    profiles = [ModuleProfile(f"seg0_l{i}", 100 << 20, 0.01)
                for i in range(6)]
    pol.on_profile(profiles, 2.0e9)      # tight scalar bandwidth
    full = pol.plan_for_jit()
    quarter = pol.plan_for_jit(shard_fraction=0.25)
    assert len(quarter.spool_stages) == len(full.spool_stages) == 6
    assert sum(quarter.spool_stages) >= sum(full.spool_stages)
    assert quarter.shard_fraction == 0.25
    assert quarter.required_bw < full.required_bw or \
        sum(quarter.spool_stages) > sum(full.spool_stages)
    with pytest.raises(ValueError):
        pol.plan_for_jit(shard_fraction=0.0)


def test_bridge_replica_countdown_consume():
    """Satellite fix: a stage fetched once per replica shard is dropped
    by the LAST fetch only — earlier fetches peek (non-consuming), and
    the lease closes once every stage of that shard is consumed."""
    spool = ActivationSpool(HostMemoryBackend(), min_offload_elements=4,
                            store_threads=1, load_threads=1)
    bridge = HookBridge(spool, fetch_timeout=5.0)
    rng = np.random.default_rng(0)
    arrays = [rng.normal(size=(256,)).astype(np.float32)]
    bridge.sharded_offload(0, 0, arrays, shard=0, replica=0,
                           n_replicas=3)
    bridge.sharded_offload(0, 0, arrays, shard=0, replica=1,
                           n_replicas=3)     # dedupe: skipped
    spool.wait_io()
    for rep in range(3):
        out = bridge.sharded_fetch(0, 0, shard=0, replica=rep,
                                   n_replicas=3)
        np.testing.assert_array_equal(out[0], arrays[0])
        live = bridge._txs.get("jit0/s0")
        if rep < 2:
            assert live is not None and live.has_stage(0)
        else:
            assert live is None          # last consumer closed the lease
    assert not spool._records
    stats = bridge.stats_by_shard()[0]
    assert stats["offloads"] == 1 and stats["replica_skips"] == 1
    assert stats["fetches"] == 3
    # a 4th fetch of the consumed stage is an error, not a hang
    bridge.fetch_timeout = 0.2
    with pytest.raises(KeyError):
        bridge.sharded_fetch(0, 0, shard=0, replica=0, n_replicas=3)
    spool.close()


def test_bridge_dedupe_disabled_stores_per_replica():
    spool = ActivationSpool(HostMemoryBackend(), min_offload_elements=4,
                            store_threads=1, load_threads=1)
    bridge = HookBridge(spool, dedupe_replicas=False, fetch_timeout=5.0)
    rng = np.random.default_rng(1)
    for rep in range(2):
        bridge.sharded_offload(0, 0, [rng.normal(size=(64,))
                                      .astype(np.float32)],
                               shard=1, replica=rep, n_replicas=2)
    spool.wait_io()
    assert spool.stats.num_stores == 2   # one blob per replica
    for rep in range(2):
        bridge.sharded_fetch(0, 0, shard=1, replica=rep, n_replicas=2)
    assert not bridge._txs and not spool._records
    spool.close()


def test_bridge_fetch_waits_for_late_offload():
    """On a mesh the fetch and store callbacks arrive on different
    threads; a fetch that beats its store must WAIT, not fail."""
    spool = ActivationSpool(HostMemoryBackend(), min_offload_elements=4,
                            store_threads=1, load_threads=1)
    bridge = HookBridge(spool, fetch_timeout=10.0)
    arr = np.arange(64, dtype=np.float32)

    def late_offload():
        time.sleep(0.3)
        bridge.offload(7, 0, [arr], shard=2)

    t = threading.Thread(target=late_offload)
    t.start()
    out = bridge.fetch(7, 0, shard=2)    # arrives first, waits
    t.join()
    np.testing.assert_array_equal(out[0], arr)
    assert not bridge._txs
    spool.close()


def test_hook_bridge_concurrent_shard_stress():
    """Satellite: hammer offload/fetch from N threads emulating XLA
    host-callback workers across interleaved steps. No cross-step key
    leaks, and SpoolStats counters sum EXACTLY: every record's bytes
    are either forwarded (store still in flight / cancelled) or loaded
    back — never both, never neither."""
    N_SHARDS, N_STEPS, N_STAGES = 4, 3, 4
    spool = ActivationSpool(HostMemoryBackend(), min_offload_elements=4,
                            store_threads=2, load_threads=2)
    bridge = HookBridge(spool, fetch_timeout=30.0)
    rng = np.random.default_rng(2)
    # unique payloads (no dedup aliasing) sized well over the threshold
    data = {(s, st, sh): rng.normal(size=(512,)).astype(np.float32)
            for s in range(N_STEPS) for st in range(N_STAGES)
            for sh in range(N_SHARDS)}
    errors = []

    def device_thread(shard):
        try:
            for step in range(N_STEPS):
                for stage in range(N_STAGES):
                    bridge.offload(step, stage,
                                   [data[(step, stage, shard)]],
                                   shard=shard)
                for stage in reversed(range(N_STAGES)):
                    out = bridge.fetch(step, stage, shard=shard)
                    np.testing.assert_array_equal(
                        out[0], data[(step, stage, shard)])
        except BaseException as e:       # pragma: no cover - fails test
            errors.append(e)

    threads = [threading.Thread(target=device_thread, args=(sh,))
               for sh in range(N_SHARDS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    spool.wait_io()
    assert not errors, errors
    # no cross-step leaks: every lease closed, no records, no step ids
    assert not bridge._txs
    assert not spool._records
    assert not spool._active_steps
    # exact accounting
    total = N_SHARDS * N_STEPS * N_STAGES
    total_bytes = sum(a.nbytes for a in data.values())
    by_shard = bridge.stats_by_shard()
    assert sorted(by_shard) == list(range(N_SHARDS))
    assert sum(v["offloads"] for v in by_shard.values()) == total
    assert sum(v["fetches"] for v in by_shard.values()) == total
    assert sum(v["bytes_in"] for v in by_shard.values()) == total_bytes
    assert sum(v["bytes_out"] for v in by_shard.values()) == total_bytes
    st = spool.stats
    per_rec = data[(0, 0, 0)].nbytes     # uniform record size
    # every offload enqueued exactly one store job; each completed or
    # was cancelled by a forwarding fetch
    assert st.num_stores + st.stores_canceled == total
    # every fetch either forwarded the in-flight arrays or reloaded the
    # blob — exactly once per record, partitioning the byte volume
    assert st.bytes_forwarded % per_rec == 0
    n_fwd = st.bytes_forwarded // per_rec
    assert st.num_loads == total - n_fwd
    # completed stores wrote exactly their logical bytes (+ the serde
    # container, identical per record); loads read the same blobs back
    assert st.bytes_offloaded_logical == st.num_stores * per_rec
    if st.num_stores:
        encoded_per_rec = st.bytes_offloaded // st.num_stores
        assert st.bytes_offloaded == st.num_stores * encoded_per_rec
        assert st.bytes_loaded == st.num_loads * encoded_per_rec
    spool.close()


# ------------------------------------- staged backward-prefetch fix

class _SlowReadBackend(FilesystemBackend):
    """Filesystem backend whose reads take `delay` seconds — makes the
    cost of a cold (non-prefetched) load deterministic."""

    def __init__(self, directory, delay):
        super().__init__(directory)
        self.delay = delay

    def read(self, key):
        time.sleep(self.delay)
        return super().read(key)

    def readinto(self, key, buf):
        # the pooled data plane loads through readinto, not read
        time.sleep(self.delay)
        return super().readinto(key, buf)


def _staged_wait(delay, monkeypatch, *, simulate_bug):
    from repro.models.api import build_model
    from repro.optim.optimizers import sgd

    if simulate_bug:
        orig = SpoolStepTransaction.prefetch

        def skip_stage0(self, stage):
            if stage == 0:
                return              # the old `si - 1 > 0` behavior
            orig(self, stage)

        monkeypatch.setattr(SpoolStepTransaction, "prefetch", skip_stage0)
    api = build_model(_cfg(128, 2))
    settings = RunSettings(attn_impl="xla", attn_chunk=32,
                           param_dtype="float32")
    backend = _SlowReadBackend(tempfile.mkdtemp(prefix="slow_spool_"),
                               delay)
    # threshold low enough that the embed stage's residuals (the token
    # indices) spool too — stage 0 is the stage the off-by-one skipped
    tr = StagedTrainer(api, settings, sgd(1e-2), policy=SpoolPolicy(),
                       backend=backend, min_offload_elements=16)
    try:
        params = api.init(jax.random.key(0))
        opt_state = tr.optimizer.init(params)
        rng = np.random.default_rng(0)
        batch = {"tokens": rng.integers(0, 100, (2, 32)),
                 "labels": rng.integers(0, 100, (2, 32))}
        _, _, rep = tr.train_step(params, opt_state, [batch])
        assert np.isfinite(rep.loss)
        # bytes_forwarded > 0 means a fetch was served from a store
        # still in flight — the cold read this helper exists to time
        # never happened, so the caller must discard the measurement
        return (tr.spool.stats.fetch_wait_time,
                tr.spool.stats.bytes_forwarded)
    finally:
        monkeypatch.undo()
        tr.close()


def test_backward_prefetch_covers_stage0(monkeypatch):
    """Regression for the `si - 1 > 0` off-by-one: stage 0 (embed) must
    be prefetched one module ahead like every other stage, so its fetch
    no longer pays a cold blocking load — fetch_wait_time drops by about
    one full read delay vs the buggy behavior."""
    prefetched = []
    orig = SpoolStepTransaction.prefetch

    def spy(self, stage):
        prefetched.append(stage)
        orig(self, stage)

    monkeypatch.setattr(SpoolStepTransaction, "prefetch", spy)
    delay = 0.2
    fixed_wait, _ = _staged_wait(delay, monkeypatch, simulate_bug=False)
    assert 0 in prefetched          # embed stage now prefetched
    # The timing comparison is only meaningful when the buggy run
    # actually pays the cold read: if the backward reaches stage 0
    # while its store is still in flight, fetch forwards the arrays
    # from memory (bytes_forwarded > 0) and no cold load happens at
    # all. That race is load-dependent, so retry until a run pays it.
    for _ in range(3):
        buggy_wait, buggy_fwd = _staged_wait(delay, monkeypatch,
                                             simulate_bug=True)
        if buggy_fwd == 0:
            break
    else:
        pytest.skip("stage-0 store raced every attempt: the cold-read "
                    "path cannot be exercised under this load")
    # the buggy path pays one extra cold load on the critical path
    assert buggy_wait - fixed_wait > 0.5 * delay, (buggy_wait, fixed_wait)
