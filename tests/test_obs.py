"""Tests for the repro.obs trace/telemetry subsystem: ring-buffer
exactness (drop counting, incremental cursors), span balance and
per-ring ordering under the HookBridge concurrency stress, the
synthetic-event overlap analyzer, exporter lane duplication, trace
schema validation on garbage input, and a traced end-to-end jit
session (valid Perfetto JSON + per-step obs_* metrics deltas)."""
import dataclasses
import json
import os
import threading

import numpy as np
import pytest

from repro import obs
from repro.configs.base import SpoolIoConfig
from repro.configs.paper_models import small_gpt
from repro.core.hooks import HookBridge
from repro.core.spool import ActivationSpool
from repro.io import HostMemoryBackend
from repro.obs import export as obs_export
from repro.obs import overlap as obs_overlap
from repro.obs import tracer as obs_tracer
from repro.obs.tracer import Tracer
from repro.session import TrainSession

MS = 1_000_000          # ns per millisecond, for synthetic events


class _tracer_installed:
    """Install a fresh Tracer as the module tracer for one test, so the
    always-compiled-in call sites record into it; restores whatever was
    there before (normally None) on exit."""

    def __init__(self, ring_size: int = obs_tracer.DEFAULT_RING_SIZE):
        self.tracer = Tracer(ring_size)

    def __enter__(self) -> Tracer:
        self._prev = obs_tracer._TRACER
        obs_tracer._TRACER = self.tracer
        return self.tracer

    def __exit__(self, *exc) -> None:
        obs_tracer._TRACER = self._prev


# ------------------------------------------------------------ ring core

def test_ring_drop_counter_exact():
    """A full ring overwrites oldest events and counts every overwrite:
    dropped == total - capacity, exactly, and the survivors are exactly
    the newest `capacity` events in record order."""
    tr = Tracer(ring_size=8)
    for i in range(20):
        tr.instant(f"ev{i}")
    (ring,) = tr.rings()
    assert ring.total == 20
    assert ring.dropped == 12
    assert tr.dropped() == 12
    assert tr.total_events() == 20
    names = [ev[0] for ev in ring.snapshot()]
    assert names == [f"ev{i}" for i in range(12, 20)]


def test_ring_not_full_drops_nothing():
    tr = Tracer(ring_size=8)
    for i in range(5):
        tr.instant(f"ev{i}")
    (ring,) = tr.rings()
    assert ring.dropped == 0
    assert [ev[0] for ev in ring.snapshot()] == [f"ev{i}"
                                                 for i in range(5)]


def test_incremental_snapshot_cursor():
    """snapshot_new returns only events past the cursor, and composing
    windows loses nothing (while the ring isn't overflowing)."""
    tr = Tracer(ring_size=64)
    for i in range(3):
        tr.instant(f"a{i}")
    first, cur = tr.snapshot_new()
    assert [ev[0] for ev in first] == ["a0", "a1", "a2"]
    for i in range(2):
        tr.instant(f"b{i}")
    second, cur = tr.snapshot_new(cur)
    assert [ev[0] for ev in second] == ["b0", "b1"]
    third, cur = tr.snapshot_new(cur)
    assert third == []


def test_span_recorded_on_exception():
    """A span that raises still records its complete event — the ring
    never ends up with a dangling begin."""
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("boom", cat="t"):
            raise RuntimeError("x")
    assert tr.open_spans() == 0
    (ev,) = tr.snapshot()
    assert ev[0] == "boom" and ev[3] >= 0


def test_tracer_injectable_clock():
    """Spans and instants read the tracer's injected clock, so tests
    can drive virtual time and assert exact durations regardless of
    machine load (the deflake seam for timing-sensitive asserts)."""
    t = [0]

    def clock():
        t[0] += 5 * MS
        return t[0]

    tr = Tracer(clock=clock)
    with tr.span("a", cat="t"):
        pass
    tr.instant("i", cat="t")
    a, i = tr.snapshot()
    assert a[0] == "a" and a[3] == 5 * MS   # exactly one tick inside
    assert i[0] == "i" and i[3] == -1 and i[2] > a[2]


def test_disabled_fast_path_is_noop():
    assert obs_tracer._TRACER is None or True  # doc: default is None
    prev = obs_tracer._TRACER
    obs_tracer._TRACER = None
    try:
        with obs.span("x", cat="t", key=1) as sp:
            sp.set(bytes=3)
        obs.instant("y")
        obs.count("c")
        obs.gauge("g", 1.0)
    finally:
        obs_tracer._TRACER = prev


# --------------------------------------------- concurrency / integrity

def test_drop_counting_exact_under_threads():
    """N writer threads each push a known number of events into small
    rings; totals and drops must come out exact per ring (each ring is
    appended only by its owner, so no cross-thread races can smear the
    counters)."""
    N_THREADS, N_EVENTS, RING = 6, 500, 64
    tr = Tracer(ring_size=RING)

    def writer(tid):
        for i in range(N_EVENTS):
            tr.instant(f"t{tid}.e{i}", cat="stress")

    threads = [threading.Thread(target=writer, args=(t,))
               for t in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    rings = tr.rings()
    assert len(rings) == N_THREADS
    for ring in rings:
        assert ring.total == N_EVENTS
        assert ring.dropped == N_EVENTS - RING
        assert len(ring.snapshot()) == RING
    assert tr.total_events() == N_THREADS * N_EVENTS
    assert tr.dropped() == N_THREADS * (N_EVENTS - RING)


def test_trace_integrity_under_hook_bridge_stress():
    """Tracing enabled under the HookBridge shard stress (4 device
    threads x 3 steps x 4 stages racing the spool's store/load
    workers): every span must balance (open_spans == 0 after quiesce),
    per-ring record order must be end-time monotonic (spans are pushed
    at exit), and nothing may drop with a default-sized ring."""
    N_SHARDS, N_STEPS, N_STAGES = 4, 3, 4
    rng = np.random.default_rng(7)
    data = {(s, st, sh): rng.normal(size=(64,)).astype(np.float32)
            for s in range(N_STEPS) for st in range(N_STAGES)
            for sh in range(N_SHARDS)}
    errors = []
    with _tracer_installed() as tr:
        spool = ActivationSpool(HostMemoryBackend(),
                                min_offload_elements=4,
                                store_threads=2, load_threads=2)
        bridge = HookBridge(spool, fetch_timeout=30.0)

        def device_thread(shard):
            try:
                for step in range(N_STEPS):
                    for stage in range(N_STAGES):
                        bridge.offload(step, stage,
                                       [data[(step, stage, shard)]],
                                       shard=shard)
                    for stage in reversed(range(N_STAGES)):
                        out = bridge.fetch(step, stage, shard=shard)
                        np.testing.assert_array_equal(
                            out[0], data[(step, stage, shard)])
            except BaseException as e:   # pragma: no cover - fails test
                errors.append(e)

        threads = [threading.Thread(target=device_thread, args=(sh,))
                   for sh in range(N_SHARDS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        spool.wait_io()
        spool.close()
    assert not errors, errors

    # every begin had a matching end, on every thread
    assert tr.open_spans() == 0
    assert tr.dropped() == 0
    assert tr.total_events() > 0
    # record order == push order == span-end order, per ring: the end
    # timestamp (ts + dur, or ts for instants) must never go backwards
    for ring in tr.rings():
        ends = [ts + max(dur, 0) for _, _, ts, dur, _ in ring.snapshot()]
        assert ends == sorted(ends), ring.thread_name
    # the hook layer traced every offload/fetch exactly once
    events = tr.snapshot()
    names = [ev[0] for ev in events]
    total = N_SHARDS * N_STEPS * N_STAGES
    assert names.count("hook.offload") == total
    assert names.count("hook.fetch") == total
    # the bridge prefetches one module ahead on the backward path; a
    # hint only counts as issued when it starts a real backend load
    # (in-flight stores forward instead), and every resolved hint is a
    # hit or a late — never both — so resolutions can't exceed issues
    c = tr.counters()
    assert (c.get("prefetch.hit", 0) + c.get("prefetch.late", 0)
            <= c.get("prefetch.issued", 0))


def test_prefetch_counters_deterministic():
    """Drive the spool's prefetch counters through every outcome with
    barriers so the result is deterministic: a hint against a completed
    store issues a load (issued); fetching after the load lands is a
    hit; a prefetched load that is dropped unconsumed is a ghost."""
    rng = np.random.default_rng(0)
    # distinct payloads per stage, or dedup aliases them to one record
    arrs = {st: [rng.normal(size=(64,)).astype(np.float32)]
            for st in (0, 1)}
    with _tracer_installed() as tr:
        spool = ActivationSpool(HostMemoryBackend(),
                                min_offload_elements=4)
        with spool.step("s0") as tx:
            tx.offload(0, arrs[0])
            tx.offload(1, arrs[1])
            spool.wait_io()          # stores done: hints start real loads
            tx.prefetch(0)
            tx.prefetch(1)
            spool.wait_io()          # loads done: the fetch is a hit
            out = tx.fetch(0)
            np.testing.assert_array_equal(out[0], arrs[0][0])
            # stage 1's prefetched load is never fetched: the lease
            # drop on __exit__ makes it a ghost
        spool.close()
    c = tr.counters()
    assert c.get("prefetch.issued", 0) == 2
    assert c.get("prefetch.hit", 0) == 1
    assert c.get("prefetch.late", 0) == 0
    assert c.get("prefetch.ghost", 0) == 1


# ------------------------------------------------------ overlap analyzer

def _span_ev(name, lo_ms, hi_ms, key=None, cat="t"):
    args = {} if key is None else {"key": key}
    return (name, cat, lo_ms * MS, (hi_ms - lo_ms) * MS, args)


def test_overlap_analyzer_synthetic():
    """Hand-built timeline with known numbers: 20 ms of I/O, 7 ms of
    exposed wait (5 overlapping the same key's disk read, 1 its decode,
    1 queued), so hidden = 1 - 7/20 = 0.65."""
    events = [
        _span_ev("io.read", 0, 10, key="a"),
        _span_ev("spool.fetch_wait", 5, 12, key="a"),
        _span_ev("codec.decode", 10, 11, key="a"),
        _span_ev("io.write", 20, 30, key="b"),
        _span_ev("codec.encode", 18, 20, key="b"),
        ("spool.offload", "spool", 1 * MS, -1, {}),   # instant: ignored
    ]
    res = obs_overlap.analyze(events, {"prefetch.issued": 4,
                                       "prefetch.hit": 3,
                                       "prefetch.late": 1})
    assert res["io_busy_s"] == pytest.approx(0.020)
    assert res["exposed_wait_s"] == pytest.approx(0.007)
    assert res["io_hidden_frac"] == pytest.approx(0.65)
    assert res["stall_read_s"] == pytest.approx(0.005)
    assert res["stall_decode_s"] == pytest.approx(0.001)
    assert res["stall_queue_s"] == pytest.approx(0.001)
    assert res["encode_s"] == pytest.approx(0.002)
    assert res["prefetch_hit_rate"] == pytest.approx(0.75)


def test_overlap_analyzer_interval_union():
    """Overlapping spans of the same kind are unioned, not summed —
    two concurrent 10 ms reads on [0,10) are 10 ms of I/O, not 20."""
    events = [_span_ev("io.read", 0, 10, key="a"),
              _span_ev("io.read", 0, 10, key="b")]
    res = obs_overlap.analyze(events)
    assert res["io_busy_s"] == pytest.approx(0.010)
    assert res["io_hidden_frac"] == 1.0


def test_overlap_analyzer_empty_window():
    res = obs_overlap.analyze([])
    assert res["io_busy_s"] == 0.0
    assert res["io_hidden_frac"] == 1.0   # no I/O, nothing exposed


def test_overlap_analyzer_opt_attribution():
    """Opt-keyed spans (the opt-overlap bridge's moment leases) leave
    the activation metrics and land in the opt lane; only the training
    thread's spans count as exposed — the side worker blocking on its
    own reads is the hidden case — and a thread block is charged to the
    I/O hidden fraction only where it intersects opt I/O activity (the
    rest of a join is the worker's update compute, not I/O)."""
    events = [
        _span_ev("io.read", 0, 10, key="act0"),             # activation
        _span_ev("io.read", 0, 8, key="opt3L1"),            # moment fetch
        _span_ev("spool.fetch_wait", 0, 8, key="opt3L1"),   # worker wait
        _span_ev("io.write", 20, 26, key="opt4L1"),         # moment stage
        _span_ev("engine.opt_join", 24, 32),                # exposed join
    ]
    res = obs_overlap.analyze(events)
    assert res["io_busy_s"] == pytest.approx(0.010)     # activation only
    assert res["exposed_wait_s"] == 0.0                 # opt wait is not
    assert res["opt_io_busy_s"] == pytest.approx(0.014)   # [0,8)+[20,26)
    assert res["opt_exposed_wait_s"] == pytest.approx(0.008)
    # the join [24,32) overlaps opt I/O only on [24,26); the other 6 ms
    # rode out the worker's update kernels — compute, not I/O
    assert res["opt_exposed_io_s"] == pytest.approx(0.002)
    assert res["opt_hidden_frac"] == pytest.approx(1.0 - 2.0 / 14.0)
    # serial staging (engine.opt_fetch/opt_stage wrap the spool calls):
    # busy is fully covered by exposed, so nothing is hidden
    serial = obs_overlap.analyze([
        _span_ev("io.read", 0, 8, key="opt3"),
        _span_ev("engine.opt_fetch", 0, 9),
        _span_ev("io.write", 10, 16, key="opt4"),
        _span_ev("engine.opt_stage", 10, 17),
    ])
    assert serial["opt_io_busy_s"] == pytest.approx(0.014)
    assert serial["opt_hidden_frac"] == pytest.approx(0.0)


def test_predicted_vs_measured_pairing():
    from repro.launch.dryrun import _predict_overlap
    pred = _predict_overlap(1e9, 3e9, 3.0)   # fits both windows
    assert pred["io_hidden_frac"] == 1.0
    paired = obs_overlap.predicted_vs_measured(
        pred, {"io_busy_s": 0.6, "io_hidden_frac": 0.9})
    assert paired["predicted_io_s"] == pytest.approx(2 / 3)
    assert paired["hidden_frac_error"] == pytest.approx(-0.1)
    # saturated store path: writes take 3x the fwd window
    slow = _predict_overlap(9e9, 1e9, 3.0)
    assert slow["io_hidden_frac"] < 1.0
    assert slow["exposed_wait_s"] == pytest.approx(
        (9.0 - 1.0) + (9.0 - 2.0))


# --------------------------------------------------- export + validation

def test_exporter_duplicates_shard_and_tier_lanes():
    tr = Tracer()
    with tr.span("hook.offload", cat="hook", args={"shard": 2}):
        pass
    with tr.span("io.write", cat="io", args={"kind": "mem", "key": "k"}):
        pass
    tr.instant("plain", cat="t")
    events = obs_export.trace_events(tr)
    by_pid = {}
    for ev in events:
        if ev["ph"] in ("X", "i"):
            by_pid.setdefault(ev["pid"], []).append(ev["name"])
    assert "hook.offload" in by_pid[obs_export.PID_THREADS]
    assert by_pid[obs_export.PID_SHARDS] == ["hook.offload"]
    assert by_pid[obs_export.PID_TIERS] == ["io.write"]
    # lane metadata names the shard / backend kind
    meta = {(ev["pid"], ev["tid"]): ev["args"]["name"]
            for ev in events if ev["ph"] == "M"
            and ev["name"] == "thread_name"}
    assert meta[(obs_export.PID_SHARDS, 0)] == "shard 2"
    assert meta[(obs_export.PID_TIERS, 0)] == "tier mem"


def test_validate_trace_accepts_exporter_output(tmp_path):
    tr = Tracer()
    with tr.span("io.write", cat="io", args={"kind": "mem"}):
        pass
    path = str(tmp_path / "t.json")
    obs_export.write_chrome_trace(path, tr, extra={"engine": "test"})
    assert obs_export.validate_trace(path, expect_cats=("io",)) == []
    doc = json.load(open(path))
    assert doc["otherData"]["engine"] == "test"
    assert doc["otherData"]["open_spans"] == 0


def test_validate_trace_rejects_garbage(tmp_path):
    assert obs_export.validate_trace({"nope": 1})
    assert obs_export.validate_trace({"traceEvents": "not-a-list"})
    errors = obs_export.validate_trace({"traceEvents": [
        {"ph": "X", "pid": 0, "tid": 0, "ts": 1.0},          # no name/dur
        {"name": "n", "ph": "Z", "pid": 0, "tid": 0, "ts": 0},  # bad ph
        {"name": "n", "ph": "X", "pid": 0, "tid": 0, "ts": 0,
         "dur": -5},                                         # bad dur
        "not an object",
    ]})
    assert len(errors) >= 4
    # expected-category enforcement
    errors = obs_export.validate_trace(
        {"traceEvents": [{"name": "n", "ph": "i", "cat": "spool",
                          "pid": 0, "tid": 0, "ts": 0, "s": "t"}]},
        expect_cats=("spool", "io"))
    assert any("'io'" in e for e in errors)
    # unreadable path
    assert obs_export.validate_trace(str(tmp_path / "missing.json"))


# --------------------------------------------------- end-to-end session

def test_traced_jit_session_end_to_end(tmp_path):
    """--trace on the jit engine with activation offload: the session
    writes a schema-valid Perfetto trace covering spool/io/codec/
    engine/hook, and each JSONL row carries its own step's deltas —
    obs_* overlap fields, per-shard traffic, and non-cumulative spool
    byte counts."""
    trace_path = str(tmp_path / "trace.json")
    metrics_path = str(tmp_path / "metrics.jsonl")
    cfg = dataclasses.replace(small_gpt(128, 2), dtype="float32")
    io = SpoolIoConfig(backend="mem", host_offload="activations")
    with TrainSession(cfg, engine="jit", io=io, optimizer="sgd",
                      lr=1e-3, batch_size=2, seq_len=32, seed=0,
                      ckpt_every=0, min_offload_elements=2 ** 8,
                      metrics_path=metrics_path,
                      trace=trace_path) as sess:
        result = sess.run(3)
        sess.spool.wait_io()
        total_offloaded = sess.spool.stats.bytes_offloaded
    assert obs_tracer._TRACER is None    # session-owned tracer released

    assert obs_export.validate_trace(
        trace_path,
        expect_cats=("spool", "io", "codec", "engine", "hook")) == []
    doc = json.load(open(trace_path))
    assert doc["otherData"]["open_spans"] == 0
    assert doc["otherData"]["dropped_events"] == 0

    rows = [json.loads(l) for l in open(metrics_path)]
    assert len(rows) == 3
    for row in rows:
        assert row["bytes_offloaded"] >= 0
        assert 0.0 <= row["obs_io_hidden_frac"] <= 1.0
        assert row["obs_io_busy_s"] > 0
        assert row["shards"]["global"]["offloads"] > 0
    # per-step deltas, not cumulative — but stores are async, so under
    # load a slow store can land in the NEXT step's delta window.
    # Assert conservation (the row deltas sum to the run's total spool
    # traffic) instead of pinning identical per-row byte counts.
    offl = [row["bytes_offloaded"] for row in rows]
    assert sum(offl) > 0
    assert sum(offl) <= total_offloaded, (offl, total_offloaded)
    assert [r.obs for r in result.reports] is not None
    last = result.reports[-1].obs
    assert last["prefetch_issued"] >= last["prefetch_hit"]
