"""Sharding-rule unit tests (mesh mocked: the rules only read
mesh.shape), verifying divisibility guards and per-name layouts for every
architecture's parameter tree."""
from types import SimpleNamespace

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.models.api import build_model
from repro.parallel.sharding import (MeshAxes, batch_specs, cache_specs,
                                     param_specs)

MESH = SimpleNamespace(shape={"data": 16, "model": 16})
MESH3 = SimpleNamespace(shape={"pod": 2, "data": 16, "model": 16})
AXES = MeshAxes(dp=("data",), tp="model")
AXES3 = MeshAxes(dp=("pod", "data"), tp="model")


def _params_sds(arch):
    api = build_model(get_config(arch))
    return jax.eval_shape(api.init, jax.random.key(0))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_specs_tree_matches_and_divides(arch):
    sds = _params_sds(arch)
    specs = param_specs(get_config(arch), sds, MESH, AXES)
    assert jax.tree_util.tree_structure(
        jax.tree.map(lambda _: 0, sds)) == jax.tree_util.tree_structure(
        jax.tree.map(lambda _: 0, specs,
                     is_leaf=lambda x: isinstance(x, P)))

    flat_s = jax.tree.leaves(sds)
    flat_p = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for leaf, spec in zip(flat_s, flat_p):
        assert len(spec) <= len(leaf.shape)
        for dim, part in zip(leaf.shape, tuple(spec)):
            if part is None:
                continue
            parts = part if isinstance(part, tuple) else (part,)
            n = 1
            for a in parts:
                n *= MESH.shape[a]
            assert dim % n == 0, (arch, leaf.shape, spec)


def test_qwen_kv_heads_replicated():
    """kv=2 cannot shard over model=16 — must be None."""
    cfg = get_config("qwen2.5-3b")
    sds = _params_sds("qwen2.5-3b")
    specs = param_specs(cfg, sds, MESH, AXES)
    wk = specs["segments"][0]["b0"]["attn"]["wk"]
    assert tuple(wk) == (None, "data", None, None)
    wq = specs["segments"][0]["b0"]["attn"]["wq"]
    assert tuple(wq) == (None, "data", "model", None)


def test_moe_experts_on_model_axis():
    cfg = get_config("kimi-k2-1t-a32b")
    sds = _params_sds("kimi-k2-1t-a32b")
    specs = param_specs(cfg, sds, MESH3, AXES3)
    w_in = specs["segments"][1]["b0"]["moe"]["w_in"]
    assert tuple(w_in)[:2] == (None, "model")       # experts over tp


def test_serving_tp_only_drops_fsdp():
    cfg = get_config("qwen2.5-3b")
    sds = _params_sds("qwen2.5-3b")
    specs = param_specs(cfg, sds, MESH, AXES, fsdp=False)
    for leaf in jax.tree.leaves(specs,
                                is_leaf=lambda x: isinstance(x, P)):
        assert "data" not in str(leaf)


def test_batch_specs_divisibility():
    class SDS:
        def __init__(self, shape):
            self.shape = shape
    b = {"tokens": SDS((256, 4096)), "pos": SDS(())}
    specs = batch_specs(b, MESH3, AXES3)
    assert tuple(specs["tokens"]) == (("pod", "data"), None)
    assert tuple(specs["pos"]) == ()
    b1 = {"tokens": SDS((1, 512))}                 # B=1: replicate
    assert tuple(batch_specs(b1, MESH3, AXES3)["tokens"]) == (None, None)


def test_cache_specs_kv_or_seq():
    class SDS:
        def __init__(self, shape):
            self.shape = shape
    # (L, B, S, KV, hd): kv=16 divisible -> sharded over model
    c = SDS((46, 128, 4096, 16, 128))
    spec = cache_specs(c, MESH3, AXES3)
    assert tuple(spec)[1] == ("pod", "data") and tuple(spec)[3] == "model"
    # kv=2 not divisible -> replicated heads
    c2 = SDS((36, 128, 32768, 2, 128))
    spec2 = cache_specs(c2, MESH3, AXES3)
    assert tuple(spec2)[3] is None
