"""Property-based tests (hypothesis) on the core invariants: tensor-id
dedup, adaptive-offloading feasibility/maximality, memory accounting."""
import threading

import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need the optional "
                           "hypothesis extra")
from hypothesis import given, settings as hsettings, strategies as st

from repro.core.accounting import MemoryTracker
from repro.core.adaptive import (ModuleProfile, plan_offload,
                                 required_bandwidth)
from repro.core.ids import TensorIdRegistry, _buffer_key

# ------------------------------------------------------------- ids


def test_ids_dedup_same_buffer():
    reg = TensorIdRegistry()
    a = np.ones((64, 64), np.float32)
    t1, dup1 = reg.acquire(a)
    t2, dup2 = reg.acquire(a)
    assert not dup1 and dup2 and t1 == t2
    reg.release(a)
    reg.release(a)
    assert reg.live_count == 0


def test_ids_distinct_buffers_not_deduped():
    reg = TensorIdRegistry()
    a = np.ones((8, 8), np.float32)
    b = np.ones((8, 8), np.float32)
    ta, da = reg.acquire(a)
    tb, db = reg.acquire(b)
    assert not da and not db and ta != tb


def test_ids_key_recycling_after_release():
    """The paper's id() pitfall: addresses recycle after free. Releasing
    must allow a new tensor at the same address to get a fresh id."""
    reg = TensorIdRegistry()
    a = np.ones((4, 4), np.float32)
    key = _buffer_key(a)
    t1, _ = reg.acquire(a)
    reg.release_key(key)
    t2, dup = reg.acquire(a)   # same buffer, new lease
    assert not dup and t2 != t1


def test_ids_parameters_excluded():
    reg = TensorIdRegistry()
    p = np.zeros((16,), np.float32)
    reg.register_parameters({"w": p})
    assert reg.is_parameter(p)
    assert not reg.is_parameter(np.zeros((16,), np.float32))


def test_ids_thread_safety():
    reg = TensorIdRegistry()
    arrs = [np.zeros((4,), np.float32) for _ in range(32)]

    def worker():
        for a in arrs:
            reg.acquire(a)
        for a in arrs:
            reg.release(a)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert reg.live_count == 0


# --------------------------------------------------------- adaptive

profiles_st = st.lists(
    st.tuples(st.integers(min_value=0, max_value=10**9),
              st.floats(min_value=1e-4, max_value=10.0)),
    min_size=2, max_size=24).map(
        lambda ls: [ModuleProfile(f"m{i}", b, t)
                    for i, (b, t) in enumerate(ls)])


@hsettings(max_examples=200, deadline=None)
@given(profiles_st, st.floats(min_value=1.0, max_value=1e12))
def test_adaptive_plan_is_feasible_and_maximal(profiles, bw):
    plan = plan_offload(profiles, bw)
    m = plan.last_offloaded
    if m >= 0:
        # feasible: chosen prefix fits the measured bandwidth
        assert required_bandwidth(profiles, m) <= bw * (1 + 1e-9)
    # maximal: offloading one more module would exceed the bandwidth
    # (or hit the keep-last-module rule)
    nxt = m + 1
    if nxt <= len(profiles) - 2:
        assert required_bandwidth(profiles, nxt) > bw * (1 - 1e-9)


@hsettings(max_examples=100, deadline=None)
@given(profiles_st, st.floats(min_value=1.0, max_value=1e10),
       st.floats(min_value=1.1, max_value=100.0))
def test_adaptive_monotone_in_bandwidth(profiles, bw, factor):
    lo = plan_offload(profiles, bw)
    hi = plan_offload(profiles, bw * factor)
    assert hi.last_offloaded >= lo.last_offloaded
    assert hi.num_offloaded >= lo.num_offloaded


@hsettings(max_examples=100, deadline=None)
@given(profiles_st, st.floats(min_value=1.0, max_value=1e12))
def test_adaptive_prefix_structure(profiles, bw):
    """The plan is always a prefix: offload[i] implies offload[j<=i]."""
    plan = plan_offload(profiles, bw)
    seen_false = False
    for o in plan.offload:
        if not o:
            seen_false = True
        assert not (o and seen_false)


def test_adaptive_keeps_last_module():
    profiles = [ModuleProfile(f"m{i}", 10**6, 0.1) for i in range(5)]
    plan = plan_offload(profiles, float("inf"))
    assert not plan.offload[-1]
    assert plan.last_offloaded == len(profiles) - 2


# ------------------------------------------------------- accounting


@hsettings(max_examples=100, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 31),
                          st.integers(min_value=1, max_value=10**6)),
                min_size=1, max_size=64))
def test_tracker_peak_and_total(events):
    tr = MemoryTracker()
    live = {}
    peak = 0
    for key, nbytes in events:
        if key in live:
            tr.free(key)
            live.pop(key)
        else:
            tr.alloc(key, nbytes)
            live[key] = nbytes
        peak = max(peak, sum(live.values()))
        assert tr.current == sum(live.values())
    assert tr.peak == peak


def test_tracker_double_alloc_is_idempotent():
    tr = MemoryTracker()
    tr.alloc("k", 100)
    tr.alloc("k", 999)       # ignored
    assert tr.current == 100
    tr.free("k")
    tr.free("k")             # ignored
    assert tr.current == 0
