"""System-level integration tests: the paper's claims, end to end, on the
runnable (staged + spool) TBA path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import small_bert, small_gpt
from repro.core.staged import StagedTrainer
from repro.models.api import build_model
from repro.models.transformer import RunSettings
from repro.optim.optimizers import sgd

B, S = 4, 64
MIN_OFF = 2 ** 10


def _setup(cfg, strategy, seed=0):
    cfg = dataclasses.replace(cfg, dtype="float32")
    api = build_model(cfg)
    settings = RunSettings(attn_impl="xla", attn_chunk=64,
                           param_dtype="float32")
    opt = sgd(1e-2)
    tr = StagedTrainer(api, settings, opt, strategy=strategy,
                       min_offload_elements=MIN_OFF)
    params = api.init(jax.random.key(seed))
    return api, tr, params, opt.init(params)


def _batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, (B, S + 1))
    return {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32)}


@pytest.fixture(scope="module")
def runs():
    """One fixture runs all three strategies on the same model/batch.

    Six layers (not three) so the forward pass is several times longer
    than one store's latency — the paper's operating regime, where
    writes land during forward and the offload peak reduction is
    unambiguous rather than a race with the first backward fetch."""
    cfg = small_gpt(128, 6)
    out = {}
    for strategy in ("keep", "offload", "recompute"):
        api, tr, params, opt_state = _setup(cfg, strategy)
        batch = _batch(cfg)
        reports, losses = [], []
        for step in range(4):
            params, opt_state, rep = tr.train_step(params, opt_state,
                                                   [batch])
            reports.append(rep)
            losses.append(rep.loss)
        out[strategy] = {"reports": reports, "losses": losses,
                         "params": params, "plan": tr.plan,
                         "profiles": tr._profiles}
        tr.close()
    return out


def _planned_bytes(run):
    """Residual bytes the adaptive plan chose to offload, from THIS
    process's own profiling step — the footprint assertions measure the
    reduction against this instead of a fixed fraction, so they hold on
    any machine regardless of how much the measured bandwidth lets the
    planner offload."""
    plan, profiles = run["plan"], run["profiles"]
    if plan is None or profiles is None:
        return 0
    return sum(p.bytes for p, off in zip(profiles, plan.offload) if off)


def test_strategies_numerically_identical(runs):
    """Offload and recompute must not change the math (paper: offloading
    is transparent)."""
    for a, b in [("keep", "offload"), ("keep", "recompute")]:
        np.testing.assert_allclose(runs[a]["losses"], runs[b]["losses"],
                                   rtol=1e-5, atol=1e-6)
        la = jax.tree.leaves(runs[a]["params"])
        lb = jax.tree.leaves(runs[b]["params"])
        for x, y in zip(la, lb):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=2e-4, atol=2e-5)


def test_offload_reduces_activation_peak(runs):
    """Paper Fig. 7/10: the activation peak drops with offloading.
    Steps 0-1 are excluded: 0 profiles (and compiles), 1 pays the
    plan transition — the claim is about steady state."""
    keep = max(r.peak_activation_bytes for r in runs["keep"]["reports"])
    off = max(r.peak_activation_bytes
              for r in runs["offload"]["reports"][2:])
    planned = _planned_bytes(runs["offload"])
    if planned == 0:
        pytest.skip("measured bandwidth planned no offloads here")
    # stores overlap forward, so some offloaded residuals are still
    # in flight at the peak: claim half the planned bytes
    assert off <= keep - 0.5 * planned, (off, keep, planned)


def test_offload_reduces_backward_begin_footprint(runs):
    """Paper Fig. 7: the begin-of-backward footprint drops ~45%."""
    keep = max(r.backward_begin_bytes for r in runs["keep"]["reports"])
    off = max(r.backward_begin_bytes
              for r in runs["offload"]["reports"][2:])
    planned = _planned_bytes(runs["offload"])
    if planned == 0:
        pytest.skip("measured bandwidth planned no offloads here")
    # by backward begin every store has landed; the last offloaded
    # module is already reloaded, so claim half the planned bytes
    assert off <= keep - 0.5 * planned, (off, keep, planned)


def test_recompute_has_lower_peak_but_same_loss(runs):
    keep = max(r.peak_activation_bytes for r in runs["keep"]["reports"])
    rec = max(r.peak_activation_bytes
              for r in runs["recompute"]["reports"])
    assert rec < keep


def test_offload_actually_spools_to_disk(runs):
    stats = runs["offload"]["reports"][-1].stats
    assert stats.bytes_offloaded > 0
    assert stats.num_stores > 0


def test_adaptive_plan_exists_after_profile_step(runs):
    rep = runs["offload"]["reports"][-1]
    assert rep.plan is not None
    # the last module (loss head) is never offloaded (§3.2 circled-4)
    assert not rep.plan.offload[-1]


def test_staged_matches_jit_training():
    """The staged trainer is numerically the same training algorithm as a
    whole-step jit (the system's central correctness invariant)."""
    cfg = dataclasses.replace(small_bert(128, 2), dtype="float32")
    api = build_model(cfg)
    settings = RunSettings(attn_impl="xla", attn_chunk=64,
                           param_dtype="float32")
    opt = sgd(1e-2)
    batch = _batch(cfg)

    params = api.init(jax.random.key(7))
    tr = StagedTrainer(api, settings, opt, strategy="offload",
                       min_offload_elements=MIN_OFF)
    p_staged, os_staged = params, opt.init(params)
    for _ in range(2):
        p_staged, os_staged, rep = tr.train_step(p_staged, os_staged,
                                                 [batch])
    tr.close()

    @jax.jit
    def step(p, o, b):
        (_, m), g = jax.value_and_grad(api.loss, has_aux=True)(
            p, b, settings)
        return opt.update(g, o, p)

    p_jit, o_jit = params, opt.init(params)
    for _ in range(2):
        p_jit, o_jit = step(p_jit, o_jit, batch)

    for a, b in zip(jax.tree.leaves(p_staged), jax.tree.leaves(p_jit)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)
