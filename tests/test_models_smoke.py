"""Per-architecture smoke tests: reduced same-family configs, one forward
and one train step on CPU, asserting output shapes and no NaNs (deliverable
f). Also exercises prefill->decode consistency for decoder archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import RunSettings, build_model

SETTINGS = RunSettings(attn_impl="xla", attn_chunk=8, param_dtype="float32")


def _reduced(arch):
    import dataclasses
    cfg = reduced(get_config(arch))
    return dataclasses.replace(cfg, dtype="float32")


def _batch(cfg, B=2, S=16, rng=None):
    rng = rng or np.random.default_rng(0)
    batch = {}
    if cfg.input_kind == "embeddings":
        batch["embeddings"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.family == "vlm":
        batch["enc_embeddings"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq_len, cfg.d_model)),
            jnp.float32)
    if cfg.family == "encdec":
        batch["enc_tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg = _reduced(arch)
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    batch = _batch(cfg)
    logits, aux = jax.jit(
        lambda p, b: api.forward(p, b, SETTINGS))(params, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits[..., :cfg.vocab_size])).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_decreases_loss(arch):
    cfg = _reduced(arch)
    api = build_model(cfg)
    params = api.init(jax.random.key(1))
    batch = _batch(cfg)

    @jax.jit
    def step(p, b):
        (l, m), g = jax.value_and_grad(
            lambda p: api.loss(p, b, SETTINGS), has_aux=True)(p)
        p = jax.tree.map(lambda w, gw: w - 0.05 * gw.astype(w.dtype), p, g)
        return l, p

    l0, params = step(params, batch)
    assert np.isfinite(float(l0))
    for _ in range(3):
        l1, params = step(params, batch)
    assert np.isfinite(float(l1))
    assert float(l1) < float(l0)  # same-batch loss must drop


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_config(a).has_decode])
def test_prefill_decode_consistency(arch):
    """Decoding token t with the prefill cache must match the full-sequence
    forward logits at position t (the core serving invariant)."""
    cfg = _reduced(arch)
    api = build_model(cfg)
    params = api.init(jax.random.key(2))
    B, S = 2, 16
    batch = _batch(cfg, B=B, S=S)

    logits_full, _ = api.forward(params, batch, SETTINGS)
    pre_batch = jax.tree.map(
        lambda a: a[:, :S - 1] if a.ndim >= 2 and a.shape[1] == S else a,
        {k: v for k, v in batch.items() if k != "labels"})
    if cfg.family == "vlm":
        pre_batch["enc_embeddings"] = batch["enc_embeddings"]
    last_logits, caches = api.prefill(params, pre_batch, SETTINGS,
                                      cache_len=S)
    np.testing.assert_allclose(np.asarray(last_logits[:, 0]),
                               np.asarray(logits_full[:, S - 2]),
                               rtol=2e-4, atol=2e-4)

    # one decode step for the final token
    step_batch = ({"tokens": batch["tokens"][:, S - 1:]}
                  if "tokens" in batch else
                  {"embeddings": batch["embeddings"][:, S - 1:]})
    # decode caches must be padded to a power-of-two-ish ring; reduced
    # configs keep S small so the prefill cache length S-1 works directly.
    logits_dec, _ = api.decode_step(params, caches, step_batch,
                                    jnp.asarray(S - 1, jnp.int32), SETTINGS)
    np.testing.assert_allclose(np.asarray(logits_dec[:, 0]),
                               np.asarray(logits_full[:, S - 1]),
                               rtol=2e-3, atol=2e-3)
