"""Unit tests for the trip-count-aware HLO analyzer (the roofline's data
source): synthetic-text parsing plus an end-to-end check on a compiled
scan where the expected dot FLOPs are known analytically."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_stats import (analyze_hlo, parse_module,
                                    _multipliers, _shape_bytes)

SYNTH = """\
HloModule test, entry_computation_layout={(f32[8,16])->f32[]}

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %ag = f32[8,32]{1,0} all-gather(%x), replica_groups=[2,2]<=[4], dimensions={1}
  %w = f32[32,16]{1,0} parameter(1)
  %d = f32[8,16]{1,0} dot(%ag, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ni, %d)
}

%cond (p2: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[] {
  %a = f32[8,16]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,16]) tuple(%z, %a)
  %w = (s32[], f32[8,16]) while(%t0), condition=%cond, body=%body
  %x1 = f32[8,16]{1,0} get-tuple-element(%w), index=1
  ROOT %ar = f32[] all-reduce(%x1), replica_groups={{0,1,2,3}}, to_apply=%cond
}
"""


def test_parse_computations_and_entry():
    comps, by_name, entry = parse_module(SYNTH)
    assert entry == "main"
    assert set(comps) == {"body", "cond", "main"}


def test_trip_count_multiplies_loop_body():
    ana = analyze_hlo(SYNTH, total_devices=4)
    # dot: 2 * 8*16 * 32 flops, x5 trips
    assert ana.flops == pytest.approx(2 * 8 * 16 * 32 * 5)
    assert ana.dot_count == 5


def test_collectives_with_groups_and_trips():
    ana = analyze_hlo(SYNTH, total_devices=4)
    ag = ana.collectives["all-gather"]
    # result 8*32*4 bytes, group size 2, wire = R*(n-1)/n, x5 trips
    assert ag.count == 5
    assert ag.wire_bytes == pytest.approx(8 * 32 * 4 * 0.5 * 5)
    ar = ana.collectives["all-reduce"]
    # explicit group {0,1,2,3}: n=4; all-reduce wire = 2R(n-1)/n; f32[] = 4B
    assert ar.count == 1
    assert ar.wire_bytes == pytest.approx(4 * 2 * 3 / 4)


def test_shape_bytes_tuple_and_layout():
    assert _shape_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert _shape_bytes("(s32[], f32[2,2]{1,0})") == 4 + 16
    assert _shape_bytes("bf16[3,5]") == 30


def test_end_to_end_scan_flops_counted():
    """Compile a real scan and verify trip-aware dot FLOPs."""
    L, D = 6, 32

    def f(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)
        return y.sum()

    co = jax.jit(f).lower(
        jax.ShapeDtypeStruct((4, D), jnp.float32),
        jax.ShapeDtypeStruct((L, D, D), jnp.float32)).compile()
    ana = analyze_hlo(co.as_text(), total_devices=1)
    want = 2 * 4 * D * D * L
    assert ana.flops == pytest.approx(want, rel=0.01)
    # XLA's own cost_analysis counts the body once — our whole reason for
    # existing; confirm the discrepancy is real.
    from repro.launch.hlo_stats import cost_analysis_dict
    xla_flops = cost_analysis_dict(co).get("flops", 0)
    assert xla_flops < want / 2
