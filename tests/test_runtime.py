"""Fault-tolerance tests on the TrainLoop: checkpoint/restart determinism,
preemption handling, straggler detection."""
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import ShardedLoader, SyntheticMarkovLM
from repro.runtime.trainer import StragglerWatchdog, TrainLoop, TrainState


def _quadratic_setup(ckpt_dir, metrics=None, slow_steps=()):
    """A tiny 'model' whose params integrate the data stream — any
    divergence between runs shows up immediately."""
    calls = {"n": 0}

    def step_fn(params, opt_state, batch):
        calls["n"] += 1
        if calls["n"] in slow_steps:
            time.sleep(0.25)
        g = jnp.asarray(batch["tokens"], jnp.float32).mean()
        params = {"w": params["w"] - 0.01 * (params["w"] - g)}
        return params, opt_state, {"loss": float(params["w"].sum())}

    src = SyntheticMarkovLM(128, seed=9)
    loader = ShardedLoader(src, global_batch=4, seq_len=8, prefetch=0)
    loop = TrainLoop(
        step_fn=step_fn,
        init_state=TrainState(0, {"w": jnp.zeros((2,))}, {}),
        loader=loader, ckpt_dir=ckpt_dir, ckpt_every=5,
        metrics_path=metrics,
        watchdog=StragglerWatchdog(window=16, threshold=2.0))
    return loop


def test_checkpoint_restart_bitwise_identical():
    d1, d2 = tempfile.mkdtemp(), tempfile.mkdtemp()
    # uninterrupted 20 steps
    loop_a = _quadratic_setup(d1)
    final_a = loop_a.run(20)
    # interrupted at 10, resumed into a NEW loop (fresh process semantics)
    loop_b1 = _quadratic_setup(d2)
    loop_b1.run(10)
    loop_b2 = _quadratic_setup(d2)
    assert loop_b2.resume()
    assert loop_b2.state.step == 10
    final_b = loop_b2.run(10)
    assert final_a.step == final_b.step == 20
    np.testing.assert_array_equal(np.asarray(final_a.params["w"]),
                                  np.asarray(final_b.params["w"]))


def test_preemption_saves_final_checkpoint():
    d = tempfile.mkdtemp()
    loop = _quadratic_setup(d)
    loop.request_preemption()        # simulated SIGTERM before any step
    final = loop.run(50)
    assert final.step == 0
    assert loop.ckpt.latest_step() == 0   # final checkpoint committed


def test_straggler_watchdog_flags_slow_steps():
    d = tempfile.mkdtemp()
    loop = _quadratic_setup(d, slow_steps={15, 16})
    loop.run(20)
    flagged = {f["step"] for f in loop.watchdog.flagged}
    assert {15, 16} & flagged


def test_metrics_jsonl_written():
    import json
    d = tempfile.mkdtemp()
    path = os.path.join(d, "metrics.jsonl")
    loop = _quadratic_setup(d, metrics=path)
    loop.run(5)
    loop.close()
    lines = [json.loads(l) for l in open(path)]
    assert len(lines) == 5
    assert all("step_time_s" in l and "loss" in l for l in lines)


def _finite_loop(batches, ckpt_dir, metrics=None):
    def step_fn(params, opt_state, batch):
        g = jnp.asarray(batch["tokens"], jnp.float32).mean()
        params = {"w": params["w"] - 0.01 * (params["w"] - g)}
        return params, opt_state, {"loss": float(params["w"].sum())}

    return TrainLoop(
        step_fn=step_fn,
        init_state=TrainState(0, {"w": jnp.zeros((2,))}, {}),
        loader=batches, ckpt_dir=ckpt_dir, ckpt_every=0,
        metrics_path=metrics)


def test_loader_exhaustion_ends_cleanly_with_final_checkpoint():
    """Regression: `next(it)` let StopIteration escape run(), skipping
    the final checkpoint (and the staged-opt-state rematerialization).
    A dry loader must end the loop cleanly instead."""
    d = tempfile.mkdtemp()
    batches = [{"tokens": np.full((2, 4), i)} for i in range(3)]
    loop = _finite_loop(batches, d)
    final = loop.run(10)            # asks for more steps than data
    loop.close()
    assert final.step == 3          # every batch consumed, then stop
    assert loop.ckpt.latest_step() == 3   # final checkpoint committed


def test_tokens_per_s_masks_padding():
    """Regression: tokens/s counted padded positions. With labels
    present, only labels >= 0 are real targets."""
    import json
    d = tempfile.mkdtemp()
    path = os.path.join(d, "metrics.jsonl")
    labels = np.full((2, 8), -1)
    labels[:, :3] = 5               # 6 real targets out of 16 positions
    batches = [{"tokens": np.zeros((2, 8), np.int32), "labels": labels}]
    loop = _finite_loop(batches, d, metrics=path)
    loop.run(1)
    loop.close()
    rec = json.loads(open(path).readline())
    tokens = rec["tokens_per_s"] * rec["step_time_s"]
    assert abs(tokens - 6) < 1e-6 * 6, rec
