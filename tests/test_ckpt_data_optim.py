"""Checkpointing (atomicity, async, GC, reshard-on-load), data pipeline
(determinism, shard disjointness, packing, resume), and optimizers."""
import json
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import (CheckpointManager, latest_step,
                                   restore_checkpoint, save_checkpoint)
from repro.data.pipeline import (PackedDataset, ShardedLoader,
                                 SyntheticMarkovLM, pack_documents)
from repro.optim.optimizers import adamw, clip_by_global_norm, sgd

# ------------------------------------------------------------ ckpt


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(16, 8)), jnp.float32),
            "nested": {"b": jnp.asarray(rng.normal(size=(4,)),
                                        jnp.bfloat16),
                       "c": [jnp.arange(5), jnp.zeros((2, 2))]}}


def test_save_restore_roundtrip():
    d = tempfile.mkdtemp()
    tree = _tree()
    save_checkpoint(d, 7, tree, metadata={"note": "x"})
    restored, manifest = restore_checkpoint(d, tree)
    assert manifest["step"] == 7 and manifest["metadata"]["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_atomic_commit_ignores_partial_writes():
    d = tempfile.mkdtemp()
    save_checkpoint(d, 1, _tree())
    # simulate a crash mid-write of step 2: tmp dir exists, no manifest
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    assert latest_step(d) == 1
    restored, m = restore_checkpoint(d, _tree())
    assert m["step"] == 1


def test_manager_async_and_gc():
    d = tempfile.mkdtemp()
    mgr = CheckpointManager(d, keep_last=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    mgr.wait()
    steps = sorted(int(x.split("_")[1]) for x in os.listdir(d)
                   if x.startswith("step_") and not x.endswith(".tmp"))
    assert steps == [3, 4]


def test_restore_shape_mismatch_raises():
    d = tempfile.mkdtemp()
    save_checkpoint(d, 1, {"a": jnp.zeros((4, 4))})
    with pytest.raises(ValueError):
        restore_checkpoint(d, {"a": jnp.zeros((8, 8))})


def test_restore_with_shardings_device_puts():
    d = tempfile.mkdtemp()
    tree = {"a": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(d, 1, tree)
    sh = jax.tree.map(lambda _: jax.devices()[0], tree)
    restored, _ = restore_checkpoint(d, tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


# ------------------------------------------------------------ data


def test_loader_deterministic_and_resumable():
    src = SyntheticMarkovLM(512, seed=3)
    l1 = ShardedLoader(src, global_batch=8, seq_len=32, prefetch=0)
    seq = [next(l1) for _ in range(4)]
    l2 = ShardedLoader(src, global_batch=8, seq_len=32, prefetch=0)
    l2.load_state_dict({"step": 2})
    np.testing.assert_array_equal(next(l2)["tokens"], seq[2]["tokens"])


def test_loader_prefetch_matches_sync():
    src = SyntheticMarkovLM(512, seed=3)
    sync = ShardedLoader(src, global_batch=4, seq_len=16, prefetch=0)
    pre = ShardedLoader(src, global_batch=4, seq_len=16, prefetch=2)
    for _ in range(3):
        np.testing.assert_array_equal(next(sync)["tokens"],
                                      next(pre)["tokens"])
    pre.close()


def test_host_shards_disjoint_streams():
    src = SyntheticMarkovLM(512, seed=5)
    a = ShardedLoader(src, global_batch=8, seq_len=16, host_id=0,
                      num_hosts=2, prefetch=0)
    b = ShardedLoader(src, global_batch=8, seq_len=16, host_id=1,
                      num_hosts=2, prefetch=0)
    ba, bb = next(a), next(b)
    assert ba["tokens"].shape == (4, 16)      # global 8 over 2 hosts
    assert not np.array_equal(ba["tokens"], bb["tokens"])


def test_labels_are_next_tokens():
    src = SyntheticMarkovLM(512, seed=0)
    l = ShardedLoader(src, global_batch=2, seq_len=16, prefetch=0)
    b = next(l)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_pack_documents():
    docs = [np.arange(5), np.arange(9), np.arange(3)]
    rows = pack_documents(docs, seq_len=8, eos_id=99)
    assert rows.shape[1] == 8
    flat = rows.reshape(-1)
    # every doc's tokens appear in order with EOS separators
    assert (flat == 99).sum() == 3
    total_tokens = sum(len(d) for d in docs) + 3
    assert rows.size >= total_tokens


def test_markov_stream_is_learnable_structure():
    """Bigram structure: next-token entropy must be far below uniform."""
    src = SyntheticMarkovLM(64, seed=1, branch=4)
    toks = src.sample(0, 0, 64, 128)
    pair_counts = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            pair_counts.setdefault(int(a), set()).add(int(b))
    avg_branch = np.mean([len(v) for v in pair_counts.values()])
    assert avg_branch <= 8          # << vocab 64


# ------------------------------------------------------------ optim


def test_sgd_reduces_quadratic():
    opt = sgd(0.1)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(50):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-3


def test_adamw_reduces_quadratic_and_counts_steps():
    opt = adamw(0.05, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert int(state.step) == 100
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}          # norm 5
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-5
    n2 = jnp.sqrt(jnp.sum(jnp.square(clipped["a"])))
    assert abs(float(n2) - 1.0) < 1e-5
