"""Tests for the unified TrainSession front door: engine parity, policy
resolution, SpoolIoConfig honored by the jit engine, unified metrics,
and resource cleanup (spool temp dirs, worker threads)."""
import dataclasses
import glob
import json
import os
import tempfile

import numpy as np
import pytest

from repro.configs.base import SpoolIoConfig
from repro.configs.paper_models import small_gpt
from repro.core.policies import (AdaptivePolicy, KeepPolicy,
                                 RecomputePolicy, SpoolPolicy,
                                 resolve_policy)
from repro.core.staged import StagedTrainer
from repro.session import TrainSession

MIN_OFF = 2 ** 8


def _cfg(hidden=128, layers=2):
    return dataclasses.replace(small_gpt(hidden, layers),
                               dtype="float32")


def _session(engine, **kw):
    kw.setdefault("optimizer", "adamw")
    kw.setdefault("lr", 1e-3)
    kw.setdefault("batch_size", 2)
    kw.setdefault("seq_len", 32)
    kw.setdefault("seed", 3)
    kw.setdefault("ckpt_every", 0)
    kw.setdefault("min_offload_elements", MIN_OFF)
    return TrainSession(_cfg(), engine=engine, **kw)


# --------------------------------------------------------- engine parity

@pytest.fixture(scope="module")
def parity():
    """Both engines, identical config, 3 steps on small-gpt."""
    out = {}
    for engine, io in [
        ("staged", None),
        ("jit", SpoolIoConfig(backend="mem", host_offload="opt_state")),
    ]:
        with _session(engine, io=io) as sess:
            result = sess.run(3)
            out[engine] = {
                "result": result,
                "losses": result.losses,
                "spool_backend": (type(sess.spool.backend).__name__
                                  if sess.spool else None),
                "spool_stats": (dataclasses.replace(sess.spool.stats)
                                if sess.spool else None),
                "io_writes": (sess.spool.backend.stats.num_writes
                              if sess.spool else 0),
            }
    return out


def test_both_engines_finite_matching_losses(parity):
    """Same arch/seed/optimizer through one front door: both engines
    produce finite losses of matching magnitude (the staged chain is the
    same training algorithm as the whole-step jit)."""
    ls, lj = parity["staged"]["losses"], parity["jit"]["losses"]
    assert len(ls) == len(lj) == 3
    assert np.all(np.isfinite(ls)) and np.all(np.isfinite(lj))
    np.testing.assert_allclose(ls, lj, rtol=5e-3)


def test_reports_unified_schema(parity):
    for engine in ("staged", "jit"):
        reports = parity[engine]["result"].reports
        assert [r.step for r in reports] == [1, 2, 3]
        assert all(r.engine == engine for r in reports)
        assert all(r.step_time > 0 for r in reports)
        assert all(r.tokens_per_s > 0 for r in reports)
        rec = reports[-1].to_metrics()
        assert rec["engine"] == engine and rec["step"] == 3
        assert "loss" in rec and "step_time_s" in rec


def test_jit_engine_honors_spool_backend(parity):
    """The jit engine builds its host-offload spool on the
    SpoolIoConfig-selected backend, and real bytes move through it."""
    assert parity["jit"]["spool_backend"] == "HostMemoryBackend"
    stats = parity["jit"]["spool_stats"]
    assert stats.num_stores > 0
    # every store either landed on the backend or was forwarded in
    # memory before the write started — both are real spool traffic
    assert parity["jit"]["io_writes"] > 0 or stats.bytes_forwarded > 0


def test_host_offload_is_transparent():
    """Staging the optimizer state through the spool between steps must
    not change the math."""
    with _session("jit") as plain:
        base = plain.run(3).losses
    with _session("jit", io=SpoolIoConfig(
            backend="mem", host_offload="opt_state")) as offl:
        offloaded = offl.run(3).losses
    np.testing.assert_allclose(base, offloaded, rtol=1e-6)


def test_host_offload_survives_per_step_checkpointing():
    """Regression: checkpointing while the opt-state store is still
    queued must not cancel the write (the checkpoint peek is
    non-consuming), or the next step's fetch dies."""
    d = tempfile.mkdtemp()
    with _session("jit", ckpt_dir=d, ckpt_every=1,
                  io=SpoolIoConfig(backend="fs", directory=d + "/spool",
                                   store_threads=1,
                                   host_offload="opt_state")) as sess:
        losses = sess.run(3).losses
    assert np.all(np.isfinite(losses))


def test_run_twice_reports_are_per_run():
    with _session("jit") as sess:
        r1 = sess.run(2)
        r2 = sess.run(2)
    assert [r.step for r in r1.reports] == [1, 2]
    assert [r.step for r in r2.reports] == [3, 4]
    assert len(sess.reports) == 4     # session keeps the full stream


def test_jit_metrics_keep_engine_aux_fields():
    """The unified schema must not drop the jit engine's aux metrics
    (ce/tokens; moe_lb/moe_z on MoE archs) that the seed JSONL had."""
    d = tempfile.mkdtemp()
    path = os.path.join(d, "metrics.jsonl")
    with _session("jit", metrics_path=path) as sess:
        sess.run(2)
    lines = [json.loads(l) for l in open(path)]
    assert len(lines) == 2
    for rec in lines:
        assert "ce" in rec and "tokens" in rec
        assert rec["engine"] == "jit"


# ------------------------------------------------------------- policies

def test_policy_resolution_matrix():
    assert isinstance(resolve_policy(None), AdaptivePolicy)
    assert isinstance(resolve_policy("keep"), KeepPolicy)
    assert isinstance(resolve_policy("recompute"), RecomputePolicy)
    assert isinstance(resolve_policy("adaptive"), AdaptivePolicy)
    assert isinstance(resolve_policy(strategy="offload"), AdaptivePolicy)
    assert isinstance(resolve_policy(strategy="offload", adaptive=False),
                      SpoolPolicy)
    pol = KeepPolicy()
    assert resolve_policy(pol) is pol
    with pytest.raises(ValueError):
        resolve_policy(pol, strategy="keep")   # both call shapes at once
    with pytest.raises(ValueError):
        resolve_policy("warp-drive")


def test_legacy_strategy_kwargs_map_to_policies():
    """Seed call shapes keep working: strategy= + adaptive= on the
    trainer construct the equivalent policy objects."""
    from repro.models.api import build_model
    from repro.models.transformer import RunSettings
    from repro.optim.optimizers import sgd

    api = build_model(_cfg(128, 1))
    settings = RunSettings(attn_impl="xla", attn_chunk=32,
                           param_dtype="float32")
    tr = StagedTrainer(api, settings, sgd(1e-2), strategy="keep")
    assert isinstance(tr.policy, KeepPolicy)
    assert tr.strategy == "keep" and not tr.adaptive
    tr.close()
    tr = StagedTrainer(api, settings, sgd(1e-2), strategy="offload",
                       adaptive=False)
    assert isinstance(tr.policy, SpoolPolicy)
    tr.close()
    tr = StagedTrainer(api, settings, sgd(1e-2))
    assert isinstance(tr.policy, AdaptivePolicy) and tr.adaptive
    tr.close()


def test_jit_engine_rejects_policy():
    with pytest.raises(ValueError):
        TrainSession(_cfg(), engine="jit", policy="keep")


# ------------------------------------------------------------- cleanup

def test_trainer_cleans_up_owned_tmpdir():
    """The seed leaked one tba_spool_* temp dir per trainer."""
    from repro.models.api import build_model
    from repro.models.transformer import RunSettings
    from repro.optim.optimizers import sgd

    pattern = os.path.join(tempfile.gettempdir(), "tba_spool_*")
    before = set(glob.glob(pattern))
    api = build_model(_cfg(128, 1))
    settings = RunSettings(attn_impl="xla", attn_chunk=32,
                           param_dtype="float32")
    tr = StagedTrainer(api, settings, sgd(1e-2))
    assert set(glob.glob(pattern)) - before      # dir exists while open
    tr.close()
    tr.close()                                   # idempotent
    assert not (set(glob.glob(pattern)) - before)

    # a user-named spool_dir is NOT removed
    keep_dir = tempfile.mkdtemp(prefix="user_spool_")
    tr = StagedTrainer(api, settings, sgd(1e-2), spool_dir=keep_dir)
    tr.close()
    assert os.path.isdir(keep_dir)


def test_session_metrics_jsonl_unified():
    d = tempfile.mkdtemp()
    path = os.path.join(d, "metrics.jsonl")
    with _session("staged", metrics_path=path) as sess:
        sess.run(2)
    lines = [json.loads(l) for l in open(path)]
    assert len(lines) == 2
    for rec in lines:
        for key in ("step", "engine", "loss", "step_time_s",
                    "tokens_per_s", "peak_activation_bytes",
                    "bytes_offloaded"):
            assert key in rec, key
    assert lines[0]["engine"] == "staged"


# ----------------------- data-plane parity matrix (backend x codec)


@pytest.fixture(scope="module")
def no_offload_losses():
    """Baseline: staged engine, keep-everything policy — the spool
    never touches a byte of residuals."""
    with _session("staged", policy=KeepPolicy()) as sess:
        return sess.run(2).losses


@pytest.mark.parametrize("backend", ["fs", "striped", "mem", "tiered",
                                     "managed", "aio"])
@pytest.mark.parametrize("codec", ["raw", "byteplane"])
def test_losses_bitwise_identical_across_data_planes(
        backend, codec, no_offload_losses, tmp_path):
    """The whole zero-copy data plane (vectored writes, pooled aligned
    loads, O_DIRECT, byte-plane codec) must be invisible to training:
    losses stay BITWISE identical to the no-offload baseline on every
    backend x codec pair."""
    io = SpoolIoConfig(
        backend=backend, codec=codec,
        directory=str(tmp_path / "spool"),
        stripe_dirs=(tuple(str(tmp_path / f"s{i}") for i in range(2))
                     if backend == "striped" else ()),
        # a tight tiered budget forces real spills to the lower tier
        host_mem_budget_bytes=64 << 10,
        pool_bytes=8 << 20)
    with _session("staged", policy=SpoolPolicy(), io=io) as sess:
        losses = sess.run(2).losses
        io_stats = sess.spool.backend.stats
        forwarded = sess.spool.stats.bytes_forwarded
    assert losses == no_offload_losses, \
        f"{backend}/{codec} changed training: {losses}"
    # real bytes moved through the data plane (or were forwarded from
    # in-flight stores — still real spool traffic)
    assert io_stats.num_writes > 0 or forwarded > 0
