"""Paged KV-cache subsystem tests (repro.kvcache): allocator units,
paged/resident layout split, deterministic continuous-batching
schedules, bitwise paged-vs-dense logits parity (including through
quantum preemption, i.e. spool eviction round trips), and the serve
accounting invariants the old driver got wrong."""
import numpy as np
import pytest

from repro.kvcache import (DenseKVCache, KVCacheConfig, PageAllocator,
                           PagePoolExhausted, Server, build_manager)
from repro.kvcache import adapters
from repro.launch.serve import build_kv_spool, build_runtime, \
    make_server, synth_requests
from repro.models.transformer import BlockDef, SegmentDef


# ---------------------------------------------------------------- units

def test_allocator_deterministic_and_null_page():
    al = PageAllocator(8)            # pages 1..7 usable, 0 reserved
    a = al.alloc(3)
    assert a == [1, 2, 3]            # fresh pages ascend
    assert 0 not in a
    al.free([2])
    assert al.alloc(1) == [2]        # LIFO recycle
    b = al.alloc(4)
    assert b == [4, 5, 6, 7]
    assert al.available == 0 and al.in_use == 7
    with pytest.raises(PagePoolExhausted):
        al.alloc(1)
    al.free(a + b)
    assert al.available == 7 and al.high_water == 7


def test_kvcfg_geometry():
    cfg = KVCacheConfig(page_tokens=16, max_seq_len=100)
    assert cfg.max_pages == 7
    assert cfg.padded_seq_len == 112
    assert cfg.resolve_pool_pages(4) == 4 * 7 + 1
    assert KVCacheConfig(pool_pages=9).resolve_pool_pages(4) == 9


def test_adapter_split():
    segs = (SegmentDef(n_repeat=2, blocks=(
        BlockDef("attn"), BlockDef("attn", window=8),
        BlockDef("rglru"))),)
    ids = adapters.paged_block_ids(segs, 64)
    assert ids == [{"b0"}]           # window 8 < 64 stays resident
    assert adapters.needs_exact_prefill(segs, 64)
    wide = (SegmentDef(n_repeat=1, blocks=(
        BlockDef("attn", window=64),)),)
    assert adapters.paged_block_ids(wide, 64) == [{"b0"}]
    assert not adapters.needs_exact_prefill(wide, 64)


# ------------------------------------------------------------- fixtures

PAGED_KW = dict(page_tokens=8, max_seq_len=48, quantum=3,
                prefetch_depth=2)


@pytest.fixture(scope="module")
def runtime():
    return build_runtime("small-gpt", seed=0)


def _serve(runtime, kind, *, n_slots=2, requests=6, quantum=0,
           record_logits=True, kv_backend="mem", io_kwargs=None):
    cfg, api, params, settings = runtime
    kvcfg = KVCacheConfig(page_tokens=8, max_seq_len=48,
                          quantum=quantum, prefetch_depth=2)
    spool = owned = None
    if kind == "paged":
        spool, owned = build_kv_spool(kv_backend, **(io_kwargs or {}))
    try:
        server = make_server(api, params, settings, kvcfg, kind=kind,
                             n_slots=n_slots,
                             spool=spool, record_logits=record_logits)
        synth_requests(server, requests, prompt_len=12, max_new=9,
                       vocab=cfg.vocab_size, seed=7)
        report = server.run()
    finally:
        if spool is not None:
            spool.close()
    return server, report


# ---------------------------------------------------- determinism/parity

def test_schedule_deterministic(runtime):
    s1, _ = _serve(runtime, "paged", quantum=3)
    s2, _ = _serve(runtime, "paged", quantum=3)
    assert s1.schedule_log == s2.schedule_log
    assert [q.tokens for q in s1.finished] == \
        [q.tokens for q in s2.finished]


def _by_rid(server):
    return {s.rid: s for s in server.finished}


def test_paged_dense_bitwise_parity(runtime):
    """Same request trace, paged (no preemption) vs dense: every
    sampled-from logits row is bitwise identical."""
    sp, rp = _serve(runtime, "paged")
    sd, rd = _serve(runtime, "dense")
    assert rp.generated_tokens == rd.generated_tokens
    p, d = _by_rid(sp), _by_rid(sd)
    assert set(p) == set(d)
    for rid in p:
        assert p[rid].tokens == d[rid].tokens
        for a, b in zip(p[rid].logits, d[rid].logits):
            np.testing.assert_array_equal(a, b)


def test_eviction_roundtrip_parity(runtime):
    """Quantum preemption forces evict->spool->restore cycles; logits
    must still match the dense baseline bitwise, token for token."""
    sp, rp = _serve(runtime, "paged", quantum=3)
    sd, _ = _serve(runtime, "dense")
    assert rp.preemptions > 0
    assert rp.kv["pages_evicted"] > 0
    assert rp.kv["pages_evicted"] == rp.kv["pages_restored"]
    p, d = _by_rid(sp), _by_rid(sd)
    for rid in p:
        assert p[rid].tokens == d[rid].tokens
        for a, b in zip(p[rid].logits, d[rid].logits):
            np.testing.assert_array_equal(a, b)


def test_managed_spool_serve_parity(runtime):
    """Evicted pages routed through the cache-manager backend (tight
    host bound -> real host/SSD tiering of kv_page blobs): logits stay
    bitwise identical to the dense baseline."""
    sp, rp = _serve(runtime, "paged", quantum=3, kv_backend="managed",
                    io_kwargs={"host_mem_budget_bytes": 16 << 10})
    sd, _ = _serve(runtime, "dense")
    assert rp.kv["pages_evicted"] > 0
    p, d = _by_rid(sp), _by_rid(sd)
    assert set(p) == set(d)
    for rid in p:
        assert p[rid].tokens == d[rid].tokens
        for a, b in zip(p[rid].logits, d[rid].logits):
            np.testing.assert_array_equal(a, b)


def test_concurrency_exceeds_slots(runtime):
    _, rp = _serve(runtime, "paged", quantum=3)
    _, rd = _serve(runtime, "dense")
    assert rd.peak_live <= rd.n_slots
    assert rp.peak_live > rp.n_slots


# ----------------------------------------------------------- accounting

def test_accounting_invariants(runtime):
    """The fixed serve accounting: the prefill-sampled first token is
    counted, idle slots are not, prompt tokens are the true lengths."""
    server, r = _serve(runtime, "paged", quantum=3)
    assert r.requests == 6
    assert r.generated_tokens == sum(
        len(s.tokens) for s in server.finished) == 6 * 9
    # exactly one token per request came from prefill logits
    assert r.decode_slot_tokens == r.generated_tokens - r.requests
    # idle slots never billed: the grid bound is strict when the tail
    # drains with a single live sequence
    assert r.decode_slot_tokens <= r.decode_steps * r.n_slots
    assert r.prompt_tokens == sum(
        len(s.prompt) for s in server.finished)
    assert r.kv["prefills"] == 6


def test_dense_cannot_evict(runtime):
    cfg, api, params, settings = runtime
    cache = build_manager("dense", api, params, settings,
                          KVCacheConfig(**PAGED_KW), 2)
    with pytest.raises(RuntimeError, match="cannot evict"):
        cache.evict(object())


def test_submit_validation(runtime):
    server, _ = None, None
    cfg, api, params, settings = runtime
    cache = build_manager("dense", api, params, settings,
                          KVCacheConfig(page_tokens=8, max_seq_len=16),
                          2)
    srv = Server(cache)
    with pytest.raises(ValueError):
        srv.submit([], 4)
    with pytest.raises(ValueError, match="exceeds"):
        srv.submit(np.arange(10), 10)
