"""Tests for the repro.io storage-backend subsystem: spool round-trip /
forwarding / cancellation over every backend, the vectored zero-copy
data plane (write_parts / readinto / size, aligned buffer pool, aio
direct I/O), stripe balance + per-device endurance projection, tiered
eviction under the RAM budget, codec round-trips incl. byteplane, serde
edge cases, and the tiered adaptive-planner bandwidth model."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adaptive import (ModuleProfile, TierBandwidth,
                                 effective_write_bandwidth, plan_offload)
from repro.core.endurance import project_device_lifespans
from repro.core.spool import ActivationSpool
from repro.io import (CODECS, AioBackend, AlignedBufferPool,
                      FilesystemBackend, HostMemoryBackend,
                      StripedBackend, TieredBackend, backend_from_spec,
                      build_backend, deserialize_leaves, encode_parts,
                      pack, parse_bytes, serialize_leaves,
                      serialize_parts, unpack)

BACKEND_KINDS = ["fs", "striped", "mem", "tiered", "aio"]
CODEC_NAMES = ["raw", "zlib", "byteplane"]


def make_backend(kind: str, tmp_path, **kw):
    if kind == "fs":
        return FilesystemBackend(str(tmp_path / "fs"))
    if kind == "striped":
        return StripedBackend([str(tmp_path / f"s{i}") for i in range(3)],
                              chunk_bytes=kw.get("chunk_bytes", 1 << 12))
    if kind == "mem":
        return HostMemoryBackend()
    if kind == "tiered":
        return TieredBackend(FilesystemBackend(str(tmp_path / "lower")),
                             capacity_bytes=kw.get("capacity_bytes",
                                                   32 << 10))
    if kind == "aio":
        return AioBackend(str(tmp_path / "aio"),
                          queue_depth=kw.get("queue_depth", 4))
    raise AssertionError(kind)


def _tree(seed=0, n=3, shape=(64, 64)):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.normal(size=shape), jnp.float32)
            for _ in range(n)]


# ------------------------------------------------------- raw backend API


@pytest.mark.parametrize("kind", BACKEND_KINDS)
def test_backend_blob_roundtrip(kind, tmp_path):
    b = make_backend(kind, tmp_path)
    data = os.urandom(10_000)
    b.write("k", data)
    assert b.read("k") == data
    assert b.stats.bytes_written == len(data)
    assert b.stats.bytes_read == len(data)
    b.delete("k")
    with pytest.raises((FileNotFoundError, OSError)):
        b.read("k")
    b.delete("missing")          # missing-tolerant, like spool.drop
    b.close()


@pytest.mark.parametrize("kind", BACKEND_KINDS)
def test_backend_reports_tier_bandwidths(kind, tmp_path):
    b = make_backend(kind, tmp_path)
    b.write("k", b"x" * 4096)
    tiers = b.tier_bandwidths()
    assert len(tiers) >= 1
    assert all(t.write_bw > 0 for t in tiers)
    if kind == "tiered":
        assert tiers[0].capacity_bytes == b.capacity_bytes
        assert tiers[-1].capacity_bytes is None


# ------------------------------------------------- spool over backends


@pytest.mark.parametrize("kind", BACKEND_KINDS)
@pytest.mark.parametrize("codec", CODEC_NAMES)
def test_spool_roundtrip_over_backend(kind, codec, tmp_path):
    spool = ActivationSpool(make_backend(kind, tmp_path), codec=codec,
                            min_offload_elements=16)
    trees = {f"k{i}": _tree(seed=i) for i in range(4)}
    for k, t in trees.items():
        spool.offload(k, t)
    spool.wait_io()
    assert spool.backend.stats.num_writes > 0
    for k in reversed(list(trees)):       # backward-order consumption
        out = spool.fetch(k)
        for a, b in zip(trees[k], out):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        spool.drop(k)
    spool.close()


@pytest.mark.parametrize("kind", BACKEND_KINDS)
def test_spool_forwarding_and_cancellation(kind, tmp_path):
    """fetch() during a slow store must forward the in-memory reference
    (§3.3.2) and cancel queued writes (§3.3.3 feature 1) on every
    backend."""
    spool = ActivationSpool(make_backend(kind, tmp_path),
                            bandwidth_limit=1e6, store_threads=1,
                            min_offload_elements=16)
    t1, t2 = _tree(1), _tree(2)
    spool.offload("a", t1)          # occupies the single store thread
    spool.offload("b", t2)          # waits in queue
    out = spool.fetch("b")          # must forward, not wait for storage
    for a, b in zip(t2, out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert spool.stats.bytes_forwarded > 0
    assert spool.stats.stores_canceled >= 1
    spool.wait_io()
    spool.close()


@pytest.mark.parametrize("kind", BACKEND_KINDS)
def test_spool_dedup_preserved_over_backend(kind, tmp_path):
    spool = ActivationSpool(make_backend(kind, tmp_path),
                            min_offload_elements=16)
    x = jnp.ones((128, 128), jnp.float32)
    spool.offload("k1", [x, x])     # same buffer twice
    spool.wait_io()
    assert spool.stats.bytes_deduped >= x.size * 4
    out = spool.fetch("k1")
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out[1]))
    spool.close()


def test_spool_drop_during_inflight_store_leaks_nothing(tmp_path):
    """drop() racing an in-flight (forwarded) store must not orphan the
    blob: on a RAM backend that would be a permanent memory leak."""
    backend = HostMemoryBackend()
    spool = ActivationSpool(backend, bandwidth_limit=2e6,
                            store_threads=1, min_offload_elements=16)
    spool.offload("a", _tree(1))    # slow store occupies the thread
    spool.offload("b", _tree(2))    # queued behind it
    spool.fetch("b")                # forwarded
    spool.fetch("a")                # forwarded from the RUNNING store
    spool.drop("a")                 # store still in flight
    spool.drop("b")                 # store still queued -> canceled
    spool.wait_io()
    assert backend.resident_bytes == 0, "orphaned blob left in RAM"
    spool.close()


# ----------------------------------------------------------- striping


def test_striped_balance_across_devices(tmp_path):
    dirs = [str(tmp_path / f"ssd{i}") for i in range(4)]
    b = StripedBackend(dirs, chunk_bytes=1 << 10)
    b.write("k", os.urandom(64 << 10))          # 64 chunks over 4 dirs
    per_dev = b.per_device_write_bytes()
    assert len([n for n in per_dev if n > 0]) >= 2
    assert max(per_dev) - min(per_dev) <= b.chunk_bytes
    for d in dirs:                              # files really spread out
        assert any(f.startswith("k.c") for f in os.listdir(d))
    assert b.read("k") == b.read("k")
    b.delete("k")
    assert all(not os.listdir(d) for d in dirs)


def test_striped_rewrite_with_fewer_chunks_prunes_tail(tmp_path):
    """Re-writing a key with a smaller blob must remove the old trailing
    chunks, or probe-based readers reassemble fresh+stale garbage."""
    dirs = [str(tmp_path / f"ssd{i}") for i in range(2)]
    b = StripedBackend(dirs, chunk_bytes=1 << 10)
    b.write("k", os.urandom(5 << 10))      # 5 chunks
    small = os.urandom(2 << 10)            # 2 chunks
    b.write("k", small)
    fresh = StripedBackend(dirs, chunk_bytes=1 << 10)
    assert fresh.read("k") == small
    b.delete("k")
    assert all(not os.listdir(d) for d in dirs)


def test_tiered_small_rewrite_clears_stale_lower_copy(tmp_path):
    """small -> oversize -> small leases of one key must never leave a
    stale lower-tier blob behind."""
    lower = HostMemoryBackend()
    b = TieredBackend(lower, capacity_bytes=1 << 10)
    b.write("k", os.urandom(1 << 20))      # oversize -> lower
    b.write("k", b"fresh-small")           # small -> upper
    assert b.read("k") == b"fresh-small"
    assert lower.resident_bytes == 0       # stale oversize copy purged
    b.delete("k")
    assert b.resident_bytes == 0 and lower.resident_bytes == 0


def test_striped_read_without_manifest(tmp_path):
    """A second backend over the same directories (fresh process view)
    must reassemble blobs by probing chunk files."""
    dirs = [str(tmp_path / f"ssd{i}") for i in range(2)]
    data = os.urandom(10_000)
    StripedBackend(dirs, chunk_bytes=1 << 10).write("k", data)
    fresh = StripedBackend(dirs, chunk_bytes=1 << 10)
    assert fresh.read("k") == data


def test_striped_endurance_projection(tmp_path):
    """Per-device write accounting feeds the Fig.9-style lifespan model:
    balanced stripes -> near-equal shares and finite per-drive lives."""
    b = StripedBackend([str(tmp_path / f"ssd{i}") for i in range(4)],
                       chunk_bytes=1 << 10)
    for i in range(8):
        b.write(f"k{i}", os.urandom(16 << 10))
    wear = project_device_lifespans(b.per_device_write_bytes(),
                                    elapsed_s=10.0)
    assert len(wear) == 4
    assert abs(sum(w.share for w in wear) - 1.0) < 1e-9
    assert max(w.share for w in wear) < 0.30    # balanced round-robin
    assert all(0 < w.lifespan_years < float("inf") for w in wear)
    # a skewed array ages its hot drive faster than a balanced one
    skewed = project_device_lifespans([3 << 20, 1 << 20], elapsed_s=10.0)
    assert skewed[0].lifespan_years < skewed[1].lifespan_years


# ------------------------------------------------------------- tiering


def test_tiered_eviction_respects_budget(tmp_path):
    lower = HostMemoryBackend()
    budget = 64 << 10
    b = TieredBackend(lower, capacity_bytes=budget)
    blobs = {f"k{i}": os.urandom(16 << 10) for i in range(10)}
    for k, v in blobs.items():
        b.write(k, v)
        assert b.resident_bytes <= budget
    assert b.evictions > 0
    # backward-access order: the *latest* stores (needed first by the
    # backward pass) are still in RAM; the earliest spilled to lower.
    assert "k9" in b.upper and "k0" not in b.upper
    assert lower.read("k0") == blobs["k0"]
    for k, v in blobs.items():                  # reads hit either tier
        assert b.read(k) == v
    b.delete("k9")
    b.delete("k0")
    assert "k9" not in b.upper


def test_tiered_oversize_blob_bypasses_ram(tmp_path):
    lower = HostMemoryBackend()
    b = TieredBackend(lower, capacity_bytes=1 << 10)
    big = os.urandom(1 << 20)
    b.write("big", big)
    assert b.resident_bytes == 0
    assert b.read("big") == big


def test_tiered_oversize_rewrite_replaces_resident_copy(tmp_path):
    """Rewriting a resident key with an over-budget blob must not leave
    the stale small copy shadowing it in RAM."""
    lower = HostMemoryBackend()
    b = TieredBackend(lower, capacity_bytes=1 << 10)
    b.write("k", b"small")
    big = os.urandom(1 << 20)
    b.write("k", big)
    assert b.read("k") == big
    assert b.resident_bytes == 0
    b.delete("k")
    assert lower.resident_bytes == 0


def test_spool_key_reuse_after_orphaned_store(tmp_path):
    """Re-offloading a key whose previous (dropped) store is still in
    flight must keep the new blob: the stale orphan cleanup must not
    delete the next lease's data."""
    backend = HostMemoryBackend()
    spool = ActivationSpool(backend, bandwidth_limit=2e6,
                            store_threads=1, min_offload_elements=16)
    t_old, t_new = _tree(1), _tree(5)
    spool.offload("k", t_old)       # slow store starts RUNNING
    spool.fetch("k")                # forwarded from the running store
    spool.drop("k")                 # orphans the in-flight write
    spool.offload("k", t_new)       # same key, new lease
    spool.wait_io()
    out = spool.fetch("k")
    for a, want in zip(out, t_new):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(want))
    spool.drop("k")
    spool.wait_io()
    assert backend.resident_bytes == 0
    spool.close()


# --------------------------------------- vectored data-plane contract


@pytest.mark.parametrize("kind", BACKEND_KINDS)
def test_write_parts_matches_joined_write(kind, tmp_path):
    """The vectored path must store byte-identical blobs to the joined
    path, and `size` must report the true stored length."""
    b = make_backend(kind, tmp_path)
    parts = [b"head", os.urandom(10_000), b"", os.urandom(3)]
    joined = b"".join(parts)
    b.write_parts("vec", [memoryview(p) for p in parts])
    b.write("join", joined)
    assert b.read("vec") == joined == b.read("join")
    assert b.size("vec") == len(joined)
    assert b.stats.bytes_written == 2 * len(joined)
    b.close()


@pytest.mark.parametrize("kind", BACKEND_KINDS)
def test_readinto_fills_caller_buffer(kind, tmp_path):
    b = make_backend(kind, tmp_path)
    data = os.urandom(20_000)
    b.write_parts("k", [memoryview(data)])
    pool = AlignedBufferPool()
    with pool.acquire(len(data)) as lease:
        mv = b.readinto("k", lease.mv)
        assert len(mv) == len(data)
        assert bytes(mv) == data
    # too-small buffer must be rejected, not silently truncated
    with pytest.raises((ValueError, FileNotFoundError)):
        b.readinto("k", memoryview(bytearray(100)))
    with pytest.raises((FileNotFoundError, OSError)):
        b.readinto("missing", memoryview(bytearray(1 << 15)))
    pool.close()
    b.close()


@pytest.mark.parametrize("kind", ["fs", "striped", "tiered"])
def test_vectored_fs_paths_copy_nothing(kind, tmp_path):
    """The zero-copy claim, as a number: fs-family vectored writes and
    pooled reads must not perform a single host-side payload copy."""
    b = make_backend(kind, tmp_path, capacity_bytes=0)  # tiered: all low
    parts = serialize_parts([np.arange(4096, dtype=np.float32)])
    b.write_parts("k", parts)
    pool = AlignedBufferPool()
    with pool.acquire(b.size("k")) as lease:
        b.readinto("k", lease.mv)
    assert b.stats.bytes_copied == 0
    if kind == "tiered":
        assert b.lower.stats.bytes_copied == 0
    pool.close()
    b.close()


def test_bufpool_alignment_and_reuse():
    pool = AlignedBufferPool(alignment=4096, max_bytes=1 << 20)
    a = pool.acquire(10_000)
    assert a.capacity % 4096 == 0 and a.capacity >= 10_000
    assert np.frombuffer(a.mv, np.uint8).ctypes.data % 4096 == 0
    a.mv[:5] = b"hello"
    a.release()
    a.release()                       # idempotent
    b = pool.acquire(9_000)           # same size class -> reuse
    assert pool.hits == 1 and pool.misses == 1
    b.release()
    assert pool.free_bytes == b.capacity
    pool.close()
    assert pool.free_bytes == 0


def test_bufpool_trims_beyond_cap():
    pool = AlignedBufferPool(alignment=4096, max_bytes=8192)
    leases = [pool.acquire(8192) for _ in range(3)]
    for lease in leases:
        lease.release()
    assert pool.trimmed == 2          # only one 8 KiB buffer cached
    assert pool.free_bytes <= 8192
    pool.close()


def test_bufpool_rejects_bad_alignment():
    with pytest.raises(ValueError):
        AlignedBufferPool(alignment=3000)
    with pytest.raises(ValueError):
        AlignedBufferPool(alignment=1 << 20)   # beyond page guarantee


def test_aio_backend_roundtrip_unaligned_sizes(tmp_path):
    """O_DIRECT padding/ftruncate must be invisible: arbitrary
    (unaligned) blob lengths round-trip exactly."""
    b = AioBackend(str(tmp_path / "aio"))
    for n in (0, 1, 511, 4096, 4097, 10_000, 70_001):
        data = os.urandom(n)
        b.write("k", data)
        assert b.size("k") == n
        assert b.read("k") == data
    b.close()


def test_aio_depth_one_no_executor(tmp_path):
    b = AioBackend(str(tmp_path / "aio"), queue_depth=1)
    data = os.urandom(30_000)
    b.write("k", data)
    assert b.read("k") == data
    b.close()


def test_aio_buffered_fallback_roundtrip(tmp_path):
    """direct=False exercises the buffered + fdatasync + fadvise path
    (what a filesystem without O_DIRECT gets)."""
    b = AioBackend(str(tmp_path / "aio"), direct=False)
    data = os.urandom(10_000)
    b.write_parts("k", [memoryview(data[:4000]), memoryview(data[4000:])])
    pool = AlignedBufferPool()
    with pool.acquire(len(data)) as lease:
        assert bytes(b.readinto("k", lease.mv)) == data
    pool.close()
    b.close()


def test_aio_readinto_unaligned_buffer_bounces(tmp_path):
    """A misaligned caller buffer must still be filled correctly (via
    the pooled aligned bounce)."""
    b = AioBackend(str(tmp_path / "aio"))
    data = os.urandom(9_000)
    b.write("k", data)
    raw = bytearray(len(data) + 1)
    mv = memoryview(raw)[1:]          # deliberately odd base address
    assert bytes(b.readinto("k", mv)) == data
    b.close()


def test_aio_rewrite_shrinking_blob_truncates(tmp_path):
    """In-place overwrite must not leave the previous lease's tail."""
    b = AioBackend(str(tmp_path / "aio"))
    b.write("k", os.urandom(50_000))
    small = os.urandom(5_000)
    b.write("k", small)
    assert b.size("k") == len(small)
    assert b.read("k") == small
    b.close()


def test_fs_write_is_atomic_no_temp_left(tmp_path):
    """The atomic-write contract: blobs appear only complete, temp
    files never survive, and a torn write (simulated) is rejected by
    serde instead of misparsed."""
    b = FilesystemBackend(str(tmp_path / "fs"))
    blob = serialize_leaves([np.arange(1024, dtype=np.float32)])
    b.write("k", blob)
    files = os.listdir(str(tmp_path / "fs"))
    assert files == ["k.act"]         # no .tmp leftovers
    # a crash mid-store under the OLD path left a truncated blob; the
    # serde guard must reject it loudly on "restart"
    with open(str(tmp_path / "fs" / "torn.act"), "wb") as f:
        f.write(blob[:len(blob) // 2])
    with pytest.raises(ValueError):
        deserialize_leaves(unpack(b.read("torn")))


def test_fs_write_failure_cleans_temp(tmp_path, monkeypatch):
    """If the vectored write dies mid-flight, the temp file must not
    accumulate (and the real blob must stay absent)."""
    b = FilesystemBackend(str(tmp_path / "fs"))
    import repro.io.backends as mod

    def boom(fd, parts, offset=0):
        raise OSError(28, "No space left on device")

    monkeypatch.setattr(mod, "pwritev_all", boom)
    with pytest.raises(OSError):
        b.write("k", b"x" * 1000)
    assert os.listdir(str(tmp_path / "fs")) == []


# -------------------------------------------------------------- codecs


@pytest.mark.parametrize("codec", sorted(CODECS))
def test_codec_pack_roundtrip(codec):
    payload = b"residual" * 4096
    blob = pack(payload, codec)
    assert bytes(unpack(blob)) == payload


def test_zlib_compresses_compressible_payloads():
    payload = np.zeros(1 << 16, np.float32).tobytes()
    assert len(pack(payload, "zlib")) < len(pack(payload, "raw"))


def test_byteplane_beats_zlib_on_bf16_residuals():
    """The codec's reason to exist: on realistic bf16 activations the
    high (sign+exponent) plane compresses while the mantissa plane is
    noise — byteplane must out-compress whole-stream zlib level 1."""
    import ml_dtypes
    rng = np.random.default_rng(0)
    a = rng.standard_normal(1 << 18).astype(np.float32)
    a[a < 0] *= 0.01
    payload = a.astype(ml_dtypes.bfloat16).tobytes()
    bp = len(pack(payload, "byteplane"))
    zl = len(pack(payload, "zlib"))
    raw = len(pack(payload, "raw"))
    assert bp < zl < raw
    assert bytes(unpack(pack(payload, "byteplane"))) == payload


def test_byteplane_chunked_and_incompressible():
    """Multi-chunk payloads round-trip (parallel encode path) and pure
    noise falls back to the per-chunk raw escape without growth beyond
    the per-chunk header."""
    from repro.io.codecs import BytePlaneCodec
    c = BytePlaneCodec(chunk_bytes=1 << 12)
    noise = os.urandom(5 * (1 << 12) + 123)     # 6 chunks, odd tail
    enc = c.encode(noise)
    assert bytes(c.decode(enc)) == noise
    assert len(enc) <= len(noise) + 16 + 6 * 16
    assert bytes(c.decode(c.encode(b""))) == b""


# ----------------------------------------------------- serde edge cases


def _edge_trees():
    import ml_dtypes
    rng = np.random.default_rng(7)
    return {
        "empty": [np.zeros((0,), np.float32), np.zeros((3, 0, 2),
                                                       np.int32)],
        "zero_d": [np.float32(3.25).reshape(()),
                   np.array(7, dtype=np.int64)],
        "ml_dtypes": [
            rng.standard_normal(257).astype(ml_dtypes.bfloat16),
            rng.standard_normal(64).astype(ml_dtypes.float8_e4m3fn),
            rng.standard_normal(33).astype(ml_dtypes.float8_e5m2),
        ],
        "mixed": [np.arange(100, dtype=np.uint8),
                  np.float32(1.5).reshape(()),
                  rng.standard_normal((17, 3)).astype(np.float16),
                  np.zeros((0, 5), ml_dtypes.bfloat16),
                  rng.integers(-9, 9, (4, 4, 4)).astype(np.int16)],
    }


@pytest.mark.parametrize("case", sorted(_edge_trees()))
@pytest.mark.parametrize("copy", [True, False])
def test_serde_edge_cases_roundtrip(case, copy):
    leaves = _edge_trees()[case]
    out = deserialize_leaves(serialize_leaves(leaves), copy=copy)
    assert len(out) == len(leaves)
    for a, got in zip(leaves, out):
        assert np.asarray(a).shape == got.shape
        assert np.asarray(a).dtype == got.dtype
        np.testing.assert_array_equal(np.asarray(a), got)


@pytest.mark.parametrize("kind", BACKEND_KINDS)
@pytest.mark.parametrize("codec", CODEC_NAMES)
def test_serde_edge_cases_through_backend(kind, codec, tmp_path):
    """Property-style: every edge tree survives the FULL data plane
    (serialize_parts -> encode_parts -> write_parts -> readinto ->
    unpack -> zero-copy deserialize) on every backend x codec pair."""
    b = make_backend(kind, tmp_path)
    trees = _edge_trees()
    pool = AlignedBufferPool()
    for name, leaves in trees.items():
        b.write_parts(name, encode_parts(serialize_parts(leaves), codec))
    for name, leaves in trees.items():
        n = b.size(name)
        assert n is not None and n > 0
        with pool.acquire(n) as lease:
            out = deserialize_leaves(unpack(b.readinto(name, lease.mv)),
                                     copy=False)
            assert len(out) == len(leaves)
            for a, got in zip(leaves, out):
                assert np.asarray(a).dtype == got.dtype
                assert np.asarray(a).shape == got.shape
                np.testing.assert_array_equal(np.asarray(a), got)
    pool.close()
    b.close()


def test_deserialize_views_are_readonly_and_copy_writable():
    blob = serialize_leaves([np.arange(64, dtype=np.float32)])
    views = deserialize_leaves(blob, copy=False)
    assert not views[0].flags.writeable     # borrowers cannot scribble
    copies = deserialize_leaves(blob, copy=True)
    assert copies[0].flags.writeable


def test_unpack_accepts_seed_format_blobs():
    """Pre-subsystem spool files had no container header; unpack must
    pass them through untouched."""
    legacy = serialize_leaves([np.ones((8, 8), np.float32)])
    out = deserialize_leaves(unpack(legacy))
    np.testing.assert_array_equal(out[0], np.ones((8, 8), np.float32))


def test_deserialized_arrays_are_writable():
    out = deserialize_leaves(serialize_leaves(
        [np.arange(16, dtype=np.float32)]))
    assert out[0].flags.writeable
    out[0][0] = 42.0                # must not raise
    assert out[0][0] == 42.0


# ----------------------------------------------- factory / spec strings


def test_parse_bytes_suffixes():
    assert parse_bytes("64kb") == 64 << 10
    assert parse_bytes("1.5mb") == int(1.5 * (1 << 20))
    assert parse_bytes("4096") == 4096


def test_backend_from_spec(tmp_path):
    base = str(tmp_path)
    assert isinstance(backend_from_spec("fs", base_dir=base),
                      FilesystemBackend)
    assert isinstance(backend_from_spec("mem"), HostMemoryBackend)
    s = backend_from_spec("striped@4", base_dir=base)
    assert isinstance(s, StripedBackend) and len(s.directories) == 4
    t = backend_from_spec("tiered:64kb,mem", base_dir=base)
    assert isinstance(t, TieredBackend)
    assert t.capacity_bytes == 64 << 10
    assert isinstance(t.lower, HostMemoryBackend)
    a = backend_from_spec("aio@8", base_dir=base)
    assert isinstance(a, AioBackend) and a.queue_depth == 8
    a2 = backend_from_spec(f"aio:{base}/dio", base_dir=base)
    assert isinstance(a2, AioBackend) and a2.directory == f"{base}/dio"
    with pytest.raises(KeyError):
        backend_from_spec("nvram", base_dir=base)


def test_build_backend_aio_from_config(tmp_path):
    from repro.configs.base import SpoolIoConfig
    ioc = SpoolIoConfig(backend="aio", queue_depth=2,
                        alignment=512, pool_bytes=1 << 20).validate()
    b = build_backend(ioc, default_dir=str(tmp_path))
    assert isinstance(b, AioBackend)
    assert b.queue_depth == 2 and b.alignment == 512
    assert b.pool.alignment == 512
    b.close()


def test_build_backend_from_config(tmp_path):
    from repro.configs.base import SpoolIoConfig
    ioc = SpoolIoConfig(backend="tiered",
                        stripe_dirs=(str(tmp_path / "a"),
                                     str(tmp_path / "b")),
                        host_mem_budget_bytes=1 << 20).validate()
    b = build_backend(ioc, default_dir=str(tmp_path))
    assert isinstance(b, TieredBackend)
    assert isinstance(b.lower, StripedBackend)


# ----------------------------------------- tiered planner bandwidth


def test_effective_bandwidth_blends_tiers():
    tiers = [TierBandwidth("ram", 10e9, 1000),
             TierBandwidth("ssd", 1e9, None)]
    assert effective_write_bandwidth(tiers, 500) == pytest.approx(10e9)
    # 1000 bytes at 10 GB/s + 1000 at 1 GB/s -> 2000/(1.1e-6 s)
    blended = effective_write_bandwidth(tiers, 2000)
    assert 1e9 < blended < 10e9
    assert blended == pytest.approx(2000 / (1000 / 10e9 + 1000 / 1e9))
    # deep overflow converges to the bottom tier's rate
    assert effective_write_bandwidth(tiers, 10 ** 9) == \
        pytest.approx(1e9, rel=0.01)


def test_calibration_measures_every_tier(tmp_path):
    """A calibration burst small enough to fit the RAM budget must still
    exercise the lower tier — an unmeasured tier reads as infinitely
    fast and the planner would treat spill traffic as free."""
    spool = ActivationSpool(make_backend("tiered", tmp_path,
                                         capacity_bytes=1 << 20),
                            codec="zlib", min_offload_elements=16)
    spool.calibrate_backend(64 << 10)
    tiers = spool.planner_bandwidth()
    assert isinstance(tiers, list) and len(tiers) == 2
    assert all(0 < t.write_bw < float("inf") for t in tiers)
    # the zlib codec bounds the store path: planner tiers must be slower
    # than the raw device measurement
    raw = spool.backend.tier_bandwidths()
    assert tiers[0].write_bw <= raw[0].write_bw
    spool.close()


def test_tiered_concurrent_spill_and_delete(tmp_path):
    """Deletes racing an in-flight eviction must not resurrect blobs in
    the lower tier."""
    lower = HostMemoryBackend()
    b = TieredBackend(lower, capacity_bytes=32 << 10)
    for i in range(8):
        b.write(f"k{i}", os.urandom(8 << 10))
    for i in range(8):
        b.delete(f"k{i}")
    assert b.resident_bytes == 0
    assert lower.resident_bytes == 0


def test_plan_offload_accepts_tiers():
    profiles = [ModuleProfile(f"m{i}", 10 ** 6, 0.1) for i in range(6)]
    fast = plan_offload(profiles, [TierBandwidth("ram", 1e12, None)])
    slow = plan_offload(profiles, [TierBandwidth("ssd", 1.0, None)])
    assert fast.num_offloaded == len(profiles) - 1   # keep-last rule
    assert slow.num_offloaded <= 1
    # a RAM budget covering only part of the traffic lands in between
    mid = plan_offload(profiles, [TierBandwidth("ram", 1e12, 2 * 10 ** 6),
                                  TierBandwidth("ssd", 1.0, None)])
    assert slow.num_offloaded <= mid.num_offloaded <= fast.num_offloaded
