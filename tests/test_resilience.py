"""Resilience subsystem tests (`repro.resilience` + its wiring).

The degradation ladder under a dying SSD, bottom to top:

  1. transient I/O errors are retried with bounded backoff (exact
     attempt accounting against the fault injector's counters);
  2. a stripe device that hard-fails stops receiving writes — chunks
     rebalance onto surviving devices with wear accounting intact;
  3. a residual fetch that ultimately fails degrades to recomputing
     the segment from kept inputs, in BOTH engines (staged try/except
     and the jit hooks' lax.cond ok-flag branch), at loss/grad parity;
  4. health transitions re-plan the adaptive offload policy mid-run;
  5. the chaos end-to-end: a device dies mid-training, every step
     completes, and the final losses match a healthy run.

Checkpoint crash-consistency (fsync + manifest-last + skip-corrupt
restore) rides along: it is the recovery story's other half.
"""
import json
import os
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import (checkpoint_is_valid, latest_step,
                                   restore_checkpoint, save_checkpoint)
from repro.configs.base import SpoolIoConfig
from repro.core.adaptive import ModuleProfile
from repro.core.endurance import project_device_lifespans
from repro.core.hooks import HookBridge, spooled_scan_body
from repro.core.policies import AdaptivePolicy
from repro.core.spool import ActivationSpool
from repro.io import (FaultInjectingBackend, FilesystemBackend,
                      HostMemoryBackend, StripedBackend,
                      backend_from_spec)
from repro.io.backend import classify_io_error
from repro.resilience import (BackendHealth, ChaosHarness, HealthEvent,
                              RetryPolicy, unwrap_chain)

MIN_OFF = 4


def _tree(rng, n=4096):
    return {"a": rng.normal(size=(n,)).astype(np.float32),
            "b": rng.normal(size=(n, 2)).astype(np.float32)}


def _assert_tree_equal(a, b):
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def _spool(backend, **kw):
    kw.setdefault("min_offload_elements", MIN_OFF)
    kw.setdefault("store_threads", 1)
    kw.setdefault("load_threads", 1)
    return ActivationSpool(backend, **kw)


def _fast_retry(**kw):
    kw.setdefault("backoff_s", 1e-4)
    kw.setdefault("backoff_max_s", 1e-3)
    return RetryPolicy(**kw)


# =================================================== taxonomy + policy

def test_error_taxonomy():
    import errno
    assert classify_io_error(OSError(errno.EIO, "io")) == "transient"
    assert classify_io_error(OSError(errno.EAGAIN, "again")) == "transient"
    assert classify_io_error(TimeoutError()) == "transient"
    assert classify_io_error(OSError(errno.ENOSPC, "full")) == "fatal"
    assert classify_io_error(OSError(errno.ENODEV, "gone")) == "fatal"
    assert classify_io_error(FileNotFoundError("x")) == "fatal"
    assert classify_io_error(ValueError("bad serde")) == "fatal"
    # unknown-errno OSErrors get the benefit of the doubt
    assert classify_io_error(OSError("mystery")) == "transient"


def test_retry_policy_backoff_schedule():
    p = RetryPolicy(max_attempts=4, backoff_s=0.01, backoff_factor=2.0,
                    backoff_max_s=0.025)
    assert [p.delay(a) for a in (1, 2, 3)] == [0.01, 0.02, 0.025]
    with pytest.raises(AssertionError):
        RetryPolicy(max_attempts=0).validate()


def test_backend_health_transitions_and_events():
    h = BackendHealth("t", fail_threshold=2, min_samples=2,
                      degrade_latency_ratio=2.0)
    events = []
    h.subscribe(events.append)
    exc = OSError(5, "boom")
    assert h.status == "healthy"
    h.record_failure("write", exc)
    assert h.status == "healthy"            # below threshold
    h.record_failure("write", exc)
    assert h.status == "failing"
    assert [e.kind for e in events] == ["failing"]
    h.record_success("write", 0.001)
    assert h.status == "healthy"
    assert [e.kind for e in events] == ["failing", "recovered"]
    # latency degradation: baseline from first 2 samples, then slow ones
    for _ in range(2):
        h.record_success("read", 0.001)
    for _ in range(6):
        h.record_success("read", 0.1)
    assert h.status == "degraded"
    assert any(e.kind == "degraded" and e.op == "read" for e in events)
    snap = h.snapshot()
    assert snap["health"] == 1 and snap["read_latency_ratio"] > 2.0


def test_health_subscriber_exceptions_are_swallowed():
    h = BackendHealth("t", fail_threshold=1)
    h.subscribe(lambda e: (_ for _ in ()).throw(RuntimeError("bad sub")))
    h.record_failure("write", OSError(5, "x"))   # must not raise
    assert h.status == "failing"


# ============================================== spool retry accounting

def test_transient_store_retry_exact_accounting():
    """Two armed transient write failures: the store succeeds on the
    3rd attempt, stats count exactly 2 retries, the injector exactly 2
    injections, and the fetch is a real backend load (no forwarding)."""
    bk = FaultInjectingBackend(HostMemoryBackend(), fail_writes=2)
    spool = _spool(bk, retry=_fast_retry())
    rng = np.random.default_rng(0)
    tree = _tree(rng)
    with spool.step("mb0") as tx:
        tx.offload(0, tree)
        spool.wait_io()
        assert bk.injected["write_failures"] == 2
        assert spool.stats.store_retries == 2
        assert spool.stats.num_stores == 1
        assert bk.inner.stats.num_writes == 1
        _assert_tree_equal(tree, tx.fetch(0))
        tx.drop(0)
    assert spool.stats.bytes_forwarded == 0
    assert spool.health.status == "healthy"     # success reset the op
    spool.close()


def test_transient_load_retry_exact_accounting(tmp_path):
    bk = FaultInjectingBackend(FilesystemBackend(str(tmp_path)))
    spool = _spool(bk, retry=_fast_retry())
    rng = np.random.default_rng(1)
    tree = _tree(rng)
    with spool.step("mb0") as tx:
        tx.offload(0, tree)
        spool.wait_io()
        bk.arm_read_failures(2)
        _assert_tree_equal(tree, tx.fetch(0))
        tx.drop(0)
    assert bk.injected["read_failures"] == 2
    assert spool.stats.load_retries == 2
    spool.close()


def test_exhausted_retries_surface_and_feed_health():
    """More consecutive failures than attempts: the store really fails
    (forwarding saves the step), with exactly max_attempts injections,
    and the health monitor transitions to failing."""
    bk = FaultInjectingBackend(HostMemoryBackend(), fail_writes=100)
    spool = _spool(bk, retry=_fast_retry(max_attempts=3))
    events = []
    spool.health.subscribe(events.append)
    rng = np.random.default_rng(2)
    tree = _tree(rng)
    with spool.step("mb0") as tx:
        tx.offload(0, tree)
        spool.wait_io()
        assert bk.injected["write_failures"] == 3   # exactly max_attempts
        assert spool.stats.store_retries == 2
        _assert_tree_equal(tree, tx.fetch(0))        # forwarded, not lost
        tx.drop(0)
    assert spool.health.status == "failing"
    assert any(e.kind == "failing" for e in events)
    spool.close()


def test_fatal_error_not_retried():
    bk = FaultInjectingBackend(
        HostMemoryBackend(), fail_writes=1,
        write_exc=OSError(28, "No space left on device"))
    spool = _spool(bk, retry=_fast_retry())
    rng = np.random.default_rng(3)
    with spool.step("mb0") as tx:
        tx.offload(0, _tree(rng))
        spool.wait_io()
        assert bk.injected["write_failures"] == 1   # one try, no retry
        assert spool.stats.store_retries == 0
        tx.drop(0)
    spool.close()


# ================================================ new fault primitives

def test_intermittent_faults_are_seeded_and_reproducible():
    def run(seed):
        bk = FaultInjectingBackend(HostMemoryBackend(),
                                   intermittent_rate=0.5,
                                   intermittent_seed=seed)
        outcomes = []
        for i in range(32):
            try:
                bk.write(f"k{i}", b"x" * 16)
                outcomes.append(True)
            except OSError:
                outcomes.append(False)
        n = bk.injected["intermittent_failures"]
        bk.close()
        return outcomes, n
    a, na = run(7)
    b, _ = run(7)
    c, _ = run(8)
    assert a == b                      # same seed, same fault schedule
    assert a != c                      # different seed differs
    assert any(a) and not all(a)       # actually intermittent
    assert na == a.count(False)


def test_enospc_after_budget():
    bk = FaultInjectingBackend(HostMemoryBackend(),
                               enospc_after_bytes=100)
    bk.write("a", b"x" * 60)
    bk.write("b", b"x" * 60)           # budget crossed by this write
    with pytest.raises(OSError) as ei:
        bk.write("c", b"x" * 10)       # ...so this one is refused
    assert ei.value.errno == 28
    assert bk.injected["enospc_failures"] == 1
    bk.close()


def test_fault_device_scoping_on_stripe(tmp_path):
    dirs = [str(tmp_path / f"d{i}") for i in range(2)]
    striped = StripedBackend(dirs, chunk_bytes=64)
    bk = FaultInjectingBackend(striped)
    # find keys whose stripe placement starts on each device
    k0 = next(f"k{i}" for i in range(64) if striped._device(f"k{i}", 0) == 0)
    k1 = next(f"k{i}" for i in range(64) if striped._device(f"k{i}", 0) == 1)
    bk.arm_write_failures(100, device=1)
    bk.write(k0, b"x" * 32)            # device-0 key unaffected
    with pytest.raises(OSError):
        bk.write(k1, b"x" * 32)
    assert bk.injected["write_failures"] == 1
    bk.close()


# =========================================== striped rebalance + wear

def test_striped_rebalance_avoids_dead_device(tmp_path):
    dirs = [str(tmp_path / f"d{i}") for i in range(3)]
    bk = StripedBackend(dirs, chunk_bytes=64)
    harness = ChaosHarness(bk)
    payload = os.urandom(64 * 6)       # 6 chunks over 3 devices
    bk.write("warm", payload)
    assert bk.read("warm") == payload
    harness.kill_device(1)
    # new writes must not touch device 1; reads of them succeed
    for i in range(4):
        key = f"post{i}"
        bk.write(key, payload)
        assert 1 not in bk._placement(key)
        assert bk.read(key) == payload
    assert bk.rebalanced_chunks >= 8   # 2 dev-1 chunks per post blob
    assert sum(bk.devices_down()) == 1
    # wear accounting: only bytes a device actually ACCEPTED count,
    # and the totals cover every blob stored
    per_dev = bk.per_device_write_bytes()
    assert per_dev[1] == len(payload) // 3   # only the pre-kill share
    assert sum(per_dev) == len(payload) * 5
    # endurance projection consumes the same counters unchanged
    wear = project_device_lifespans(per_dev, 10.0)
    assert len(wear) == 3
    # heal: the device rejoins the write set
    harness.heal_device(1)
    assert sum(bk.devices_down()) == 0
    bk.write("healed", payload)
    assert bk.read("healed") == payload
    bk.close()


def test_striped_read_of_dead_device_chunk_raises(tmp_path):
    """Chunks already ON a device that dies are unreadable — that is
    the failure the spool retries and the engines recompute around."""
    dirs = [str(tmp_path / f"d{i}") for i in range(2)]
    bk = StripedBackend(dirs, chunk_bytes=64)
    payload = os.urandom(64 * 4)
    bk.write("k", payload)
    ChaosHarness(bk).kill_device(0)
    with pytest.raises(OSError):
        bk.read("k")                   # some chunk lives on device 0
    bk.close()


def test_striped_write_failures_down_device_at_threshold(tmp_path,
                                                         monkeypatch):
    """Consecutive chunk-write failures take the device out of the
    write set at fail_threshold; wear counts only accepted bytes."""
    dirs = [str(tmp_path / f"d{i}") for i in range(2)]
    bk = StripedBackend(dirs, chunk_bytes=64, fail_threshold=2)
    real = bk._write_chunk
    fails = {"n": 0}

    def flaky(dev, key, i, views):
        if dev == 0 and fails["n"] < 2:
            fails["n"] += 1
            raise OSError(5, "injected chunk failure")
        return real(dev, key, i, views)

    monkeypatch.setattr(bk, "_write_chunk", flaky)
    k1 = next(f"k{i}" for i in range(64) if bk._device(f"k{i}", 0) == 0)
    k2 = next(f"j{i}" for i in range(64) if bk._device(f"j{i}", 0) == 0)
    bk.write(k1, b"x" * 64)            # retried onto device 1
    assert bk.chunk_write_failures == 1
    assert not any(bk.devices_down())  # one failure: not down yet
    assert bk.rebalanced_chunks == 1
    bk.write(k2, b"z" * 64)            # second consecutive failure
    assert bk.chunk_write_failures == 2
    assert bk.devices_down()[0]        # threshold reached: downed
    assert bk.per_device_write_bytes()[0] == 0
    assert bk.read(k1) == b"x" * 64    # data followed the rebalance
    bk.close()


# ======================================== engine degradation: staged

def _staged_session(io, **kw):
    from repro.session import TrainSession
    kw.setdefault("batch_size", 2)
    kw.setdefault("seq_len", 32)
    kw.setdefault("seed", 0)
    kw.setdefault("min_offload_elements", 0)
    return TrainSession("small-gpt", engine="staged", policy="spool",
                        io=io, **kw)


def test_staged_fetch_failure_recomputes_at_loss_parity():
    """Arm unrecoverable read failures after step 1: every later fetch
    exhausts its retries and degrades to recompute-from-kept-inputs.
    Forward math is untouched and the recompute branch re-derives the
    same gradients, so the loss trajectory matches a healthy run."""
    def run(chaos):
        io = SpoolIoConfig(backend="fault:fs", retry_attempts=2,
                           retry_backoff_s=1e-3)
        with _staged_session(io) as sess:
            losses = list(sess.run(1).losses)
            if chaos:
                sess.spool.backend.arm_read_failures(10_000)
            losses += sess.run(2).losses
            stats = sess.spool.stats.snapshot()
            injected = dict(sess.spool.backend.injected)
        return losses, stats, injected

    healthy, _, _ = run(False)
    degraded, stats, injected = run(True)
    assert injected["read_failures"] > 0
    assert stats.fetch_fallbacks > 0, "recompute fallback never fired"
    assert stats.load_retries > 0, "retry path never exercised"
    assert len(degraded) == 3 and all(np.isfinite(degraded))
    np.testing.assert_allclose(degraded, healthy, rtol=1e-3)


def test_staged_on_fetch_fail_raise_mode():
    """on_fetch_fail='raise' keeps the seed behavior: an unreadable
    residual blob kills the step instead of degrading."""
    io = SpoolIoConfig(backend="fault:fs", retry_attempts=1,
                       retry_backoff_s=1e-3, on_fetch_fail="raise")
    with _staged_session(io) as sess:
        assert sess.trainer.on_fetch_fail == "raise"
        sess.run(1)                    # healthy step works
        sess.spool.backend.arm_read_failures(10_000)
        with pytest.raises((RuntimeError, OSError)):
            sess.run(1)
        sess.spool.backend.arm_read_failures(0)


# =========================================== engine degradation: jit

def test_hook_fallback_grads_match_reference():
    fb = FaultInjectingBackend(HostMemoryBackend())
    spool = _spool(fb, min_offload_elements=0, retry=_fast_retry())
    bridge = HookBridge(spool, fetch_fallback=True)
    # force stores to COMPLETE before backward so the fetch must hit
    # the backend (defeats §3.3.2 tensor forwarding for this test)
    orig = bridge.offload

    def offload_sync(step, stage, arrays, **kw):
        orig(step, stage, arrays, **kw)
        spool.wait_io()

    bridge.offload = offload_sync

    def fn(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])

    wrapped = spooled_scan_body(fn, bridge)
    rng = np.random.RandomState(0)
    p = {"w": jnp.asarray(rng.randn(4, 4), jnp.float32),
         "b": jnp.ones((4,), jnp.float32)}
    x = jnp.asarray(rng.randn(2, 4), jnp.float32)

    @jax.jit
    def gradf(p, x, step):
        return jax.grad(lambda p: jnp.sum(
            wrapped(p, x, step, jnp.float32(0)) ** 2))(p)

    ref = jax.grad(lambda p: jnp.sum(fn(p, x) ** 2))(p)
    g1 = gradf(p, x, jnp.float32(0.0))       # healthy: fetched branch
    for k in ref:
        np.testing.assert_allclose(g1[k], ref[k], rtol=1e-5)
    assert spool.stats.fetch_fallbacks == 0

    fb.arm_read_failures(10_000)             # device gone: cond flips
    g2 = gradf(p, x, jnp.float32(1.0))
    for k in ref:
        np.testing.assert_allclose(g2[k], ref[k], rtol=1e-5)
    assert spool.stats.fetch_fallbacks == 1
    assert bridge.stats_by_shard()[None]["degraded_fetches"] == 1
    # the aborted stage's lease was cleaned up: no leaked transactions
    assert not bridge._txs
    bridge.close()
    spool.close()


def test_hook_without_fallback_keeps_default_semantics():
    fb = FaultInjectingBackend(HostMemoryBackend())
    spool = _spool(fb, min_offload_elements=0, retry=_fast_retry())
    bridge = HookBridge(spool)               # fetch_fallback=False
    assert not bridge.fetch_fallback

    def fn(p, x):
        return jnp.tanh(x @ p["w"])

    wrapped = spooled_scan_body(fn, bridge)
    p = {"w": jnp.eye(4, dtype=jnp.float32)}
    x = jnp.ones((2, 4), jnp.float32)

    @jax.jit
    def gradf(p, x, step):
        return jax.grad(lambda p: jnp.sum(
            wrapped(p, x, step, jnp.float32(0)) ** 2))(p)

    g = gradf(p, x, jnp.float32(0.0))        # healthy pass works
    assert np.isfinite(np.asarray(g["w"]).sum())
    bridge.close()
    spool.close()


# =================================================== mid-run re-plan

def _profiles():
    return [ModuleProfile(f"seg0_l{i}", 64 << 20, 0.05) for i in range(4)]


def test_adaptive_replan_on_bandwidth_collapse():
    pol = AdaptivePolicy()
    pol.on_profile(_profiles(), 8e9)       # plenty of bandwidth
    n0 = sum(pol.plan.offload)
    assert n0 > 0
    h = BackendHealth("fs", fail_threshold=2)
    pol.attach_health(h)
    exc = OSError(5, "dying ssd")
    h.record_failure("write", exc)
    h.record_failure("write", exc)         # -> failing event
    assert pol.replans == 1
    assert sum(pol.plan.offload) == 0      # device gone: stop offloading
    assert pol.last_health_event.kind == "failing"
    # recovery re-plans back up to the original plan
    h.record_success("write", 0.001)
    assert pol.replans == 2
    assert sum(pol.plan.offload) == n0


def test_adaptive_replan_scales_with_latency_degradation():
    pol = AdaptivePolicy()
    pol.on_profile(_profiles(), 2e9)
    n0 = sum(pol.plan.offload)
    assert n0 > 0
    pol.on_health_event(HealthEvent(
        kind="degraded", backend="fs", op="write",
        consecutive_failures=0, latency_ratio=100.0))
    assert pol.replans == 1
    assert sum(pol.plan.offload) < n0      # 1/100th of the bandwidth


def test_replan_before_profile_is_a_noop():
    pol = AdaptivePolicy()
    pol.on_health_event(HealthEvent(
        kind="failing", backend="fs", op="write",
        consecutive_failures=3, latency_ratio=1.0))
    assert pol.replans == 0 and pol.plan is None


# ====================================== checkpoint crash consistency

def test_checkpoint_truncated_blob_skipped_on_restore(tmp_path):
    d = str(tmp_path)
    tree = {"w": np.arange(12, dtype=np.float32).reshape(3, 4)}
    save_checkpoint(d, 1, tree)
    save_checkpoint(d, 2, {"w": tree["w"] + 1})
    npz = os.path.join(d, "step_00000002", "arrays.npz")
    with open(npz, "rb") as f:
        blob = f.read()
    with open(npz, "wb") as f:
        f.write(blob[:len(blob) // 2])     # torn write / crashed copy
    assert not checkpoint_is_valid(os.path.join(d, "step_00000002"))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert latest_step(d) == 1
        assert any("corrupt" in str(x.message) for x in w)
    restored, manifest = restore_checkpoint(
        d, {"w": np.zeros((3, 4), np.float32)})
    assert manifest["step"] == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]), tree["w"])
    # an explicitly requested broken step is an error, never a silent
    # substitute
    with pytest.raises(ValueError, match="partial or corrupt"):
        restore_checkpoint(d, tree, step=2)


def test_checkpoint_missing_manifest_is_invalid(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 5, {"w": np.ones(3, np.float32)})
    os.unlink(os.path.join(d, "step_00000005", "manifest.json"))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        assert latest_step(d) is None


# ================================================== chaos end to end

def test_chaos_device_death_mid_run_end_to_end(tmp_path):
    """The acceptance scenario: on a fault-wrapped 3-way stripe, device
    1 hard-fails mid-run and reads briefly raise; training completes
    every step, the retry / recompute-fallback / rebalance paths each
    fire at least once, and the losses match a healthy run."""
    def run(tag, chaos):
        dirs = [str(tmp_path / tag / f"d{i}") for i in range(3)]
        io = SpoolIoConfig(backend="fault:striped:" + ",".join(dirs),
                           retry_attempts=2, retry_backoff_s=1e-3)
        mp = str(tmp_path / f"{tag}.jsonl")
        losses = []
        with _staged_session(io, metrics_path=mp) as sess:
            harness = ChaosHarness(sess.spool.backend)
            assert harness.fault is not None
            assert harness.striped is not None
            for step in range(5):
                if chaos and step == 2:
                    sess.spool.wait_io()
                    harness.kill_device(1)
                    harness.raising_reads(5)
                losses += sess.run(1).losses
            report = harness.report()
            stats = sess.spool.stats.snapshot()
        with open(mp) as f:
            rows = [json.loads(line) for line in f]
        return losses, report, stats, rows

    healthy_losses, _, _, _ = run("healthy", False)
    losses, report, stats, rows = run("chaos", True)

    assert len(losses) == 5 and all(np.isfinite(losses))
    # every degradation rung fired
    assert stats.load_retries > 0, "retry path never exercised"
    assert stats.fetch_fallbacks > 0, "recompute fallback never fired"
    assert report["read_failures"] == 5
    assert report["rebalanced_chunks"] > 0, "rebalance never happened"
    assert report["devices_down"] == 1
    # loss parity: forward math is chaos-free and the recompute branch
    # re-derives the same gradients
    np.testing.assert_allclose(losses, healthy_losses, rtol=1e-3)
    # the metrics stream recorded the incident, step by step
    assert len(rows) == 5
    assert all("resilience_health" in r for r in rows)
    assert sum(r["resilience_fetch_fallbacks"] for r in rows) \
        == stats.fetch_fallbacks
    assert rows[-1]["resilience_devices_down"] == 1
    assert rows[0]["resilience_devices_down"] == 0


def test_unwrap_chain_walks_wrappers(tmp_path):
    bk = backend_from_spec(
        f"fault:tiered:1mb,striped:{tmp_path}/a,{tmp_path}/b")
    kinds = {b.kind for b in unwrap_chain(bk)}
    assert {"fault", "tiered", "striped"} <= kinds
    h = ChaosHarness(bk)
    assert h.fault is not None and h.striped is not None
    bk.close()
