"""Regression tests for the beyond-paper graph optimizations (§Perf
iterations 1 & 3): causal-blocked attention and chunked cross-entropy
must be exact rewrites of the base forms."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import small_gpt
from repro.models.api import build_model
from repro.models.attention import attend, attend_blocked, attend_chunked
from repro.models.transformer import RunSettings

RNG = np.random.default_rng(7)


def _qkv(B=2, S=256, Hq=4, Hkv=2, D=32):
    q = jnp.asarray(RNG.normal(size=(B, S, Hq, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("causal,window,cap", [
    (True, 0, 0.0), (True, 64, 0.0), (True, 32, 0.0), (True, 0, 30.0)])
def test_blocked_equals_chunked(causal, window, cap):
    q, k, v = _qkv()
    a = attend_chunked(q, k, v, causal=causal, window=window,
                       logit_cap=cap, chunk=32)
    b = attend_blocked(q, k, v, causal=causal, window=window,
                       logit_cap=cap, chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-6)


def test_blocked_grads_equal():
    q, k, v = _qkv(S=128)
    ga = jax.grad(lambda q: attend_chunked(
        q, k, v, causal=True, window=64, chunk=32).sum())(q)
    gb = jax.grad(lambda q: attend_blocked(
        q, k, v, causal=True, window=64, chunk=32).sum())(q)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(gb),
                               rtol=1e-5, atol=1e-5)


def test_dispatcher_uses_blocked_for_long_causal():
    """attend() must route long causal sequences through the blocked
    path and produce identical results."""
    q, k, v = _qkv(S=256)
    out = attend(q, k, v, causal=True, chunk=64, impl="xla")
    want = attend_chunked(q, k, v, causal=True, chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_chunked_ce_exact():
    cfg = dataclasses.replace(small_gpt(128, 2), dtype="float32")
    api = build_model(cfg)
    B, S = 2, 64
    batch = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(
            RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    params = api.init(jax.random.key(0))
    s0 = RunSettings(attn_impl="xla", attn_chunk=64,
                     param_dtype="float32", ce_chunk=0)
    s1 = RunSettings(attn_impl="xla", attn_chunk=64,
                     param_dtype="float32", ce_chunk=16)
    (l0, _), g0 = jax.value_and_grad(api.loss, has_aux=True)(
        params, batch, s0)
    (l1, _), g1 = jax.value_and_grad(api.loss, has_aux=True)(
        params, batch, s1)
    assert abs(float(l0) - float(l1)) < 1e-5
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_ce_chunk_ignored_when_not_divisible():
    cfg = dataclasses.replace(small_gpt(128, 2), dtype="float32")
    api = build_model(cfg)
    B, S = 2, 60                       # not divisible by 16
    batch = {"tokens": jnp.zeros((B, S), jnp.int32),
             "labels": jnp.zeros((B, S), jnp.int32)}
    params = api.init(jax.random.key(0))
    s = RunSettings(attn_impl="xla", attn_chunk=64,
                    param_dtype="float32", ce_chunk=16)
    loss, _ = api.loss(params, batch, s)
    assert np.isfinite(float(loss))
