"""Aligned, reusable host buffers for the spool's zero-copy data plane.

MemAscend (arXiv 2505.23254) measures host-memory churn — fresh multi-MB
allocations page-faulted on first touch, then thrown away per store —
as a first-order bottleneck for SSD-offloaded training. The pool fixes
that: page-aligned `mmap` buffers in power-of-two size classes, leased
per I/O job and returned for reuse, so the steady-state store/load loop
performs zero large allocations. Page alignment (4 KiB) is also exactly
what `O_DIRECT` file descriptors require, so one pool serves both the
buffered and the direct-I/O backends.

Leases are explicit (`PooledBuffer.release()`), not GC-driven: a load's
deserialized views borrow the buffer until the spool record is dropped,
and releasing on finalizer time would hand the buffer to a new writer
while those views are still readable.
"""
from __future__ import annotations

import mmap
import threading
from typing import Dict, List, Optional

from repro import obs

#: O_DIRECT-compatible default: one x86 page / the common LBA-format size.
DEFAULT_ALIGNMENT = 4096


def _size_class(nbytes: int, alignment: int) -> int:
    """Smallest power-of-two multiple of `alignment` holding `nbytes`.

    Power-of-two classes bound internal waste at 2x and keep the free
    lists short; every class >= alignment is a multiple of it, so any
    align-rounded write length fits the leased capacity."""
    cap = alignment
    while cap < nbytes:
        cap <<= 1
    return cap


class PooledBuffer:
    """One leased buffer. `mv` is the full-capacity writable memoryview
    (page-aligned base); `data` is the first `nbytes` of it. Release
    returns the buffer to the pool — idempotent, and mandatory before
    the memory can be reused."""

    __slots__ = ("_pool", "_mm", "mv", "capacity", "nbytes", "_released")

    def __init__(self, pool: "AlignedBufferPool", mm: mmap.mmap,
                 capacity: int, nbytes: int):
        self._pool = pool
        self._mm = mm
        self.mv = memoryview(mm)
        self.capacity = capacity
        self.nbytes = nbytes
        self._released = False

    @property
    def data(self) -> memoryview:
        return self.mv[:self.nbytes]

    def release(self) -> None:
        if self._released:
            return
        self._released = True
        self.mv.release()
        self.mv = None
        self._pool._put_back(self._mm, self.capacity)
        self._mm = None

    def __enter__(self) -> "PooledBuffer":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.release()


class AlignedBufferPool:
    """Thread-safe pool of page-aligned buffers in power-of-two size
    classes. `max_bytes` caps the *idle* (free-list) footprint — leased
    bytes are whatever the callers hold; buffers returned beyond the cap
    are freed instead of cached."""

    def __init__(self, *, alignment: int = DEFAULT_ALIGNMENT,
                 max_bytes: int = 256 << 20):
        if alignment <= 0 or (alignment & (alignment - 1)):
            raise ValueError(f"alignment must be a power of two, "
                             f"got {alignment}")
        if alignment > mmap.PAGESIZE:
            # mmap guarantees page alignment and no more; a stricter
            # requirement would need manual over-allocate-and-trim
            raise ValueError(
                f"alignment {alignment} exceeds the page size "
                f"{mmap.PAGESIZE} that mmap-backed buffers guarantee")
        self.alignment = alignment
        self.max_bytes = max_bytes
        self._lock = threading.Lock()
        self._free: Dict[int, List[mmap.mmap]] = {}
        self._free_bytes = 0
        self.hits = 0
        self.misses = 0
        self.trimmed = 0            # returns dropped over max_bytes
        self.bytes_allocated = 0    # lifetime mmap volume (miss cost)

    def acquire(self, nbytes: int) -> PooledBuffer:
        """Lease a buffer of capacity >= max(nbytes, alignment)."""
        cap = _size_class(max(nbytes, 1), self.alignment)
        with self._lock:
            bucket = self._free.get(cap)
            if bucket:
                mm = bucket.pop()
                self._free_bytes -= cap
                self.hits += 1
                obs.count("pool.hit")
                return PooledBuffer(self, mm, cap, nbytes)
            self.misses += 1
            self.bytes_allocated += cap
        obs.count("pool.miss")
        # a miss is a fresh mmap whose pages fault on first touch — the
        # exact churn MemAscend measures, so it earns a timeline mark
        obs.instant("pool.miss", cat="pool", bytes=cap)
        # mmap outside the lock: faulting fresh pages is the slow part
        return PooledBuffer(self, mmap.mmap(-1, cap), cap, nbytes)

    def _put_back(self, mm: mmap.mmap, cap: int) -> None:
        with self._lock:
            if self._free_bytes + cap <= self.max_bytes:
                self._free.setdefault(cap, []).append(mm)
                self._free_bytes += cap
                return
            self.trimmed += 1
        obs.count("pool.trim")
        try:
            mm.close()
        except BufferError:
            pass    # borrower still holds a view; GC reclaims the map

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    @property
    def free_bytes(self) -> int:
        with self._lock:
            return self._free_bytes

    def stats(self) -> Dict[str, float]:
        return {
            "hits": self.hits, "misses": self.misses,
            "hit_rate": round(self.hit_rate, 4),
            "trimmed": self.trimmed,
            "free_bytes": self.free_bytes,
            "bytes_allocated": self.bytes_allocated,
            "alignment": self.alignment,
        }

    def close(self) -> None:
        """Free every idle buffer (leased ones are released by their
        holders)."""
        with self._lock:
            buckets, self._free = self._free, {}
            self._free_bytes = 0
        for bucket in buckets.values():
            for mm in bucket:
                try:
                    mm.close()
                except BufferError:
                    # a borrower still holds a zero-copy view of this
                    # buffer; dropping our reference is enough — the map
                    # is reclaimed when the last view dies
                    pass
