"""O_DIRECT-style direct I/O backend with pooled aligned buffers and
depth-N submission (`repro.io.aio`).

Why the buffered `fs` backend cannot saturate a device: every buffered
write costs one extra memcpy into the page cache, competes with dirty-
page writeback throttling, and — worst for this repo's methodology —
makes `calibrate_backend` measure *memcpy* bandwidth, so the adaptive
planner plans against a number the device never delivers (MemAscend,
arXiv 2505.23254, measures exactly this host-side churn as the offload
ceiling). This backend:

  * stages each blob once into a 4 KiB-aligned `AlignedBufferPool`
    buffer (reused across jobs — zero steady-state allocations),
  * writes it through an `O_DIRECT` descriptor, bypassing the page
    cache entirely, split into `queue_depth` aligned segments submitted
    concurrently so the device sees real queue depth (GreedySnake,
    arXiv 2512.17570: overlap quality is won in the host I/O engine's
    submission discipline),
  * reads scatter straight into the caller's pooled buffer, with an
    aligned bounce only when the caller's buffer is not itself aligned.

Filesystems that reject `O_DIRECT` (some overlay/network mounts) are
detected by a one-block probe at construction; the backend then falls
back to buffered I/O plus `fdatasync` + `posix_fadvise(DONTNEED)`, which
keeps measured bandwidth the device's and the page cache unpolluted,
just with one extra kernel copy.

Writes overwrite the key's file in place (no temp+rename): spool keys
are reused every training step, and overwriting allocated extents is
measurably faster under O_DIRECT than re-allocating them through a
truncate or rename. The trade is crash atomicity — a blob torn by a
crash is *detected* (serde's container and truncation guards reject it)
rather than prevented; residuals are per-step ephemera, unlike
checkpoints, so detection is the right cost point. The `fs` backend
keeps rename-atomicity for callers that want it.
"""
from __future__ import annotations

import os
from concurrent.futures import ThreadPoolExecutor, wait
from typing import List, Optional

import numpy as np

from repro.io.backend import (StorageBackend, as_memoryviews,
                              pwritev_all, register_backend)
from repro.io.bufpool import DEFAULT_ALIGNMENT, AlignedBufferPool


def _align_up(n: int, alignment: int) -> int:
    return -(-n // alignment) * alignment


def _is_aligned(mv: memoryview, alignment: int) -> bool:
    """O_DIRECT needs the *memory address* aligned, not just the
    length. numpy exposes the address portably for any buffer."""
    if len(mv) == 0:
        return True
    return np.frombuffer(mv, dtype=np.uint8).ctypes.data % alignment == 0


@register_backend("aio")
class AioBackend(StorageBackend):
    """Direct-I/O blob store: one file per key in one directory, written
    and read through `O_DIRECT` descriptors from pooled aligned buffers
    with depth-N concurrent segment submission. See module docstring."""

    def __init__(self, directory: str, *,
                 alignment: int = DEFAULT_ALIGNMENT,
                 queue_depth: int = 4,
                 pool: Optional[AlignedBufferPool] = None,
                 pool_bytes: int = 256 << 20,
                 direct: Optional[bool] = None):
        super().__init__()
        if queue_depth < 1:
            raise ValueError("queue_depth must be >= 1")
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        self.alignment = alignment
        self.queue_depth = queue_depth
        self.pool = pool if pool is not None else \
            AlignedBufferPool(alignment=alignment, max_bytes=pool_bytes)
        self._owns_pool = pool is None
        self._ex = (ThreadPoolExecutor(max_workers=queue_depth,
                                       thread_name_prefix="aio-seg")
                    if queue_depth > 1 else None)
        #: True when the directory's filesystem accepted an O_DIRECT
        #: write; False -> buffered + fdatasync + fadvise(DONTNEED)
        self.direct = self._probe_direct() if direct is None else \
            bool(direct)

    # ---------------------------------------------------------- probing

    def _probe_direct(self) -> bool:
        if not hasattr(os, "O_DIRECT"):
            return False
        probe = os.path.join(self.directory,
                             f".o_direct_probe.{os.getpid()}")
        try:
            fd = os.open(probe,
                         os.O_WRONLY | os.O_CREAT | os.O_DIRECT, 0o644)
        except OSError:
            return False
        try:
            # opening can succeed where the actual transfer fails
            # (overlayfs historically) — probe one real aligned block
            with self.pool.acquire(self.alignment) as lease:
                os.pwrite(fd, lease.mv[:self.alignment], 0)
            return True
        except OSError:
            return False
        finally:
            os.close(fd)
            try:
                os.unlink(probe)
            except OSError:
                pass

    # ------------------------------------------------------------ paths

    def _path(self, key: str) -> str:
        return os.path.join(self.directory, f"{key}.act")

    def _segments(self, nbytes: int) -> List[tuple]:
        """Split [0, nbytes) into up to queue_depth aligned spans."""
        if nbytes <= 0:
            return []
        seg = _align_up(-(-nbytes // self.queue_depth), self.alignment)
        return [(off, min(seg, nbytes - off))
                for off in range(0, nbytes, seg)]

    def _submit_all(self, fn, segs: List[tuple]) -> List:
        """Run one I/O callable per segment on the executor and wait for
        EVERY future before surfacing the first failure. `list(map(...))`
        would re-raise immediately while sibling threads still hold the
        fd — closing it then lets the OS recycle the descriptor under a
        still-running pwritev, i.e. cross-blob corruption."""
        futures = [self._ex.submit(fn, s) for s in segs]
        wait(futures)
        for f in futures:
            exc = f.exception()
            if exc is not None:
                raise exc
        return [f.result() for f in futures]

    # ----------------------------------------------------------- writes

    def _write(self, key: str, data: bytes) -> None:
        self._write_parts(key, as_memoryviews([data]))

    def _write_parts(self, key: str, parts: List[memoryview]) -> None:
        nbytes = sum(len(p) for p in parts)
        path = self._path(key)
        lease = self.pool.acquire(_align_up(nbytes, self.alignment))
        try:
            # the single staging copy, through numpy (its memcpy is ~2x
            # CPython's memoryview slice-assign on multi-MB spans)
            dst = np.frombuffer(lease.mv, dtype=np.uint8)
            off = 0
            for p in parts:
                n = len(p)
                dst[off:off + n] = np.frombuffer(p, dtype=np.uint8)
                off += n
            self._note_copy(nbytes)
            mv = lease.mv
            padded = _align_up(nbytes, self.alignment) if self.direct \
                else nbytes
            # In-place overwrite, no O_TRUNC: spool keys are reused
            # every step, and overwriting allocated extents is ~20%
            # faster than re-allocating them under O_DIRECT (truncate
            # frees them; tmp+rename never reuses them). The final
            # ftruncate trims both the alignment padding and any longer
            # previous lease of the key. Crash mid-write leaves a
            # hybrid blob, which serde's truncation/format guards
            # reject on restart — ephemeral residuals, unlike
            # checkpoints, never need rename-atomicity.
            flags = os.O_WRONLY | os.O_CREAT
            if self.direct:
                flags |= os.O_DIRECT
            fd = os.open(path, flags, 0o644)
            try:
                segs = self._segments(padded)
                if self._ex is not None and len(segs) > 1:
                    self._submit_all(
                        lambda s: pwritev_all(fd, [mv[s[0]:s[0] + s[1]]],
                                              s[0]), segs)
                elif padded:
                    pwritev_all(fd, [mv[:padded]])
                os.ftruncate(fd, nbytes)
                if not self.direct:
                    # buffered fallback: push to the device and evict
                    # the cached pages, so measured bandwidth stays the
                    # device's and the cache stays clean
                    os.fdatasync(fd)
                    os.posix_fadvise(fd, 0, 0, os.POSIX_FADV_DONTNEED)
            finally:
                os.close(fd)
        except BaseException:
            try:
                os.unlink(path)
            except OSError:
                pass
            raise
        finally:
            lease.release()

    # ------------------------------------------------------------ reads

    @staticmethod
    def _pread_seg(fd: int, target: memoryview, off: int, length: int,
                   eof: int) -> int:
        """Read [off, off+length) tolerating the short read at EOF —
        O_DIRECT lets us *request* past EOF with aligned counts but a
        retry at the resulting unaligned offset would EINVAL, so the
        usual fill-the-buffer loop cannot be used here. Returns the
        bytes that actually belong to the blob."""
        got = 0
        while got < length and off + got < eof:
            n = os.preadv(fd, [target[off + got:off + length]],
                          off + got)
            if n <= 0:
                break
            got += n
        return min(got, max(0, eof - off))

    def _readinto(self, key: str, buf: memoryview) -> int:
        try:
            fd = os.open(self._path(key),
                         os.O_RDONLY
                         | (os.O_DIRECT if self.direct else 0))
        except FileNotFoundError:
            raise FileNotFoundError(key) from None
        bounce = None
        try:
            nbytes = os.fstat(fd).st_size
            if nbytes > len(buf):
                raise ValueError(f"buffer of {len(buf)} bytes cannot "
                                 f"hold {nbytes}-byte blob {key!r}")
            padded = _align_up(nbytes, self.alignment)
            target = buf
            if self.direct and (len(buf) < padded
                                or not _is_aligned(buf, self.alignment)):
                # pooled aligned bounce; pool capacities are alignment
                # multiples, so `padded` always fits
                bounce = self.pool.acquire(padded)
                target = bounce.mv
            request = padded if self.direct else nbytes
            segs = self._segments(request)
            if self._ex is not None and len(segs) > 1:
                got = sum(self._submit_all(
                    lambda s: self._pread_seg(fd, target, s[0], s[1],
                                              nbytes), segs))
            else:
                got = self._pread_seg(fd, target, 0, request, nbytes)
            if got != nbytes:
                raise OSError(f"short read of {key!r}: "
                              f"{got}/{nbytes} bytes")
            if bounce is not None:
                buf[:nbytes] = bounce.mv[:nbytes]
                self._note_copy(nbytes)
            return nbytes
        finally:
            if bounce is not None:
                bounce.release()
            os.close(fd)

    def _read(self, key: str) -> bytes:
        n = self._size(key)
        if n is None:
            raise FileNotFoundError(key)
        with self.pool.acquire(_align_up(n, self.alignment)) as lease:
            got = self._readinto(key, lease.mv)
            self._note_copy(got)
            return bytes(lease.mv[:got])

    # ------------------------------------------------------------- misc

    def _size(self, key: str) -> Optional[int]:
        try:
            return os.stat(self._path(key)).st_size
        except OSError:
            return None

    def _delete(self, key: str) -> None:
        try:
            os.unlink(self._path(key))
        except OSError:
            pass

    def close(self) -> None:
        if self._ex is not None:
            self._ex.shutdown(wait=True)
            self._ex = None
        if self._owns_pool:
            self.pool.close()
        super().close()
