"""repro.io — pluggable tiered storage backends for the activation spool.

Layering (bottom up):

  serde    arrays <-> bytes (writable on the way back)
  codecs   bytes <-> bytes (raw / zlib), self-describing container
  backend  StorageBackend interface + IoStats + registry
  backends fs | striped | mem | tiered implementations
  factory  SpoolIoConfig / spec-string -> backend construction

`core/spool.py` composes these: serialize -> pack(codec) -> backend.write
on the store path, and the inverse on load.
"""
from repro.io.backend import (BACKENDS, NOMINAL_WRITE_BW, IoStats,
                              StorageBackend, get_backend_cls,
                              register_backend)
from repro.io.backends import (FilesystemBackend, HostMemoryBackend,
                               StripedBackend, TieredBackend)
from repro.io.codecs import (CODECS, Codec, RawCodec, ZlibCodec,
                             get_codec, pack, pack_parts, register_codec,
                             unpack)
from repro.io.factory import backend_from_spec, build_backend, parse_bytes
from repro.io.serde import (deserialize_leaves, serialize_leaves,
                            serialize_parts)

__all__ = [
    "BACKENDS", "NOMINAL_WRITE_BW", "IoStats", "StorageBackend",
    "get_backend_cls", "register_backend",
    "FilesystemBackend", "HostMemoryBackend", "StripedBackend",
    "TieredBackend",
    "CODECS", "Codec", "RawCodec", "ZlibCodec", "get_codec", "pack",
    "pack_parts", "register_codec", "unpack",
    "backend_from_spec", "build_backend", "parse_bytes",
    "deserialize_leaves", "serialize_leaves", "serialize_parts",
]
