"""repro.io — pluggable tiered storage backends for the activation spool.

Layering (bottom up):

  serde    arrays <-> bytes parts (zero-copy out, view-or-copy back)
  codecs   bytes <-> bytes (raw / zlib / byteplane), self-describing
           vectored container (`encode_parts`)
  bufpool  aligned reusable host buffers (the anti-churn layer)
  backend  StorageBackend interface (write/write_parts/read/readinto)
           + IoStats (incl. copy accounting) + registry
  backends fs | striped | mem | tiered implementations
  aio      O_DIRECT-style direct I/O with depth-N submission
  factory  SpoolIoConfig / spec-string -> backend construction

The `managed` backend kind (the class- and reuse-distance-aware
storage brain over the same stores) lives in `repro.cache.manager` and
registers itself here; `tiered`'s placement protocol is the static
configuration of the shared `repro.cache.placement.PlacementEngine`.


`core/spool.py` composes these: serialize_parts -> encode_parts(codec)
-> backend.write_parts on the store path (zero payload copies for the
raw codec on vectored backends), and readinto a pooled buffer ->
deserialize_leaves(copy=False) views on the load path.
"""
from repro.io.aio import AioBackend
from repro.io.backend import (BACKENDS, NOMINAL_WRITE_BW, IoStats,
                              StorageBackend, as_memoryviews,
                              get_backend_cls, preadv_all, pwritev_all,
                              register_backend)
from repro.io.backends import (FilesystemBackend, HostMemoryBackend,
                               StripedBackend, TieredBackend)
from repro.io.bufpool import AlignedBufferPool, PooledBuffer
from repro.io.codecs import (CODECS, BytePlaneCodec, Codec, RawCodec,
                             ZlibCodec, encode_parts, get_codec, pack,
                             pack_parts, register_codec, unpack,
                             unpack_aliased)
from repro.io.factory import backend_from_spec, build_backend, parse_bytes
from repro.io.faults import FaultInjectingBackend
from repro.io.serde import (deserialize_leaves, serialize_leaves,
                            serialize_parts)

__all__ = [
    "BACKENDS", "NOMINAL_WRITE_BW", "IoStats", "StorageBackend",
    "get_backend_cls", "register_backend", "as_memoryviews",
    "preadv_all", "pwritev_all",
    "AioBackend", "FaultInjectingBackend", "FilesystemBackend",
    "HostMemoryBackend", "StripedBackend", "TieredBackend",
    "AlignedBufferPool", "PooledBuffer",
    "CODECS", "BytePlaneCodec", "Codec", "RawCodec", "ZlibCodec",
    "encode_parts", "get_codec", "pack", "pack_parts", "register_codec",
    "unpack", "unpack_aliased",
    "CacheConfig", "CacheManager",
    "backend_from_spec", "build_backend", "parse_bytes",
    "deserialize_leaves", "serialize_leaves", "serialize_parts",
]


def __getattr__(name):
    # lazy re-export: repro.cache.manager imports repro.io.backend, so
    # an eager import here would cycle whenever repro.cache loads first
    if name in ("CacheConfig", "CacheManager"):
        from repro.cache import manager
        return getattr(manager, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
