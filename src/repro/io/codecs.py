"""Pluggable byte codecs for spooled activation blobs.

Replaces the spool's implicit raw-bytes format with a self-describing
container: `pack` prefixes the encoded payload with a magic tag and the
codec name, so `unpack` needs no out-of-band knowledge — a spool can be
reconfigured between write and read, and mixed-codec directories stay
readable. Codecs trade CPU for PCIe/SSD bandwidth (the knob the paper's
§3.4 WAF analysis motivates: fewer bytes written is both faster on a
saturated link and linearly more SSD lifespan).

The container is *vectored*: `encode_parts` returns a part list that
the storage backends scatter to the device with `write_parts`, so the
raw codec adds zero payload copies to the store path. Compressing
codecs necessarily materialize their output; `byteplane` is the
bf16/fp16-aware one — it shuffles 2-byte floats into exponent and
mantissa byte planes and DEFLATEs only the compressible (sign+exponent)
plane, chunked so one blob's chunks encode in parallel across a shared
worker pool.
"""
from __future__ import annotations

import abc
import os
import struct
import threading
import zlib
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Type, Union

import numpy as np

_MAGIC = b"RIO1"


class Codec(abc.ABC):
    #: registry key, set by @register_codec
    name: str = "?"

    @abc.abstractmethod
    def encode(self, data) -> bytes: ...

    @abc.abstractmethod
    def decode(self, data): ...


CODECS: Dict[str, Type[Codec]] = {}


def register_codec(name: str):
    def deco(cls: Type[Codec]) -> Type[Codec]:
        cls.name = name
        CODECS[name] = cls
        return cls
    return deco


def get_codec(codec: Union[str, Codec, None]) -> Codec:
    if codec is None:
        return RawCodec()
    if isinstance(codec, Codec):
        return codec
    try:
        return CODECS[codec]()
    except KeyError:
        raise KeyError(f"unknown codec {codec!r}; "
                       f"registered: {sorted(CODECS)}") from None


@register_codec("raw")
class RawCodec(Codec):
    def encode(self, data):
        return data

    def decode(self, data):
        return data


@register_codec("zlib")
class ZlibCodec(Codec):
    """stdlib DEFLATE. Level 1 by default: activation tensors are mostly
    low-entropy mantissa noise, so higher levels cost CPU for little
    extra ratio on the store path."""

    def __init__(self, level: int = 1):
        self.level = level

    def encode(self, data) -> bytes:
        return zlib.compress(data, self.level)

    def decode(self, data) -> bytearray:
        # bytearray, not bytes: the spool deserializes decode output
        # into zero-copy views, and a writable backing buffer lets
        # fetch's copy-on-demand skip a redundant memcpy (bytes-backed
        # views are read-only no matter what the caller intends)
        return bytearray(zlib.decompress(data))


# ------------------------------------------------------------ byteplane

# shared chunk-encode pool: zlib releases the GIL, so one blob's chunks
# really compress in parallel, and a process-wide pool keeps the thread
# count bounded no matter how many spool store workers hold codecs
_PLANE_EX: Optional[ThreadPoolExecutor] = None
_PLANE_EX_LOCK = threading.Lock()


def _plane_executor() -> ThreadPoolExecutor:
    global _PLANE_EX
    with _PLANE_EX_LOCK:
        if _PLANE_EX is None:
            _PLANE_EX = ThreadPoolExecutor(
                max_workers=min(8, os.cpu_count() or 1),
                thread_name_prefix="byteplane")
        return _PLANE_EX


@register_codec("byteplane")
class BytePlaneCodec(Codec):
    """Byte-plane shuffle + selective DEFLATE for 2-byte float payloads.

    bf16/fp16 activations are (little-endian) `[mantissa-low,
    sign|exponent-high]` byte pairs: the high plane is a handful of
    distinct values per tensor (low entropy — residual magnitudes
    cluster), the low plane is mantissa noise DEFLATE cannot touch.
    zlib over the interleaved stream wastes its window re-discovering
    that; splitting the planes and compressing ONLY the high plane gets
    a better ratio at half the DEFLATE input — measurably better ratio
    *and* throughput than `zlib` on real residuals.

    The payload is processed in `chunk_bytes` chunks, each shuffled and
    deflated independently on a shared worker pool (parallel encode for
    large blobs, bounded scratch memory), with a per-chunk raw escape
    hatch when DEFLATE does not pay (fp32-heavy or random chunks).

    Container: ``BPL1 | u8 level | u64 total | u32 nchunks`` then per
    chunk ``u8 flag | u32 clen | u32 hi_len`` + payload (flag 0: clen
    raw bytes; flag 1: ceil(clen/2) low-plane bytes + hi_len deflated
    high-plane bytes). Lossless for every dtype — fp32 payloads just
    land on the raw escape more often.
    """

    MAGIC = b"BPL1"
    _HEAD = struct.Struct("<BQI")       # level, total bytes, nchunks
    _CHUNK = struct.Struct("<BII")      # flag, clen, hi_len

    def __init__(self, level: int = 1, chunk_bytes: int = 1 << 20,
                 parallel: bool = True):
        if chunk_bytes <= 0:
            raise ValueError("chunk_bytes must be positive")
        self.level = level
        self.chunk_bytes = chunk_bytes
        self.parallel = parallel

    # ------------------------------------------------------------ encode

    def _encode_chunk(self, chunk: np.ndarray):
        lo = np.ascontiguousarray(chunk[0::2])
        hi = np.ascontiguousarray(chunk[1::2])
        comp = zlib.compress(hi, self.level)
        if len(comp) >= hi.nbytes:
            # incompressible high plane: store the chunk verbatim (the
            # shuffle alone buys nothing and costs a decode pass)
            return (0, chunk, b"")
        return (1, lo, comp)

    def _map(self, fn, jobs: List):
        if self.parallel and len(jobs) > 1:
            return list(_plane_executor().map(fn, jobs))
        return [fn(j) for j in jobs]

    def encode(self, data) -> bytes:
        arr = np.frombuffer(data, dtype=np.uint8)
        n = arr.nbytes
        chunks = [arr[o:o + self.chunk_bytes]
                  for o in range(0, n, self.chunk_bytes)] or \
                 [arr]                   # one empty chunk for n == 0
        encoded = self._map(self._encode_chunk, chunks)
        out: List[bytes] = [self.MAGIC,
                            self._HEAD.pack(self.level, n, len(chunks))]
        for (flag, first, comp), chunk in zip(encoded, chunks):
            out.append(self._CHUNK.pack(flag, chunk.nbytes, len(comp)))
            # .data: hand the plane to the final join as a view, not a
            # fresh bytes object (the join is the single output copy)
            out.append(first.data if isinstance(first, np.ndarray)
                       else first)
            if flag:
                out.append(comp)
        return b"".join(out)

    # ------------------------------------------------------------ decode

    def decode(self, data) -> memoryview:
        mv = data if isinstance(data, memoryview) else memoryview(data)
        if mv.itemsize != 1 or mv.ndim != 1:
            mv = mv.cast("B")
        if bytes(mv[:4]) != self.MAGIC:
            raise ValueError("not a byteplane payload")
        _, total, nchunks = self._HEAD.unpack_from(mv, 4)
        out = np.empty(total, dtype=np.uint8)
        jobs = []
        off = 4 + self._HEAD.size
        start = 0
        for _ in range(nchunks):
            flag, clen, hi_len = self._CHUNK.unpack_from(mv, off)
            off += self._CHUNK.size
            first_len = clen if flag == 0 else clen - clen // 2
            jobs.append((flag, start, clen,
                         mv[off:off + first_len],
                         mv[off + first_len:off + first_len + hi_len]))
            off += first_len + hi_len
            start += clen
        if start != total:
            raise ValueError("corrupt byteplane container")

        def dec(job):
            flag, start, clen, first, comp = job
            dst = out[start:start + clen]
            if flag == 0:
                dst[:] = np.frombuffer(first, dtype=np.uint8)
            else:
                dst[0::2] = np.frombuffer(first, dtype=np.uint8)
                dst[1::2] = np.frombuffer(zlib.decompress(comp),
                                          dtype=np.uint8)
            return None

        self._map(dec, jobs)
        # memoryview keeps `out` alive; zero-copy handoff to serde
        return out.data


# ------------------------------------------------------------ container


def pack(payload, codec: Union[str, Codec, None] = None) -> bytes:
    """magic | u8 name length | codec name | encoded payload."""
    return pack_parts([payload], codec)


def encode_parts(parts, codec: Union[str, Codec, None] = None) -> List:
    """The self-describing container as a part list: header parts plus
    the encoded payload. The raw codec passes the payload parts through
    untouched — with a vectored backend (`write_parts`) the store path
    then performs ZERO host-side payload copies. Compressing codecs
    join once (their scratch input) and contribute their output part."""
    c = get_codec(codec)
    name = c.name.encode("ascii")
    head: List = [_MAGIC, struct.pack("B", len(name)), name]
    if isinstance(c, RawCodec):
        return head + list(parts)
    return head + [c.encode(b"".join(
        p if isinstance(p, (bytes, bytearray, memoryview))
        else memoryview(p) for p in parts))]


def pack_parts(parts, codec: Union[str, Codec, None] = None) -> bytes:
    """`pack`, but over a list of bytes-like payload parts, joined once
    into a monolithic blob (the legacy non-vectored store path; the
    vectored path hands `encode_parts` straight to `write_parts`)."""
    return b"".join(bytes(p) if isinstance(p, memoryview) else p
                    for p in encode_parts(parts, codec))


def unpack_aliased(blob):
    """Inverse of `pack` as ``(payload, aliases_blob)``: the bool tells
    the caller whether `payload` borrows `blob`'s buffer (raw codec /
    container-less legacy blobs) or owns fresh memory (every decoding
    codec) — the spool uses it to release a pooled read buffer the
    moment nothing references it."""
    if bytes(blob[:len(_MAGIC)]) != _MAGIC:
        return blob, True               # passthrough borrows
    (nlen,) = struct.unpack_from("B", blob, len(_MAGIC))
    off = len(_MAGIC) + 1
    name = bytes(blob[off:off + nlen]).decode("ascii")
    codec = get_codec(name)
    payload = memoryview(blob)[off + nlen:]
    if isinstance(codec, RawCodec):
        return payload, True
    return codec.decode(payload), False


def unpack(blob):
    """Inverse of `pack`; blobs without the magic tag are passed through
    untouched (seed-format files stay readable). Raw-codec payloads come
    back as a zero-copy memoryview of `blob`."""
    return unpack_aliased(blob)[0]
