"""Pluggable byte codecs for spooled activation blobs.

Replaces the spool's implicit raw-bytes format with a self-describing
container: `pack` prefixes the encoded payload with a magic tag and the
codec name, so `unpack` needs no out-of-band knowledge — a spool can be
reconfigured between write and read, and mixed-codec directories stay
readable. Codecs trade CPU for PCIe/SSD bandwidth (the knob the paper's
§3.4 WAF analysis motivates: fewer bytes written is both faster on a
saturated link and linearly more SSD lifespan).
"""
from __future__ import annotations

import abc
import struct
import zlib
from typing import Dict, Type, Union

_MAGIC = b"RIO1"


class Codec(abc.ABC):
    #: registry key, set by @register_codec
    name: str = "?"

    @abc.abstractmethod
    def encode(self, data: bytes) -> bytes: ...

    @abc.abstractmethod
    def decode(self, data: bytes) -> bytes: ...


CODECS: Dict[str, Type[Codec]] = {}


def register_codec(name: str):
    def deco(cls: Type[Codec]) -> Type[Codec]:
        cls.name = name
        CODECS[name] = cls
        return cls
    return deco


def get_codec(codec: Union[str, Codec, None]) -> Codec:
    if codec is None:
        return RawCodec()
    if isinstance(codec, Codec):
        return codec
    try:
        return CODECS[codec]()
    except KeyError:
        raise KeyError(f"unknown codec {codec!r}; "
                       f"registered: {sorted(CODECS)}") from None


@register_codec("raw")
class RawCodec(Codec):
    def encode(self, data: bytes) -> bytes:
        return data

    def decode(self, data: bytes) -> bytes:
        return data


@register_codec("zlib")
class ZlibCodec(Codec):
    """stdlib DEFLATE. Level 1 by default: activation tensors are mostly
    low-entropy mantissa noise, so higher levels cost CPU for little
    extra ratio on the store path."""

    def __init__(self, level: int = 1):
        self.level = level

    def encode(self, data: bytes) -> bytes:
        return zlib.compress(data, self.level)

    def decode(self, data: bytes) -> bytes:
        return zlib.decompress(data)


def pack(payload: bytes, codec: Union[str, Codec, None] = None) -> bytes:
    """magic | u8 name length | codec name | encoded payload."""
    return pack_parts([payload], codec)


def pack_parts(parts, codec: Union[str, Codec, None] = None) -> bytes:
    """`pack`, but over a list of bytes-like payload parts: the raw
    codec joins container header and parts in one pass (no intermediate
    payload copy — the spool's hot store path)."""
    c = get_codec(codec)
    name = c.name.encode("ascii")
    head = [_MAGIC, struct.pack("B", len(name)), name]
    if isinstance(c, RawCodec):
        return b"".join(head + list(parts))
    return b"".join(head + [c.encode(b"".join(parts))])


def unpack(blob):
    """Inverse of `pack`; blobs without the magic tag are passed through
    untouched (seed-format files stay readable). Raw-codec payloads come
    back as a zero-copy memoryview of `blob`."""
    if bytes(blob[:len(_MAGIC)]) != _MAGIC:
        return blob
    (nlen,) = struct.unpack_from("B", blob, len(_MAGIC))
    off = len(_MAGIC) + 1
    name = bytes(blob[off:off + nlen]).decode("ascii")
    codec = get_codec(name)
    payload = memoryview(blob)[off + nlen:]
    return payload if isinstance(codec, RawCodec) \
        else codec.decode(payload)
