"""Storage-backend interface for the activation spool (`repro.io`).

The spool's I/O engine (core/spool.py) is backend-agnostic: it hands a
`StorageBackend` opaque byte blobs under string keys and gets them back.
Backends model the storage tiers of the paper's experimental setup and of
the tiered-cache related work (10Cache, MemAscend):

  * `FilesystemBackend` — one directory on one device (the seed behavior)
  * `StripedBackend`    — round-robin chunk striping across N directories
                          (the paper's multi-SSD array), with per-device
                          write accounting for endurance projection
  * `HostMemoryBackend` — CPU-RAM tier
  * `TieredBackend`     — host-RAM first under a byte budget, spilling to
                          a lower backend in backward-access order
  * `AioBackend`        — O_DIRECT-style direct I/O with an aligned
                          buffer pool and depth-N submission (repro.io.aio)

The data plane is vectored and copy-accounted: `write_parts` moves a
serde part list to the device without a monolithic join, `readinto`
fills a caller-owned (pooled) buffer instead of allocating a fresh blob,
and `IoStats.bytes_copied` counts every host-side payload copy the path
could not avoid, so copies-per-byte is a measured number rather than a
claim.

Every backend measures its own `IoStats` (bytes + wall time per
direction), which the adaptive-offloading planner consumes as per-tier
`TierBandwidth` entries instead of a single scalar.

Backends are registered under string keys (`register_backend`) so config
and CLI layers can select them declaratively (`build_backend`,
`backend_from_spec`).
"""
from __future__ import annotations

import abc
import errno
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Type

from repro import obs
from repro.core.adaptive import TierBandwidth

# ------------------------------------------------------- error taxonomy
#
# The spool's retry layer (repro.resilience) needs to know which backend
# failures are worth a second attempt. The split follows what actually
# recovers on real storage:
#
#   transient — the device is still there but momentarily unhappy
#               (interrupted syscall, contended queue, a flaky-media
#               EIO): a bounded retry with backoff routinely succeeds.
#   fatal     — retrying cannot help: the blob is gone (ENOENT), the
#               device is gone or read-only (ENODEV/EROFS/EACCES), the
#               filesystem is out of space (ENOSPC — freeing space is a
#               *placement* decision, not a retry), or the payload
#               itself is malformed (serde ValueError on a torn blob).

#: errno values a bounded retry may ride out
TRANSIENT_ERRNOS = frozenset({
    errno.EINTR, errno.EAGAIN, errno.EBUSY, errno.ETIMEDOUT, errno.EIO,
    errno.ENOBUFS, errno.ENOMEM,
})

#: errno values where retrying the same call is provably pointless
FATAL_ERRNOS = frozenset({
    errno.ENOENT, errno.ENOSPC, errno.ENODEV, errno.ENXIO, errno.EROFS,
    errno.EACCES, errno.EPERM, errno.EISDIR, errno.ENOTDIR,
})


def classify_io_error(exc: BaseException) -> str:
    """Classify a backend failure as ``"transient"`` or ``"fatal"``.

    Unknown `OSError`s without a listed errno default to transient (one
    retry is cheap; losing a step is not); everything that is not an
    OSError — serde ValueError on a truncated blob, KeyError, etc. —
    is fatal because the bytes themselves are wrong, not the device."""
    if isinstance(exc, FileNotFoundError):
        return "fatal"
    if isinstance(exc, OSError):
        if exc.errno in FATAL_ERRNOS:
            return "fatal"
        if exc.errno in TRANSIENT_ERRNOS:
            return "transient"
        return "transient"
    if isinstance(exc, (TimeoutError, InterruptedError)):
        return "transient"
    return "fatal"

# Nominal sequential-write bandwidths (bytes/s) per backend kind, used by
# dry-run projections when no measurement exists yet. fs: one datacenter
# NVMe; striped: the paper's 4x D7-P5810 array; mem/tiered: host DRAM
# reached over PCIe 4.0 x16.
NOMINAL_WRITE_BW: Dict[str, float] = {
    "fs": 2.0e9,
    "striped": 8.0e9,
    "mem": 20.0e9,
    "tiered": 20.0e9,
    # one NVMe reached over O_DIRECT: no page-cache double copy, so the
    # nominal rate is the device's, not the memcpy-throttled buffered one
    "aio": 3.0e9,
}


@dataclass
class IoStats:
    """Measured I/O volume and busy time for one backend (or one tier).

    write_time / read_time are *utilization clocks*: time during which at
    least one writer (reader) was inside the backend. Summing per-call
    wall times would overstate time N-fold under N concurrent spool
    threads and make measured bandwidth look N-fold worse than the
    device's — the adaptive planner would then underoffload."""
    bytes_written: int = 0
    bytes_read: int = 0
    write_time: float = 0.0
    read_time: float = 0.0
    num_writes: int = 0
    num_reads: int = 0
    num_deletes: int = 0
    # host-side payload copies the data plane could not avoid (joins,
    # bounce/staging buffers) — NOT the device transfer itself. The
    # vectored fs path runs at 0; the benchmark asserts <= 1 per byte.
    bytes_copied: int = 0

    @property
    def write_bandwidth(self) -> float:
        return self.bytes_written / self.write_time \
            if self.write_time else float("inf")

    @property
    def read_bandwidth(self) -> float:
        return self.bytes_read / self.read_time \
            if self.read_time else float("inf")

    def as_dict(self) -> Dict[str, float]:
        return {
            "bytes_written": self.bytes_written,
            "bytes_read": self.bytes_read,
            "write_time_s": self.write_time,
            "read_time_s": self.read_time,
            "num_writes": self.num_writes,
            "num_reads": self.num_reads,
            "write_gb_s": (self.write_bandwidth / 1e9
                           if self.write_time else None),
            "read_gb_s": (self.read_bandwidth / 1e9
                          if self.read_time else None),
            "bytes_copied": self.bytes_copied,
            "copies_per_byte": (
                self.bytes_copied
                / (self.bytes_written + self.bytes_read)
                if (self.bytes_written + self.bytes_read) else 0.0),
        }


def as_memoryviews(parts) -> List[memoryview]:
    """Normalize a part list to memoryviews without copying payloads.
    Multi-byte / multi-dimensional views are flattened to a byte view so
    `len(part)` is its byte length everywhere downstream."""
    out = []
    for p in parts:
        mv = p if isinstance(p, memoryview) else memoryview(p)
        if mv.itemsize != 1 or mv.ndim != 1:
            mv = mv.cast("B")
        out.append(mv)
    return out


# one iovec batch per syscall; Linux caps at sysconf(_SC_IOV_MAX) >= 1024
_IOV_MAX = 1024


def pwritev_all(fd: int, parts: List[memoryview], offset: int = 0) -> int:
    """`os.pwritev` the whole part list at `offset`, riding out partial
    writes and the IOV_MAX batch cap. Returns the end offset."""
    queue = [p for p in parts if len(p)]
    while queue:
        written = os.pwritev(fd, queue[:_IOV_MAX], offset)
        if written <= 0:
            raise OSError(f"pwritev stalled at offset {offset}")
        offset += written
        while queue and written >= len(queue[0]):
            written -= len(queue[0])
            queue.pop(0)
        if queue and written:
            queue[0] = queue[0][written:]
    return offset


def preadv_all(fd: int, buf: memoryview, offset: int = 0) -> int:
    """Fill `buf` from `fd` starting at `offset`; stops early only at
    EOF. Returns bytes read."""
    got = 0
    while got < len(buf):
        n = os.preadv(fd, [buf[got:]], offset + got)
        if n <= 0:
            break
        got += n
    return got


class StorageBackend(abc.ABC):
    """Key/value blob store with measured per-backend bandwidth.

    Subclasses implement `_write`/`_read`/`_delete`; the public methods
    wrap them with timing so `stats` is always populated. `delete` is
    missing-tolerant (dropping an un-spooled key is a no-op), matching
    the spool's unconditional `drop`.
    """

    #: registry key, set by @register_backend
    kind: str = "?"

    #: True when `read` hands back the stored blob itself with no copy
    #: (RAM-backed stores). Pooled loaders then skip the readinto
    #: staging buffer and deserialize straight over the returned blob.
    zero_copy_read: bool = False

    def __init__(self) -> None:
        self.stats = IoStats()
        self._stats_lock = threading.Lock()
        self._active = {"w": 0, "r": 0}
        self._window_start = {"w": 0.0, "r": 0.0}

    # ------------------------------------------------------- public API

    def _enter(self, side: str) -> None:
        with self._stats_lock:
            if self._active[side] == 0:
                self._window_start[side] = time.perf_counter()
            self._active[side] += 1

    def _exit(self, side: str) -> float:
        """Returns elapsed busy time to credit (0 while others are still
        inside the window)."""
        now = time.perf_counter()
        with self._stats_lock:
            self._active[side] -= 1
            if self._active[side] == 0:
                return now - self._window_start[side]
            return 0.0

    def write(self, key: str, data: bytes) -> None:
        self._enter("w")
        try:
            with obs.span("io.write", cat="io", key=key, kind=self.kind,
                          bytes=len(data)):
                self._write(key, data)
        except BaseException:
            self._exit("w")
            raise
        dt = self._exit("w")
        with self._stats_lock:
            self.stats.bytes_written += len(data)
            self.stats.write_time += dt
            self.stats.num_writes += 1

    def write_parts(self, key: str, parts) -> None:
        """Vectored write: the blob as a list of bytes-like parts, moved
        to the device without a monolithic ``b"".join``. Backends without
        a native scatter path fall back to one (counted) join."""
        parts = as_memoryviews(parts)
        nbytes = sum(len(p) for p in parts)
        self._enter("w")
        try:
            with obs.span("io.write", cat="io", key=key, kind=self.kind,
                          bytes=nbytes):
                self._write_parts(key, parts)
        except BaseException:
            self._exit("w")
            raise
        dt = self._exit("w")
        with self._stats_lock:
            self.stats.bytes_written += nbytes
            self.stats.write_time += dt
            self.stats.num_writes += 1

    def read(self, key: str) -> bytes:
        self._enter("r")
        try:
            with obs.span("io.read", cat="io", key=key,
                          kind=self.kind) as sp:
                data = self._read(key)
                sp.set(bytes=len(data))
        except BaseException:
            self._exit("r")
            raise
        dt = self._exit("r")
        with self._stats_lock:
            self.stats.bytes_read += len(data)
            self.stats.read_time += dt
            self.stats.num_reads += 1
        return data

    def readinto(self, key: str, buf) -> memoryview:
        """Read the blob into the caller's buffer (typically a pooled
        aligned one) and return the filled prefix as a memoryview —
        no per-load blob allocation. `buf` must be at least `size(key)`
        bytes."""
        mv = buf if isinstance(buf, memoryview) else memoryview(buf)
        self._enter("r")
        try:
            with obs.span("io.read", cat="io", key=key,
                          kind=self.kind) as sp:
                n = self._readinto(key, mv)
                sp.set(bytes=n)
        except BaseException:
            self._exit("r")
            raise
        dt = self._exit("r")
        with self._stats_lock:
            self.stats.bytes_read += n
            self.stats.read_time += dt
            self.stats.num_reads += 1
        return mv[:n]

    def size(self, key: str) -> Optional[int]:
        """Stored blob size in bytes, or None when the backend cannot
        answer without reading (callers then fall back to `read`)."""
        return self._size(key)

    def delete(self, key: str) -> None:
        self._delete(key)
        with self._stats_lock:
            self.stats.num_deletes += 1

    def flush(self) -> None:
        """Durability barrier; a no-op for backends without buffering."""

    def reset_stats(self) -> None:
        """Start a fresh measurement window (e.g. before a calibration
        burst, so tier bandwidths reflect only uncontended writes)."""
        with self._stats_lock:
            self.stats = IoStats()

    def calibrate(self, data: bytes, repeats: int = 2) -> None:
        """Measure write bandwidth with a synthetic burst: reset stats,
        write `repeats` copies of `data`, delete them. Composite
        backends override this to exercise *every* tier — a tier the
        burst never reaches would otherwise report infinite bandwidth
        and the planner would treat spill traffic as free."""
        self.reset_stats()
        for i in range(repeats):
            self.write(f"_calibrate{i}", data)
        for i in range(repeats):
            self.delete(f"_calibrate{i}")

    def close(self) -> None:
        self.flush()

    def tier_bandwidths(self) -> List[TierBandwidth]:
        """Measured per-tier write bandwidth for the adaptive planner.

        Flat backends report one unbounded tier; `TieredBackend`
        overrides this to expose its capacity-bounded upper tier plus
        the lower backend's tiers.
        """
        return [TierBandwidth(self.kind, self.stats.write_bandwidth, None)]

    # ---------------------------------------------------- to implement

    def _note_copy(self, nbytes: int) -> None:
        """Record an unavoidable host-side payload copy (join, bounce
        buffer) so copies-per-byte stays a measured quantity."""
        with self._stats_lock:
            self.stats.bytes_copied += nbytes

    def _write_parts(self, key: str, parts: List[memoryview]) -> None:
        """Default scatter path: join once (counted) and defer to
        `_write`. Backends with a real vectored path override this."""
        data = b"".join(parts)
        self._note_copy(len(data))
        self._write(key, data)

    def _readinto(self, key: str, buf: memoryview) -> int:
        """Default gather path: `_read` then one (counted) copy into the
        caller's buffer. Backends with a native scatter-read override."""
        data = self._read(key)
        n = len(data)
        if n > len(buf):
            raise ValueError(f"buffer of {len(buf)} bytes cannot hold "
                             f"{n}-byte blob {key!r}")
        buf[:n] = data
        self._note_copy(n)
        return n

    def _size(self, key: str) -> Optional[int]:
        return None

    @abc.abstractmethod
    def _write(self, key: str, data: bytes) -> None: ...

    @abc.abstractmethod
    def _read(self, key: str) -> bytes: ...

    @abc.abstractmethod
    def _delete(self, key: str) -> None: ...


# ---------------------------------------------------------------- registry

BACKENDS: Dict[str, Type[StorageBackend]] = {}


def register_backend(name: str):
    def deco(cls: Type[StorageBackend]) -> Type[StorageBackend]:
        cls.kind = name
        BACKENDS[name] = cls
        return cls
    return deco


def get_backend_cls(name: str) -> Type[StorageBackend]:
    if name == "managed" and name not in BACKENDS:
        # the cache manager registers itself on import; it lives in
        # repro.cache (which imports this module), so it cannot be
        # imported eagerly here
        import repro.cache.manager  # noqa: F401
    try:
        return BACKENDS[name]
    except KeyError:
        raise KeyError(f"unknown storage backend {name!r}; "
                       f"registered: {sorted(BACKENDS)}") from None
