"""Storage-backend interface for the activation spool (`repro.io`).

The spool's I/O engine (core/spool.py) is backend-agnostic: it hands a
`StorageBackend` opaque byte blobs under string keys and gets them back.
Backends model the storage tiers of the paper's experimental setup and of
the tiered-cache related work (10Cache, MemAscend):

  * `FilesystemBackend` — one directory on one device (the seed behavior)
  * `StripedBackend`    — round-robin chunk striping across N directories
                          (the paper's multi-SSD array), with per-device
                          write accounting for endurance projection
  * `HostMemoryBackend` — CPU-RAM tier
  * `TieredBackend`     — host-RAM first under a byte budget, spilling to
                          a lower backend in backward-access order

Every backend measures its own `IoStats` (bytes + wall time per
direction), which the adaptive-offloading planner consumes as per-tier
`TierBandwidth` entries instead of a single scalar.

Backends are registered under string keys (`register_backend`) so config
and CLI layers can select them declaratively (`build_backend`,
`backend_from_spec`).
"""
from __future__ import annotations

import abc
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Type

from repro.core.adaptive import TierBandwidth

# Nominal sequential-write bandwidths (bytes/s) per backend kind, used by
# dry-run projections when no measurement exists yet. fs: one datacenter
# NVMe; striped: the paper's 4x D7-P5810 array; mem/tiered: host DRAM
# reached over PCIe 4.0 x16.
NOMINAL_WRITE_BW: Dict[str, float] = {
    "fs": 2.0e9,
    "striped": 8.0e9,
    "mem": 20.0e9,
    "tiered": 20.0e9,
}


@dataclass
class IoStats:
    """Measured I/O volume and busy time for one backend (or one tier).

    write_time / read_time are *utilization clocks*: time during which at
    least one writer (reader) was inside the backend. Summing per-call
    wall times would overstate time N-fold under N concurrent spool
    threads and make measured bandwidth look N-fold worse than the
    device's — the adaptive planner would then underoffload."""
    bytes_written: int = 0
    bytes_read: int = 0
    write_time: float = 0.0
    read_time: float = 0.0
    num_writes: int = 0
    num_reads: int = 0
    num_deletes: int = 0

    @property
    def write_bandwidth(self) -> float:
        return self.bytes_written / self.write_time \
            if self.write_time else float("inf")

    @property
    def read_bandwidth(self) -> float:
        return self.bytes_read / self.read_time \
            if self.read_time else float("inf")

    def as_dict(self) -> Dict[str, float]:
        return {
            "bytes_written": self.bytes_written,
            "bytes_read": self.bytes_read,
            "write_time_s": self.write_time,
            "read_time_s": self.read_time,
            "num_writes": self.num_writes,
            "num_reads": self.num_reads,
            "write_gb_s": (self.write_bandwidth / 1e9
                           if self.write_time else None),
            "read_gb_s": (self.read_bandwidth / 1e9
                          if self.read_time else None),
        }


class StorageBackend(abc.ABC):
    """Key/value blob store with measured per-backend bandwidth.

    Subclasses implement `_write`/`_read`/`_delete`; the public methods
    wrap them with timing so `stats` is always populated. `delete` is
    missing-tolerant (dropping an un-spooled key is a no-op), matching
    the spool's unconditional `drop`.
    """

    #: registry key, set by @register_backend
    kind: str = "?"

    def __init__(self) -> None:
        self.stats = IoStats()
        self._stats_lock = threading.Lock()
        self._active = {"w": 0, "r": 0}
        self._window_start = {"w": 0.0, "r": 0.0}

    # ------------------------------------------------------- public API

    def _enter(self, side: str) -> None:
        with self._stats_lock:
            if self._active[side] == 0:
                self._window_start[side] = time.perf_counter()
            self._active[side] += 1

    def _exit(self, side: str) -> float:
        """Returns elapsed busy time to credit (0 while others are still
        inside the window)."""
        now = time.perf_counter()
        with self._stats_lock:
            self._active[side] -= 1
            if self._active[side] == 0:
                return now - self._window_start[side]
            return 0.0

    def write(self, key: str, data: bytes) -> None:
        self._enter("w")
        try:
            self._write(key, data)
        except BaseException:
            self._exit("w")
            raise
        dt = self._exit("w")
        with self._stats_lock:
            self.stats.bytes_written += len(data)
            self.stats.write_time += dt
            self.stats.num_writes += 1

    def read(self, key: str) -> bytes:
        self._enter("r")
        try:
            data = self._read(key)
        except BaseException:
            self._exit("r")
            raise
        dt = self._exit("r")
        with self._stats_lock:
            self.stats.bytes_read += len(data)
            self.stats.read_time += dt
            self.stats.num_reads += 1
        return data

    def delete(self, key: str) -> None:
        self._delete(key)
        with self._stats_lock:
            self.stats.num_deletes += 1

    def flush(self) -> None:
        """Durability barrier; a no-op for backends without buffering."""

    def reset_stats(self) -> None:
        """Start a fresh measurement window (e.g. before a calibration
        burst, so tier bandwidths reflect only uncontended writes)."""
        with self._stats_lock:
            self.stats = IoStats()

    def calibrate(self, data: bytes, repeats: int = 2) -> None:
        """Measure write bandwidth with a synthetic burst: reset stats,
        write `repeats` copies of `data`, delete them. Composite
        backends override this to exercise *every* tier — a tier the
        burst never reaches would otherwise report infinite bandwidth
        and the planner would treat spill traffic as free."""
        self.reset_stats()
        for i in range(repeats):
            self.write(f"_calibrate{i}", data)
        for i in range(repeats):
            self.delete(f"_calibrate{i}")

    def close(self) -> None:
        self.flush()

    def tier_bandwidths(self) -> List[TierBandwidth]:
        """Measured per-tier write bandwidth for the adaptive planner.

        Flat backends report one unbounded tier; `TieredBackend`
        overrides this to expose its capacity-bounded upper tier plus
        the lower backend's tiers.
        """
        return [TierBandwidth(self.kind, self.stats.write_bandwidth, None)]

    # ---------------------------------------------------- to implement

    @abc.abstractmethod
    def _write(self, key: str, data: bytes) -> None: ...

    @abc.abstractmethod
    def _read(self, key: str) -> bytes: ...

    @abc.abstractmethod
    def _delete(self, key: str) -> None: ...


# ---------------------------------------------------------------- registry

BACKENDS: Dict[str, Type[StorageBackend]] = {}


def register_backend(name: str):
    def deco(cls: Type[StorageBackend]) -> Type[StorageBackend]:
        cls.kind = name
        BACKENDS[name] = cls
        return cls
    return deco


def get_backend_cls(name: str) -> Type[StorageBackend]:
    try:
        return BACKENDS[name]
    except KeyError:
        raise KeyError(f"unknown storage backend {name!r}; "
                       f"registered: {sorted(BACKENDS)}") from None
