"""Fault-injecting storage backend (`repro.io.faults`).

Wraps any `StorageBackend` and injects the failure modes a resilient
spool must survive but a healthy CI box never produces on its own:

  * write failures       — the next `fail_writes` eligible writes raise
                           (`OSError` by default, e.g. ENOSPC), leaving
                           the blob unwritten so the spool's
                           failed-store forwarding / error surfacing
                           paths run;
  * short reads          — the next `short_reads` read/readinto calls
                           return `short_by` bytes fewer than the blob
                           holds, driving serde's truncation guards and
                           the load-worker's pool-lease cleanup;
  * delayed completion   — every write (read) sleeps `write_delay`
                           (`read_delay`) seconds first, widening the
                           in-flight windows that tensor forwarding,
                           store cancellation and orphaned-write
                           deletion race against.

Failures can be scoped to keys containing `fail_key_substr`, and armed
at runtime through `arm_write_failures` / `arm_short_reads`; `injected`
counts what actually fired. The wrapper is registered as backend kind
"fault" and constructible from a spec string — ``fault:<inner-spec>``
or ``fault@N:<inner-spec>`` (fail the first N writes), e.g.
``fault@2:mem`` — so the whole spool stack can be pointed at a faulty
device from config, exactly like any other `repro.io` backend.

The wrapper's own `IoStats` observe the *caller-visible* outcome
(failed writes are not counted as written bytes); the inner backend
keeps its own stats for the traffic that really reached it.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

from repro.io.backend import StorageBackend, register_backend


@register_backend("fault")
class FaultInjectingBackend(StorageBackend):
    """See module docstring. All delegation reaches the inner backend
    through its PUBLIC methods, so composite inners (striped / tiered /
    aio) keep their own vectored paths and accounting."""

    def __init__(self, inner: StorageBackend, *,
                 fail_writes: int = 0,
                 write_exc: Optional[BaseException] = None,
                 fail_key_substr: Optional[str] = None,
                 short_reads: int = 0,
                 short_by: int = 1,
                 write_delay: float = 0.0,
                 read_delay: float = 0.0):
        super().__init__()
        self.inner = inner
        self.write_delay = write_delay
        self.read_delay = read_delay
        self._flock = threading.Lock()
        self._fail_writes = int(fail_writes)
        self._write_exc = write_exc
        self._fail_key_substr = fail_key_substr
        self._short_reads = int(short_reads)
        self._short_by = int(short_by)
        self.injected: Dict[str, int] = {"write_failures": 0,
                                         "short_reads": 0}
        # mirror the inner's data-plane affordances so the spool makes
        # the same plumbing choices it would against the bare backend
        self.zero_copy_read = inner.zero_copy_read
        self.owned_tmpdirs = tuple(getattr(inner, "owned_tmpdirs", ()))

    @property
    def pool(self):
        return getattr(self.inner, "pool", None)

    @property
    def directory(self):
        return getattr(self.inner, "directory", None)

    # ----------------------------------------------------- arming knobs

    def arm_write_failures(self, n: int, *,
                           exc: Optional[BaseException] = None,
                           key_substr: Optional[str] = None) -> None:
        """The next `n` eligible writes raise."""
        with self._flock:
            self._fail_writes = int(n)
            if exc is not None:
                self._write_exc = exc
            self._fail_key_substr = key_substr

    def arm_short_reads(self, n: int, *, short_by: int = 1) -> None:
        """The next `n` reads come back `short_by` bytes truncated."""
        with self._flock:
            self._short_reads = int(n)
            self._short_by = int(short_by)

    # ------------------------------------------------------- injection

    def _maybe_fail_write(self, key: str) -> None:
        with self._flock:
            if self._fail_writes <= 0:
                return
            if self._fail_key_substr is not None \
                    and self._fail_key_substr not in key:
                return
            self._fail_writes -= 1
            self.injected["write_failures"] += 1
            exc = self._write_exc
        if exc is None:
            raise OSError(f"injected write failure for {key!r}")
        # fresh instance per injection: concurrent store workers must
        # not share one exception object (each raise rewrites its
        # __traceback__, corrupting the sibling's surfaced error)
        try:
            fresh = type(exc)(*exc.args)
        except TypeError:            # exotic ctor: fall back to sharing
            fresh = exc
        raise fresh

    def _shortfall(self) -> int:
        with self._flock:
            if self._short_reads <= 0:
                return 0
            self._short_reads -= 1
            self.injected["short_reads"] += 1
            return self._short_by

    # ------------------------------------------------------ delegation

    def _write(self, key: str, data: bytes) -> None:
        if self.write_delay:
            time.sleep(self.write_delay)
        self._maybe_fail_write(key)
        self.inner.write(key, data)

    def _write_parts(self, key: str, parts: List[memoryview]) -> None:
        if self.write_delay:
            time.sleep(self.write_delay)
        self._maybe_fail_write(key)
        self.inner.write_parts(key, parts)

    def _read(self, key: str) -> bytes:
        if self.read_delay:
            time.sleep(self.read_delay)
        data = self.inner.read(key)
        cut = self._shortfall()
        return data[:max(0, len(data) - cut)] if cut else data

    def _readinto(self, key: str, buf: memoryview) -> int:
        if self.read_delay:
            time.sleep(self.read_delay)
        n = len(self.inner.readinto(key, buf))
        cut = self._shortfall()
        return max(0, n - cut) if cut else n

    def _size(self, key: str) -> Optional[int]:
        return self.inner.size(key)

    def _delete(self, key: str) -> None:
        self.inner.delete(key)

    def flush(self) -> None:
        self.inner.flush()

    def tier_bandwidths(self):
        return self.inner.tier_bandwidths()

    def close(self) -> None:
        self.inner.close()
        super().close()
