"""Fault-injecting storage backend (`repro.io.faults`).

Wraps any `StorageBackend` and injects the failure modes a resilient
spool must survive but a healthy CI box never produces on its own:

  * write failures       — the next `fail_writes` eligible writes raise
                           (`OSError` by default, e.g. ENOSPC), leaving
                           the blob unwritten so the spool's
                           failed-store forwarding / retry / error
                           surfacing paths run;
  * raising reads        — the next `fail_reads` eligible read/readinto
                           calls raise (`read_exc`), driving the load
                           worker's retry and the engines'
                           recompute-fallback paths (a short read only
                           corrupts; a raising read is a device gone);
  * short reads          — the next `short_reads` read/readinto calls
                           return `short_by` bytes fewer than the blob
                           holds, driving serde's truncation guards and
                           the load-worker's pool-lease cleanup;
  * intermittent faults  — every write fails with probability
                           `intermittent_rate`, drawn from a *seeded*
                           RNG so chaos runs replay bit-for-bit;
  * ENOSPC after budget  — once `enospc_after_bytes` bytes have been
                           accepted, further writes raise
                           ``OSError(ENOSPC)``: a filling filesystem;
  * delayed completion   — every write (read) sleeps `write_delay`
                           (`read_delay`) seconds first, widening the
                           in-flight windows that tensor forwarding,
                           store cancellation and orphaned-write
                           deletion race against.

Failures can be scoped to keys containing `fail_key_substr` and — when
the inner chain contains a `StripedBackend` — to keys whose stripe
placement *starts* on device `device` (per-stripe-device scoping: kill
the traffic headed at one NVMe, leave its siblings alone). Arming
happens at runtime through `arm_write_failures` / `arm_read_failures` /
`arm_short_reads` / `arm_intermittent` / `arm_enospc`; `injected`
counts what actually fired. The wrapper is registered as backend kind
"fault" and constructible from a spec string — ``fault:<inner-spec>``
or ``fault@N:<inner-spec>`` (fail the first N writes), e.g.
``fault@2:mem`` — so the whole spool stack can be pointed at a faulty
device from config, exactly like any other `repro.io` backend.

The wrapper's own `IoStats` observe the *caller-visible* outcome
(failed writes are not counted as written bytes); the inner backend
keeps its own stats for the traffic that really reached it.
"""
from __future__ import annotations

import errno
import random
import threading
import time
from typing import Dict, List, Optional

from repro.io.backend import StorageBackend, register_backend


@register_backend("fault")
class FaultInjectingBackend(StorageBackend):
    """See module docstring. All delegation reaches the inner backend
    through its PUBLIC methods, so composite inners (striped / tiered /
    aio) keep their own vectored paths and accounting."""

    def __init__(self, inner: StorageBackend, *,
                 fail_writes: int = 0,
                 write_exc: Optional[BaseException] = None,
                 fail_key_substr: Optional[str] = None,
                 fail_reads: int = 0,
                 read_exc: Optional[BaseException] = None,
                 read_key_substr: Optional[str] = None,
                 short_reads: int = 0,
                 short_by: int = 1,
                 intermittent_rate: float = 0.0,
                 intermittent_seed: int = 0,
                 enospc_after_bytes: Optional[int] = None,
                 device: Optional[int] = None,
                 write_delay: float = 0.0,
                 read_delay: float = 0.0):
        super().__init__()
        self.inner = inner
        self.write_delay = write_delay
        self.read_delay = read_delay
        self._flock = threading.Lock()
        self._fail_writes = int(fail_writes)
        self._write_exc = write_exc
        self._fail_key_substr = fail_key_substr
        self._fail_reads = int(fail_reads)
        self._read_exc = read_exc
        self._read_key_substr = read_key_substr
        self._short_reads = int(short_reads)
        self._short_by = int(short_by)
        self._intermittent_rate = float(intermittent_rate)
        self._intermittent_exc: Optional[BaseException] = None
        self._rng = random.Random(intermittent_seed)
        self._enospc_after = enospc_after_bytes
        self._bytes_through = 0
        self._fail_device = device
        self.injected: Dict[str, int] = {"write_failures": 0,
                                         "read_failures": 0,
                                         "short_reads": 0,
                                         "intermittent_failures": 0,
                                         "enospc_failures": 0}
        # mirror the inner's data-plane affordances so the spool makes
        # the same plumbing choices it would against the bare backend
        self.zero_copy_read = inner.zero_copy_read
        self.owned_tmpdirs = tuple(getattr(inner, "owned_tmpdirs", ()))

    @property
    def pool(self):
        return getattr(self.inner, "pool", None)

    @property
    def directory(self):
        return getattr(self.inner, "directory", None)

    # ----------------------------------------------------- arming knobs

    def arm_write_failures(self, n: int, *,
                           exc: Optional[BaseException] = None,
                           key_substr: Optional[str] = None,
                           device: Optional[int] = None) -> None:
        """The next `n` eligible writes raise."""
        with self._flock:
            self._fail_writes = int(n)
            if exc is not None:
                self._write_exc = exc
            self._fail_key_substr = key_substr
            if device is not None:
                self._fail_device = device

    def arm_read_failures(self, n: int, *,
                          exc: Optional[BaseException] = None,
                          key_substr: Optional[str] = None,
                          device: Optional[int] = None) -> None:
        """The next `n` eligible read/readinto calls raise."""
        with self._flock:
            self._fail_reads = int(n)
            if exc is not None:
                self._read_exc = exc
            self._read_key_substr = key_substr
            if device is not None:
                self._fail_device = device

    def arm_short_reads(self, n: int, *, short_by: int = 1) -> None:
        """The next `n` reads come back `short_by` bytes truncated."""
        with self._flock:
            self._short_reads = int(n)
            self._short_by = int(short_by)

    def arm_intermittent(self, rate: float, *, seed: int = 0,
                         exc: Optional[BaseException] = None) -> None:
        """Each write fails with probability `rate` (seeded RNG)."""
        assert 0.0 <= rate <= 1.0
        with self._flock:
            self._intermittent_rate = float(rate)
            self._intermittent_exc = exc
            self._rng = random.Random(seed)

    def arm_enospc(self, after_bytes: int) -> None:
        """Writes raise ``OSError(ENOSPC)`` once `after_bytes` more
        bytes have been accepted through this wrapper."""
        with self._flock:
            self._enospc_after = self._bytes_through + int(after_bytes)

    # ------------------------------------------------------- injection

    def _on_fail_device(self, key: str) -> bool:
        """Per-stripe-device scoping: does `key`'s stripe placement
        start on the armed device? True when no device scope is set."""
        dev = self._fail_device
        if dev is None:
            return True
        b = self.inner
        while b is not None:
            if hasattr(b, "_device") and hasattr(b, "directories"):
                return b._device(key, 0) == dev
            b = getattr(b, "inner", None)
        return True  # no stripe inside: scope is vacuous

    @staticmethod
    def _fresh(exc: BaseException) -> BaseException:
        # fresh instance per injection: concurrent store workers must
        # not share one exception object (each raise rewrites its
        # __traceback__, corrupting the sibling's surfaced error)
        try:
            return type(exc)(*exc.args)
        except TypeError:            # exotic ctor: fall back to sharing
            return exc

    def _maybe_fail_write(self, key: str, nbytes: int) -> None:
        exc: Optional[BaseException] = None
        with self._flock:
            if (self._fail_writes > 0
                    and (self._fail_key_substr is None
                         or self._fail_key_substr in key)
                    and self._on_fail_device(key)):
                self._fail_writes -= 1
                self.injected["write_failures"] += 1
                exc = self._write_exc or OSError(
                    f"injected write failure for {key!r}")
            elif (self._enospc_after is not None
                    and self._bytes_through >= self._enospc_after):
                self.injected["enospc_failures"] += 1
                exc = OSError(errno.ENOSPC,
                              f"injected ENOSPC for {key!r}")
            elif (self._intermittent_rate > 0.0
                    and self._rng.random() < self._intermittent_rate):
                self.injected["intermittent_failures"] += 1
                exc = self._intermittent_exc or OSError(
                    errno.EIO, f"injected intermittent failure for "
                    f"{key!r}")
            else:
                self._bytes_through += nbytes
                return
        raise self._fresh(exc)

    def _maybe_fail_read(self, key: str) -> None:
        with self._flock:
            if (self._fail_reads <= 0
                    or (self._read_key_substr is not None
                        and self._read_key_substr not in key)
                    or not self._on_fail_device(key)):
                return
            self._fail_reads -= 1
            self.injected["read_failures"] += 1
            exc = self._read_exc or OSError(
                errno.EIO, f"injected read failure for {key!r}")
        raise self._fresh(exc)

    def _shortfall(self) -> int:
        with self._flock:
            if self._short_reads <= 0:
                return 0
            self._short_reads -= 1
            self.injected["short_reads"] += 1
            return self._short_by

    # ------------------------------------------------------ delegation

    def _write(self, key: str, data: bytes) -> None:
        if self.write_delay:
            time.sleep(self.write_delay)
        self._maybe_fail_write(key, len(data))
        self.inner.write(key, data)

    def _write_parts(self, key: str, parts: List[memoryview]) -> None:
        if self.write_delay:
            time.sleep(self.write_delay)
        self._maybe_fail_write(key, sum(len(p) for p in parts))
        self.inner.write_parts(key, parts)

    def _read(self, key: str) -> bytes:
        if self.read_delay:
            time.sleep(self.read_delay)
        self._maybe_fail_read(key)
        data = self.inner.read(key)
        cut = self._shortfall()
        return data[:max(0, len(data) - cut)] if cut else data

    def _readinto(self, key: str, buf: memoryview) -> int:
        if self.read_delay:
            time.sleep(self.read_delay)
        self._maybe_fail_read(key)
        n = len(self.inner.readinto(key, buf))
        cut = self._shortfall()
        return max(0, n - cut) if cut else n

    def _size(self, key: str) -> Optional[int]:
        return self.inner.size(key)

    def _delete(self, key: str) -> None:
        self.inner.delete(key)

    def flush(self) -> None:
        self.inner.flush()

    def tier_bandwidths(self):
        return self.inner.tier_bandwidths()

    def close(self) -> None:
        self.inner.close()
        super().close()
