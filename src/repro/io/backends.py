"""The four storage-backend implementations behind the `repro.io`
registry: filesystem (seed behavior), multi-SSD striping, host-RAM, and
the capacity-budgeted RAM-over-SSD tier."""
from __future__ import annotations

import os
import threading
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

from repro.cache.placement import PlacementEngine
from repro.core.adaptive import TierBandwidth
from repro.io.backend import (StorageBackend, as_memoryviews, preadv_all,
                              pwritev_all, register_backend)


@register_backend("fs")
class FilesystemBackend(StorageBackend):
    """One blob file per key in one directory — the seed ActivationSpool
    path, extracted. The directory stands in for a single SSD.

    Writes are vectored (`os.pwritev` over the serde part list, no
    monolithic join) and rename-atomic: the blob lands in a
    same-directory temp file that is `os.replace`d over the real name
    only once fully written, so a *process* crash mid-store can never
    leave a truncated blob under the final name for
    `deserialize_leaves` to misparse on restart. (Power loss is weaker:
    without a per-store fsync — unaffordable per residual — the journal
    may commit the rename before the data lands; serde's truncation
    guard then rejects the torn blob loudly instead.) Reads can scatter
    straight into a caller-owned buffer (`readinto`)."""

    def __init__(self, directory: str):
        super().__init__()
        self.directory = directory
        os.makedirs(directory, exist_ok=True)

    def _path(self, key: str) -> str:
        return os.path.join(self.directory, f"{key}.act")

    def _tmp_path(self, key: str) -> str:
        # pid+tid suffix: concurrent writers of *different* keys (the
        # spool's store pool) must not collide on temp names
        return (self._path(key)
                + f".tmp.{os.getpid()}.{threading.get_ident()}")

    def _write(self, key: str, data: bytes) -> None:
        self._write_parts(key, as_memoryviews([data]))

    def _write_parts(self, key: str, parts: List[memoryview]) -> None:
        tmp = self._tmp_path(key)
        fd = os.open(tmp, os.O_WRONLY | os.O_CREAT | os.O_TRUNC, 0o644)
        try:
            pwritev_all(fd, parts)
        except BaseException:
            os.close(fd)
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        os.close(fd)
        os.replace(tmp, self._path(key))

    def _read(self, key: str) -> bytes:
        with open(self._path(key), "rb") as f:
            return f.read()

    def _readinto(self, key: str, buf: memoryview) -> int:
        fd = os.open(self._path(key), os.O_RDONLY)
        try:
            n = os.fstat(fd).st_size
            if n > len(buf):
                raise ValueError(f"buffer of {len(buf)} bytes cannot "
                                 f"hold {n}-byte blob {key!r}")
            got = preadv_all(fd, buf[:n])
            if got != n:
                raise OSError(f"short read of {key!r}: {got}/{n} bytes")
            return got
        finally:
            os.close(fd)

    def _size(self, key: str) -> Optional[int]:
        try:
            return os.stat(self._path(key)).st_size
        except OSError:
            return None

    def _delete(self, key: str) -> None:
        try:
            os.unlink(self._path(key))
        except OSError:
            pass


@register_backend("striped")
class StripedBackend(StorageBackend):
    """Round-robin chunk striping across N directories.

    Each directory stands in for one SSD of the paper's per-GPU array
    (§3.4 uses 4x D7-P5810). A blob is split into `chunk_bytes` chunks;
    chunk i lands on device (i % N), so sequential writes load all
    devices evenly and reads fan out across the array. Per-device byte
    counters feed `core.endurance.project_device_lifespans` so wear is
    modeled per drive, not for the array as a whole.
    """

    def __init__(self, directories: Sequence[str], *,
                 chunk_bytes: int = 4 << 20):
        super().__init__()
        if not directories:
            raise ValueError("StripedBackend needs >= 1 directory")
        if chunk_bytes <= 0:
            raise ValueError("chunk_bytes must be positive")
        self.directories = list(directories)
        self.chunk_bytes = chunk_bytes
        for d in self.directories:
            os.makedirs(d, exist_ok=True)
        self.device_write_bytes = [0] * len(self.directories)
        self.device_read_bytes = [0] * len(self.directories)
        self._dev_lock = threading.Lock()
        # key -> number of chunks (rebuilt by probing if missing)
        self._manifest: Dict[str, int] = {}

    def _device(self, key: str, i: int) -> int:
        # Start each key's round-robin at a key-dependent device (stable
        # crc32, not salted hash()): otherwise every blob smaller than
        # chunk_bytes would land on device 0 and the "array" would wear
        # and bottleneck like a single drive.
        start = zlib.crc32(key.encode()) % len(self.directories)
        return (start + i) % len(self.directories)

    def _chunk_path(self, key: str, i: int) -> str:
        return os.path.join(self.directories[self._device(key, i)],
                            f"{key}.c{i}")

    def _write(self, key: str, data: bytes) -> None:
        self._write_parts(key, as_memoryviews([data]))

    def _write_parts(self, key: str, parts: List[memoryview]) -> None:
        # Partition the part list into per-chunk view lists: memoryview
        # slicing is zero-copy, so each stripe chunk is pwritev'd from
        # the original serde buffers without assembling the blob or the
        # chunk anywhere on the host.
        chunks: List[List[memoryview]] = [[]]
        room = self.chunk_bytes
        for p in parts:
            while len(p):
                take = min(room, len(p))
                chunks[-1].append(p[:take])
                p = p[take:]
                room -= take
                if room == 0:
                    chunks.append([])
                    room = self.chunk_bytes
        if len(chunks) > 1 and not chunks[-1]:
            chunks.pop()
        n = len(chunks)
        for i, views in enumerate(chunks):
            fd = os.open(self._chunk_path(key, i),
                         os.O_WRONLY | os.O_CREAT | os.O_TRUNC, 0o644)
            try:
                pwritev_all(fd, views)
            finally:
                os.close(fd)
            with self._dev_lock:
                self.device_write_bytes[self._device(key, i)] += \
                    sum(len(v) for v in views)
        with self._dev_lock:
            self._manifest[key] = n
        # a re-write with fewer chunks must not leave the old tail
        # behind: the probe-based reader (fresh process over the same
        # stripe dirs) would concatenate fresh + stale chunks, and
        # delete would leak the tail
        i = n
        while os.path.exists(self._chunk_path(key, i)):
            try:
                os.unlink(self._chunk_path(key, i))
            except OSError:
                pass
            i += 1

    def _num_chunks(self, key: str) -> int:
        with self._dev_lock:
            n = self._manifest.get(key)
        if n is not None:
            return n
        i = 0
        while os.path.exists(self._chunk_path(key, i)):
            i += 1
        return i

    def _read(self, key: str) -> bytes:
        n = self._num_chunks(key)
        if n == 0:
            raise FileNotFoundError(key)
        parts = []
        for i in range(n):
            with open(self._chunk_path(key, i), "rb") as f:
                chunk = f.read()
            parts.append(chunk)
            with self._dev_lock:
                self.device_read_bytes[self._device(key, i)] += \
                    len(chunk)
        return b"".join(parts)

    def _readinto(self, key: str, buf: memoryview) -> int:
        """Gather the stripe chunks directly into successive slices of
        the caller's buffer — no per-chunk bytes objects, no join."""
        n = self._num_chunks(key)
        if n == 0:
            raise FileNotFoundError(key)
        off = 0
        for i in range(n):
            fd = os.open(self._chunk_path(key, i), os.O_RDONLY)
            try:
                sz = os.fstat(fd).st_size
                if off + sz > len(buf):
                    raise ValueError(
                        f"buffer of {len(buf)} bytes cannot hold "
                        f"striped blob {key!r} (>= {off + sz} bytes)")
                got = preadv_all(fd, buf[off:off + sz])
                if got != sz:
                    raise OSError(f"short read of {key!r} chunk {i}: "
                                  f"{got}/{sz} bytes")
            finally:
                os.close(fd)
            with self._dev_lock:
                self.device_read_bytes[self._device(key, i)] += sz
            off += sz
        return off

    def _size(self, key: str) -> Optional[int]:
        n = self._num_chunks(key)
        if n == 0:
            return None
        total = 0
        for i in range(n):
            try:
                total += os.stat(self._chunk_path(key, i)).st_size
            except OSError:
                return None
        return total

    def _delete(self, key: str) -> None:
        n = self._num_chunks(key)
        with self._dev_lock:
            self._manifest.pop(key, None)
        for i in range(n):
            try:
                os.unlink(self._chunk_path(key, i))
            except OSError:
                pass

    def per_device_write_bytes(self) -> List[int]:
        with self._dev_lock:
            return list(self.device_write_bytes)


@register_backend("mem")
class HostMemoryBackend(StorageBackend):
    """CPU-RAM tier: blobs live in a host-side dict. On its own it is the
    fastest tier (no serialization to media); under `TieredBackend` it is
    the bounded upper level of the hierarchy."""

    #: `_read` returns the stored bytes object itself — loaders can
    #: deserialize views straight over it (immutable, refcount-kept)
    zero_copy_read = True

    def __init__(self):
        super().__init__()
        self._blobs: Dict[str, bytes] = {}
        self._lock = threading.Lock()

    # _write_parts/_readinto: the base-class fallbacks (join + counted
    # copy; read + counted copy into the caller's buffer) ARE this
    # backend's native semantics — RAM is the storage medium, so the
    # join is the device write itself, honestly counted as a host copy.

    def _write(self, key: str, data: bytes) -> None:
        with self._lock:
            self._blobs[key] = data

    def _read(self, key: str) -> bytes:
        with self._lock:
            try:
                return self._blobs[key]
            except KeyError:
                raise FileNotFoundError(key) from None

    def _size(self, key: str) -> Optional[int]:
        with self._lock:
            data = self._blobs.get(key)
        return len(data) if data is not None else None

    def _delete(self, key: str) -> None:
        with self._lock:
            self._blobs.pop(key, None)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._blobs

    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return sum(len(b) for b in self._blobs.values())


@register_backend("tiered")
class TieredBackend(StorageBackend):
    """Host-RAM upper tier under a byte budget, spilling to a lower
    backend (10Cache-style heterogeneous hierarchy).

    Writes land in RAM while the budget holds; when a write would exceed
    `capacity_bytes`, resident blobs are evicted to the lower backend in
    *backward-access order*: the backward pass consumes keys in reverse
    store order, so the earliest-stored keys are the ones needed furthest
    in the future — they are evicted first (Belady's choice under the
    spool's LIFO access pattern). Blobs larger than the whole budget
    bypass RAM entirely.

    The placement protocol itself lives in
    `repro.cache.placement.PlacementEngine`; this class is the static
    (class-blind, FIFO-victim, no-promotion) configuration of it, kept
    for configs that want the fixed byte-budget split without the
    `CacheManager`'s reuse-distance machinery.
    """

    def __init__(self, lower: StorageBackend, *, capacity_bytes: int,
                 upper: Optional[HostMemoryBackend] = None):
        super().__init__()
        self.upper = upper if upper is not None else HostMemoryBackend()
        self.lower = lower
        self.capacity_bytes = capacity_bytes
        self._engine = PlacementEngine(
            self.upper, lower, capacity_bytes=capacity_bytes,
            note_copy=self._note_copy)

    @property
    def resident_bytes(self) -> int:
        return self._engine.resident_bytes

    @property
    def evictions(self) -> int:
        return self._engine.evictions

    @property
    def bytes_evicted(self) -> int:
        return self._engine.bytes_evicted

    def _write(self, key: str, data: bytes) -> None:
        # a pre-joined blob is stored by reference in RAM: no join copy
        self._engine.put(key, len(data),
                         lambda tier: tier.write(key, data))

    def _write_parts(self, key: str, parts: List[memoryview]) -> None:
        # ram_copy: a part-list payload's RAM placement joins (one host
        # copy) — counted on THIS backend's stats too, so the tiered
        # copies-per-byte number stays honest; lower-tier copies live on
        # the lower backend's own stats
        self._engine.put(key, sum(len(p) for p in parts),
                         lambda tier: tier.write_parts(key, parts),
                         ram_copy=True)

    def _read(self, key: str) -> bytes:
        return self._engine.read(key)

    def _readinto(self, key: str, buf: memoryview) -> int:
        return self._engine.readinto(key, buf)

    def _size(self, key: str) -> Optional[int]:
        return self._engine.size(key)

    def _delete(self, key: str) -> None:
        self._engine.delete(key)

    def flush(self) -> None:
        self.lower.flush()

    def reset_stats(self) -> None:
        super().reset_stats()
        self.upper.reset_stats()
        self.lower.reset_stats()

    def calibrate(self, data: bytes, repeats: int = 2) -> None:
        """Burst both tiers: a small burst fits the RAM budget, so the
        lower tier would never be measured (and would read as infinitely
        fast to the planner) if we only wrote through the front door."""
        self.reset_stats()
        for i in range(repeats):
            self.upper.write(f"_calibrate{i}", data)
        for i in range(repeats):
            self.upper.delete(f"_calibrate{i}")
        self.lower.calibrate(data, repeats)

    def close(self) -> None:
        self.lower.close()

    def tier_bandwidths(self) -> List[TierBandwidth]:
        up = TierBandwidth("host-ram", self.upper.stats.write_bandwidth,
                           self.capacity_bytes)
        return [up] + self.lower.tier_bandwidths()
