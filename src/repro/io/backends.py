"""The four storage-backend implementations behind the `repro.io`
registry: filesystem (seed behavior), multi-SSD striping, host-RAM, and
the capacity-budgeted RAM-over-SSD tier."""
from __future__ import annotations

import errno
import os
import threading
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

from repro import obs
from repro.cache.placement import PlacementEngine
from repro.core.adaptive import TierBandwidth
from repro.io.backend import (StorageBackend, as_memoryviews, preadv_all,
                              pwritev_all, register_backend)


@register_backend("fs")
class FilesystemBackend(StorageBackend):
    """One blob file per key in one directory — the seed ActivationSpool
    path, extracted. The directory stands in for a single SSD.

    Writes are vectored (`os.pwritev` over the serde part list, no
    monolithic join) and rename-atomic: the blob lands in a
    same-directory temp file that is `os.replace`d over the real name
    only once fully written, so a *process* crash mid-store can never
    leave a truncated blob under the final name for
    `deserialize_leaves` to misparse on restart. (Power loss is weaker:
    without a per-store fsync — unaffordable per residual — the journal
    may commit the rename before the data lands; serde's truncation
    guard then rejects the torn blob loudly instead.) Reads can scatter
    straight into a caller-owned buffer (`readinto`)."""

    def __init__(self, directory: str):
        super().__init__()
        self.directory = directory
        os.makedirs(directory, exist_ok=True)

    def _path(self, key: str) -> str:
        return os.path.join(self.directory, f"{key}.act")

    def _tmp_path(self, key: str) -> str:
        # pid+tid suffix: concurrent writers of *different* keys (the
        # spool's store pool) must not collide on temp names
        return (self._path(key)
                + f".tmp.{os.getpid()}.{threading.get_ident()}")

    def _write(self, key: str, data: bytes) -> None:
        self._write_parts(key, as_memoryviews([data]))

    def _write_parts(self, key: str, parts: List[memoryview]) -> None:
        tmp = self._tmp_path(key)
        fd = os.open(tmp, os.O_WRONLY | os.O_CREAT | os.O_TRUNC, 0o644)
        try:
            pwritev_all(fd, parts)
        except BaseException:
            os.close(fd)
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        os.close(fd)
        os.replace(tmp, self._path(key))

    def _read(self, key: str) -> bytes:
        with open(self._path(key), "rb") as f:
            return f.read()

    def _readinto(self, key: str, buf: memoryview) -> int:
        fd = os.open(self._path(key), os.O_RDONLY)
        try:
            n = os.fstat(fd).st_size
            if n > len(buf):
                raise ValueError(f"buffer of {len(buf)} bytes cannot "
                                 f"hold {n}-byte blob {key!r}")
            got = preadv_all(fd, buf[:n])
            if got != n:
                raise OSError(f"short read of {key!r}: {got}/{n} bytes")
            return got
        finally:
            os.close(fd)

    def _size(self, key: str) -> Optional[int]:
        try:
            return os.stat(self._path(key)).st_size
        except OSError:
            return None

    def _delete(self, key: str) -> None:
        try:
            os.unlink(self._path(key))
        except OSError:
            pass


@register_backend("striped")
class StripedBackend(StorageBackend):
    """Chunk striping across N directories, with capacity/health-aware
    rebalancing.

    Each directory stands in for one SSD of the paper's per-GPU array
    (§3.4 uses 4x D7-P5810). A blob is split into `chunk_bytes` chunks;
    chunk i *prefers* device ((crc32(key) + i) % N), so sequential
    writes load all devices evenly and reads fan out across the array.
    Per-device byte counters feed
    `core.endurance.project_device_lifespans` so wear is modeled per
    drive, not for the array as a whole.

    Resilience: a chunk write that fails is retried on the next-best
    healthy device (ordered by free bytes), and the *actual* placement
    is recorded in the per-key manifest so reads, sizes and deletes
    follow the chunk wherever it landed. A device accumulates
    consecutive write failures; at `fail_threshold` it is taken out of
    the write set (ENOSPC takes it out immediately — a full drive does
    not get healthier by retrying). `set_device_error` is the chaos
    seam: it makes every chunk write *and read* on that device raise,
    as if the NVMe dropped off the bus. Wear accounting only ever
    counts bytes that a device actually accepted.
    """

    def __init__(self, directories: Sequence[str], *,
                 chunk_bytes: int = 4 << 20,
                 fail_threshold: int = 2):
        super().__init__()
        if not directories:
            raise ValueError("StripedBackend needs >= 1 directory")
        if chunk_bytes <= 0:
            raise ValueError("chunk_bytes must be positive")
        self.directories = list(directories)
        self.chunk_bytes = chunk_bytes
        self.fail_threshold = fail_threshold
        for d in self.directories:
            os.makedirs(d, exist_ok=True)
        n = len(self.directories)
        self.device_write_bytes = [0] * n
        self.device_read_bytes = [0] * n
        self.rebalanced_chunks = 0
        self.chunk_write_failures = 0
        self._dev_lock = threading.Lock()
        self._fail_counts = [0] * n
        self._down_writes = [False] * n   # out of the write set
        self._forced_exc: Dict[int, BaseException] = {}  # chaos seam
        # key -> device index per chunk (rebuilt by probing if missing)
        self._manifest: Dict[str, List[int]] = {}

    def _device(self, key: str, i: int) -> int:
        # Start each key's round-robin at a key-dependent device (stable
        # crc32, not salted hash()): otherwise every blob smaller than
        # chunk_bytes would land on device 0 and the "array" would wear
        # and bottleneck like a single drive.
        start = zlib.crc32(key.encode()) % len(self.directories)
        return (start + i) % len(self.directories)

    def _path_on(self, dev: int, key: str, i: int) -> str:
        return os.path.join(self.directories[dev], f"{key}.c{i}")

    def _chunk_path(self, key: str, i: int) -> str:
        # default (pre-rebalance) placement; kept for back-compat
        return self._path_on(self._device(key, i), key, i)

    # --------------------------------------------- device health seams

    def set_device_error(self, dev: int, exc: BaseException) -> None:
        """Chaos seam: device `dev` raises `exc` on every chunk write
        and read until `clear_device_error` — a hard device loss."""
        with self._dev_lock:
            self._forced_exc[dev] = exc
            self._down_writes[dev] = True

    def clear_device_error(self, dev: int) -> None:
        """The device came back: readmit it to the write set."""
        with self._dev_lock:
            self._forced_exc.pop(dev, None)
            self._down_writes[dev] = False
            self._fail_counts[dev] = 0

    def devices_down(self) -> List[bool]:
        with self._dev_lock:
            return list(self._down_writes)

    def free_device_bytes(self, dev: int) -> int:
        """Free bytes on device `dev`'s filesystem (0 when down)."""
        with self._dev_lock:
            if self._down_writes[dev] or dev in self._forced_exc:
                return 0
        try:
            st = os.statvfs(self.directories[dev])
            return st.f_bavail * st.f_frsize
        except OSError:
            return 0

    def _forced(self, dev: int) -> Optional[BaseException]:
        with self._dev_lock:
            exc = self._forced_exc.get(dev)
        if exc is None:
            return None
        try:  # fresh instance: concurrent raisers must not share one
            return type(exc)(*exc.args)
        except TypeError:
            return exc

    def _note_write_failure(self, dev: int, exc: BaseException) -> None:
        went_down = False
        with self._dev_lock:
            self.chunk_write_failures += 1
            self._fail_counts[dev] += 1
            full = (isinstance(exc, OSError)
                    and exc.errno == errno.ENOSPC)
            if not self._down_writes[dev] and (
                    full or self._fail_counts[dev] >= self.fail_threshold):
                self._down_writes[dev] = True
                went_down = True
        if went_down and obs.is_enabled():
            obs.instant("resilience.device_down", cat="resilience",
                        dev=dev, dir=self.directories[dev],
                        error=repr(exc))

    def _candidate_order(self, key: str, i: int) -> List[int]:
        """Devices to try for chunk (key, i): the default placement
        first if it is healthy, then the other healthy devices by free
        bytes (fullest last). With the whole array down, fall back to
        the default device so the caller sees the real error."""
        default = self._device(key, i)
        with self._dev_lock:
            healthy = [d for d in range(len(self.directories))
                       if not self._down_writes[d]]
        if not healthy:
            return [default]
        order = [d for d in healthy if d == default]
        rest = [d for d in healthy if d != default]
        rest.sort(key=lambda d: (-self.free_device_bytes(d),
                                 (d - default) % len(self.directories)))
        return order + rest

    # ------------------------------------------------------ write path

    def _write(self, key: str, data: bytes) -> None:
        self._write_parts(key, as_memoryviews([data]))

    def _write_chunk(self, dev: int, key: str, i: int,
                     views: List[memoryview]) -> None:
        forced = self._forced(dev)
        if forced is not None:
            raise forced
        path = self._path_on(dev, key, i)
        fd = os.open(path, os.O_WRONLY | os.O_CREAT | os.O_TRUNC, 0o644)
        try:
            pwritev_all(fd, views)
        except BaseException:
            os.close(fd)
            try:  # never leave a torn chunk for the probe to find
                os.unlink(path)
            except OSError:
                pass
            raise
        os.close(fd)

    def _place_chunk(self, key: str, i: int,
                     views: List[memoryview]) -> int:
        nbytes = sum(len(v) for v in views)
        default = self._device(key, i)
        last_exc: Optional[BaseException] = None
        for dev in self._candidate_order(key, i):
            try:
                self._write_chunk(dev, key, i, views)
            except (OSError, ValueError) as e:
                self._note_write_failure(dev, e)
                last_exc = e
                continue
            with self._dev_lock:
                self.device_write_bytes[dev] += nbytes
                self._fail_counts[dev] = 0
                if dev != default:
                    self.rebalanced_chunks += 1
            if dev != default and obs.is_enabled():
                obs.count("resilience.rebalance")
                obs.instant("resilience.rebalance", cat="resilience",
                            key=key, chunk=i, frm=default, to=dev)
            return dev
        assert last_exc is not None
        raise last_exc

    def _write_parts(self, key: str, parts: List[memoryview]) -> None:
        # Partition the part list into per-chunk view lists: memoryview
        # slicing is zero-copy, so each stripe chunk is pwritev'd from
        # the original serde buffers without assembling the blob or the
        # chunk anywhere on the host.
        chunks: List[List[memoryview]] = [[]]
        room = self.chunk_bytes
        for p in parts:
            while len(p):
                take = min(room, len(p))
                chunks[-1].append(p[:take])
                p = p[take:]
                room -= take
                if room == 0:
                    chunks.append([])
                    room = self.chunk_bytes
        if len(chunks) > 1 and not chunks[-1]:
            chunks.pop()
        n = len(chunks)
        placement: List[int] = []
        for i, views in enumerate(chunks):
            placement.append(self._place_chunk(key, i, views))
        with self._dev_lock:
            self._manifest[key] = placement
        ndirs = len(self.directories)
        # a re-write must not leave stale copies behind: a rebalanced
        # chunk may have MOVED devices, and a shorter blob leaves a
        # tail — either way the probe-based reader (fresh process over
        # the same stripe dirs) would pick up stale chunks, and delete
        # would leak them
        for j, dev in enumerate(placement):
            for d in range(ndirs):
                if d != dev:
                    try:
                        os.unlink(self._path_on(d, key, j))
                    except OSError:
                        pass
        j = n
        while True:
            found = False
            for d in range(ndirs):
                try:
                    os.unlink(self._path_on(d, key, j))
                    found = True
                except OSError:
                    pass
            if not found:
                break
            j += 1

    # ------------------------------------------------------- read path

    def _locate(self, key: str, i: int,
                dev_hint: Optional[int] = None) -> Optional[int]:
        """Find which device holds chunk (key, i): manifest hint first,
        then default placement, then a full probe (fresh process)."""
        order: List[int] = []
        for d in ([dev_hint] if dev_hint is not None else []) \
                + [self._device(key, i)] \
                + list(range(len(self.directories))):
            if d not in order:
                order.append(d)
        for d in order:
            if os.path.exists(self._path_on(d, key, i)):
                return d
        return None

    def _placement(self, key: str) -> List[int]:
        with self._dev_lock:
            p = self._manifest.get(key)
        if p is not None:
            return p
        placement: List[int] = []
        while True:
            d = self._locate(key, len(placement))
            if d is None:
                return placement
            placement.append(d)

    def _read_chunk_fd(self, dev: int, key: str, i: int) -> int:
        forced = self._forced(dev)
        if forced is not None:
            raise forced
        return os.open(self._path_on(dev, key, i), os.O_RDONLY)

    def _read(self, key: str) -> bytes:
        placement = self._placement(key)
        if not placement:
            raise FileNotFoundError(key)
        parts = []
        for i, dev in enumerate(placement):
            fd = self._read_chunk_fd(dev, key, i)
            with os.fdopen(fd, "rb") as f:
                chunk = f.read()
            parts.append(chunk)
            with self._dev_lock:
                self.device_read_bytes[dev] += len(chunk)
        return b"".join(parts)

    def _readinto(self, key: str, buf: memoryview) -> int:
        """Gather the stripe chunks directly into successive slices of
        the caller's buffer — no per-chunk bytes objects, no join."""
        placement = self._placement(key)
        if not placement:
            raise FileNotFoundError(key)
        off = 0
        for i, dev in enumerate(placement):
            fd = self._read_chunk_fd(dev, key, i)
            try:
                sz = os.fstat(fd).st_size
                if off + sz > len(buf):
                    raise ValueError(
                        f"buffer of {len(buf)} bytes cannot hold "
                        f"striped blob {key!r} (>= {off + sz} bytes)")
                got = preadv_all(fd, buf[off:off + sz])
                if got != sz:
                    raise OSError(f"short read of {key!r} chunk {i}: "
                                  f"{got}/{sz} bytes")
            finally:
                os.close(fd)
            with self._dev_lock:
                self.device_read_bytes[dev] += sz
            off += sz
        return off

    def _size(self, key: str) -> Optional[int]:
        placement = self._placement(key)
        if not placement:
            return None
        total = 0
        for i, dev in enumerate(placement):
            try:
                total += os.stat(self._path_on(dev, key, i)).st_size
            except OSError:
                return None
        return total

    def _delete(self, key: str) -> None:
        with self._dev_lock:
            self._manifest.pop(key, None)
        ndirs = len(self.directories)
        i = 0
        while True:  # probe-based: catches stale/moved copies too
            found = False
            for d in range(ndirs):
                try:
                    os.unlink(self._path_on(d, key, i))
                    found = True
                except OSError:
                    pass
            if not found:
                break
            i += 1

    def per_device_write_bytes(self) -> List[int]:
        with self._dev_lock:
            return list(self.device_write_bytes)


@register_backend("mem")
class HostMemoryBackend(StorageBackend):
    """CPU-RAM tier: blobs live in a host-side dict. On its own it is the
    fastest tier (no serialization to media); under `TieredBackend` it is
    the bounded upper level of the hierarchy."""

    #: `_read` returns the stored bytes object itself — loaders can
    #: deserialize views straight over it (immutable, refcount-kept)
    zero_copy_read = True

    def __init__(self):
        super().__init__()
        self._blobs: Dict[str, bytes] = {}
        self._lock = threading.Lock()

    # _write_parts/_readinto: the base-class fallbacks (join + counted
    # copy; read + counted copy into the caller's buffer) ARE this
    # backend's native semantics — RAM is the storage medium, so the
    # join is the device write itself, honestly counted as a host copy.

    def _write(self, key: str, data: bytes) -> None:
        with self._lock:
            self._blobs[key] = data

    def _read(self, key: str) -> bytes:
        with self._lock:
            try:
                return self._blobs[key]
            except KeyError:
                raise FileNotFoundError(key) from None

    def _size(self, key: str) -> Optional[int]:
        with self._lock:
            data = self._blobs.get(key)
        return len(data) if data is not None else None

    def _delete(self, key: str) -> None:
        with self._lock:
            self._blobs.pop(key, None)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._blobs

    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return sum(len(b) for b in self._blobs.values())


@register_backend("tiered")
class TieredBackend(StorageBackend):
    """Host-RAM upper tier under a byte budget, spilling to a lower
    backend (10Cache-style heterogeneous hierarchy).

    Writes land in RAM while the budget holds; when a write would exceed
    `capacity_bytes`, resident blobs are evicted to the lower backend in
    *backward-access order*: the backward pass consumes keys in reverse
    store order, so the earliest-stored keys are the ones needed furthest
    in the future — they are evicted first (Belady's choice under the
    spool's LIFO access pattern). Blobs larger than the whole budget
    bypass RAM entirely.

    The placement protocol itself lives in
    `repro.cache.placement.PlacementEngine`; this class is the static
    (class-blind, FIFO-victim, no-promotion) configuration of it, kept
    for configs that want the fixed byte-budget split without the
    `CacheManager`'s reuse-distance machinery.
    """

    def __init__(self, lower: StorageBackend, *, capacity_bytes: int,
                 upper: Optional[HostMemoryBackend] = None):
        super().__init__()
        self.upper = upper if upper is not None else HostMemoryBackend()
        self.lower = lower
        self.capacity_bytes = capacity_bytes
        self._engine = PlacementEngine(
            self.upper, lower, capacity_bytes=capacity_bytes,
            note_copy=self._note_copy)

    @property
    def resident_bytes(self) -> int:
        return self._engine.resident_bytes

    @property
    def evictions(self) -> int:
        return self._engine.evictions

    @property
    def bytes_evicted(self) -> int:
        return self._engine.bytes_evicted

    def _write(self, key: str, data: bytes) -> None:
        # a pre-joined blob is stored by reference in RAM: no join copy
        self._engine.put(key, len(data),
                         lambda tier: tier.write(key, data))

    def _write_parts(self, key: str, parts: List[memoryview]) -> None:
        # ram_copy: a part-list payload's RAM placement joins (one host
        # copy) — counted on THIS backend's stats too, so the tiered
        # copies-per-byte number stays honest; lower-tier copies live on
        # the lower backend's own stats
        self._engine.put(key, sum(len(p) for p in parts),
                         lambda tier: tier.write_parts(key, parts),
                         ram_copy=True)

    def _read(self, key: str) -> bytes:
        return self._engine.read(key)

    def _readinto(self, key: str, buf: memoryview) -> int:
        return self._engine.readinto(key, buf)

    def _size(self, key: str) -> Optional[int]:
        return self._engine.size(key)

    def _delete(self, key: str) -> None:
        self._engine.delete(key)

    def flush(self) -> None:
        self.lower.flush()

    def reset_stats(self) -> None:
        super().reset_stats()
        self.upper.reset_stats()
        self.lower.reset_stats()

    def calibrate(self, data: bytes, repeats: int = 2) -> None:
        """Burst both tiers: a small burst fits the RAM budget, so the
        lower tier would never be measured (and would read as infinitely
        fast to the planner) if we only wrote through the front door."""
        self.reset_stats()
        for i in range(repeats):
            self.upper.write(f"_calibrate{i}", data)
        for i in range(repeats):
            self.upper.delete(f"_calibrate{i}")
        self.lower.calibrate(data, repeats)

    def close(self) -> None:
        self.lower.close()

    def tier_bandwidths(self) -> List[TierBandwidth]:
        up = TierBandwidth("host-ram", self.upper.stats.write_bandwidth,
                           self.capacity_bytes)
        return [up] + self.lower.tier_bandwidths()
