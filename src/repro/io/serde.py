"""Array-list <-> bytes serialization for spooled residuals.

Grown from the seed `core/spool.py` helpers (`_serialize`/`_deserialize`)
with two changes:

* single-copy format: ``RSA2 | u32 header_len | pickled metas | raw
  buffers`` assembled with one ``b"".join`` over memoryviews — the seed's
  tobytes-then-pickle path copied every payload twice. `serialize_parts`
  exposes the part list so the codec container can join once more parts
  instead of re-copying the payload.
* deserialized arrays are materialized into one writable backing buffer
  (`np.frombuffer` over a pickle blob returns read-only views), so
  fetched residuals behave like the originals downstream.

Legacy blobs (the seed's pickled ``(metas, blobs)`` tuples) still load.
"""
from __future__ import annotations

import math
import pickle
import struct
from typing import List, Sequence

import numpy as np

_MAGIC = b"RSA2"


def _np_dtype(dt: str) -> np.dtype:
    import ml_dtypes
    return np.dtype(getattr(ml_dtypes, dt, dt) if isinstance(dt, str)
                    else dt)


def serialize_parts(leaves: Sequence[np.ndarray]) -> List[bytes]:
    """The blob as a list of bytes-like parts (no payload copy; array
    buffers are exposed as memoryviews). ``b"".join(parts)`` is the
    canonical single-copy assembly."""
    arrs = [np.ascontiguousarray(np.asarray(a)) for a in leaves]
    metas = [(a.shape, str(a.dtype)) for a in arrs]
    header = pickle.dumps(metas, protocol=4)
    parts: List[bytes] = [_MAGIC, struct.pack("<I", len(header)), header]
    parts += [a.reshape(-1).view(np.uint8).data for a in arrs]
    return parts


def serialize_leaves(leaves: Sequence[np.ndarray]) -> bytes:
    return b"".join(serialize_parts(leaves))


def deserialize_leaves(data) -> List[np.ndarray]:
    """bytes / bytearray / memoryview -> list of *writable* arrays."""
    if bytes(data[:4]) == _MAGIC:
        buf = memoryview(bytearray(data))    # one writable copy
        (hlen,) = struct.unpack_from("<I", buf, 4)
        off = 8
        metas = pickle.loads(bytes(buf[off:off + hlen]))
        off += hlen
        out = []
        for shape, dt in metas:
            np_dt = _np_dtype(dt)
            n = np_dt.itemsize * math.prod(shape)
            out.append(np.frombuffer(buf[off:off + n],
                                     dtype=np_dt).reshape(shape))
            off += n
        return out
    # legacy seed format: pickled (metas, blobs)
    metas, blobs = pickle.loads(data)
    out = []
    for (shape, dt), blob in zip(metas, blobs):
        out.append(np.frombuffer(bytearray(blob),
                                 dtype=_np_dtype(dt)).reshape(shape))
    return out
