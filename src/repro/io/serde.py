"""Array-list <-> bytes serialization for spooled residuals.

Grown from the seed `core/spool.py` helpers (`_serialize`/`_deserialize`)
into the zero-copy data plane's serde layer:

* part-list format: ``RSA2 | u32 header_len | pickled metas | raw
  buffers`` — `serialize_parts` exposes the raw array buffers as
  memoryviews, so a vectored backend (`write_parts`) moves them to the
  device with no join and no payload copy at all.
* `deserialize_leaves(..., copy=False)` parses a blob into zero-copy
  read-only views over the caller's buffer (the spool's pooled-load
  path: views stay valid while the pool lease is held, and consumers
  copy on demand when they materialize device arrays). The default
  `copy=True` materializes fresh writable per-leaf arrays — one payload
  copy, but no whole-blob ``bytearray`` double-buffer like the old path.

Legacy blobs (the seed's pickled ``(metas, blobs)`` tuples) still load.
"""
from __future__ import annotations

import math
import pickle
import struct
from typing import List, Sequence

import numpy as np

_MAGIC = b"RSA2"


def _np_dtype(dt: str) -> np.dtype:
    import ml_dtypes
    return np.dtype(getattr(ml_dtypes, dt, dt) if isinstance(dt, str)
                    else dt)


def serialize_parts(leaves: Sequence[np.ndarray]) -> List[bytes]:
    """The blob as a list of bytes-like parts (no payload copy; array
    buffers are exposed as memoryviews). ``b"".join(parts)`` is the
    canonical single-copy assembly; `StorageBackend.write_parts` is the
    zero-copy one."""
    arrs = []
    for x in leaves:
        x = np.asarray(x)
        # reshape back: ascontiguousarray silently promotes 0-d to 1-d
        arrs.append(np.ascontiguousarray(x).reshape(x.shape))
    metas = [(a.shape, str(a.dtype)) for a in arrs]
    header = pickle.dumps(metas, protocol=4)
    parts: List[bytes] = [_MAGIC, struct.pack("<I", len(header)), header]
    parts += [a.reshape(-1).view(np.uint8).data for a in arrs]
    return parts


def serialize_leaves(leaves: Sequence[np.ndarray]) -> bytes:
    return b"".join(serialize_parts(leaves))


def deserialize_leaves(data, *, copy: bool = True,
                       pinned: bool = True) -> List[np.ndarray]:
    """bytes / bytearray / memoryview -> list of arrays.

    copy=True  (default): every array owns fresh writable memory.
    copy=False: zero-copy views over `data`'s buffer. With pinned=True
    (default) the views are forced read-only — required when the buffer
    is a recyclable pool lease, so borrowers (and jax's zero-copy
    asarray) can never alias memory the pool will reuse; consumers copy
    on demand. Pass pinned=False when `data` owns fresh unshared memory
    (e.g. a codec's decode output): the views keep the buffer alive by
    reference and writable views skip the copy-on-demand."""
    view = data if isinstance(data, memoryview) else memoryview(data)
    if view.itemsize != 1 or view.ndim != 1:
        view = view.cast("B")
    if bytes(view[:4]) == _MAGIC:
        (hlen,) = struct.unpack_from("<I", view, 4)
        off = 8
        metas = pickle.loads(bytes(view[off:off + hlen]))
        off += hlen
        out = []
        for shape, dt in metas:
            np_dt = _np_dtype(dt)
            n = np_dt.itemsize * math.prod(shape)
            seg = view[off:off + n]
            if len(seg) < n:
                raise ValueError(
                    f"truncated residual blob: leaf {shape}/{dt} needs "
                    f"{n} bytes, {len(seg)} left")
            if n == 0:
                # np.frombuffer rejects empty buffers of wide dtypes
                arr = np.empty(shape, dtype=np_dt)
            else:
                arr = np.frombuffer(seg, dtype=np_dt).reshape(shape)
                if copy:
                    arr = arr.copy()        # fresh, writable, owns data
                elif pinned:
                    # frombuffer inherits writability from the buffer;
                    # see docstring for why pinned views go read-only
                    arr.flags.writeable = False
            out.append(arr)
            off += n
        return out
    # legacy seed format: pickled (metas, blobs) — always materialized
    metas, blobs = pickle.loads(data)
    out = []
    for (shape, dt), blob in zip(metas, blobs):
        out.append(np.frombuffer(bytearray(blob),
                                 dtype=_np_dtype(dt)).reshape(shape))
    return out
