"""Backend construction from declarative config (`SpoolIoConfig`) and
from compact CLI spec strings.

Spec grammar (CLI surface, `--spool-backend`-style flags):

    fs                      filesystem under the default spool dir
    fs:/path                filesystem at /path
    mem                     host-RAM tier
    striped:/a,/b           stripe across the listed directories
    striped@4               stripe across 4 subdirs of the default dir
    striped:/base@4         stripe across 4 subdirs of /base
    tiered:64mb             RAM budget 64 MiB over fs default
    tiered:64mb,<spec>      RAM budget over any lower spec (recursive)
    managed:64mb            cache-manager brain, 64 MiB host bound, fs SSD
    managed:64mb,<spec>     ... over any lower spec (recursive)
    aio                     O_DIRECT data plane under the default dir
    aio:/path@8             O_DIRECT at /path, submission depth 8
    fault:<spec>            fault-injection wrapper over any lower spec
    fault@2:mem             ... failing the first 2 writes (tests)
"""
from __future__ import annotations

import os
import tempfile
from typing import List, Optional

from repro.io.aio import AioBackend
from repro.io.backend import StorageBackend, get_backend_cls
from repro.io.backends import (FilesystemBackend, HostMemoryBackend,
                               StripedBackend, TieredBackend)
from repro.io.faults import FaultInjectingBackend

_SUFFIX = {"kb": 1 << 10, "mb": 1 << 20, "gb": 1 << 30, "tb": 1 << 40,
           "k": 1 << 10, "m": 1 << 20, "g": 1 << 30, "t": 1 << 40,
           "b": 1}


def parse_bytes(text: str) -> int:
    """'64mb' / '1g' / '4096' -> bytes."""
    s = str(text).strip().lower()
    for suf in sorted(_SUFFIX, key=len, reverse=True):
        if s.endswith(suf):
            return int(float(s[:-len(suf)]) * _SUFFIX[suf])
    return int(float(s))


def _default_dir(base_dir: Optional[str],
                 created: Optional[List[str]] = None) -> str:
    if base_dir:
        return base_dir
    d = tempfile.mkdtemp(prefix="tba_spool_")
    if created is not None:
        created.append(d)
    return d


def _stripe_dirs(base: str, n: int) -> List[str]:
    return [os.path.join(base, f"stripe{i}") for i in range(n)]


def _own_tmpdirs(backend: StorageBackend,
                 created: List[str]) -> StorageBackend:
    # Temp dirs the factory invented (no user-named directory) are the
    # caller's to remove on close — advertise them so StagedTrainer /
    # TrainSession can clean up instead of leaking tba_spool_* dirs.
    backend.owned_tmpdirs = tuple(created)
    return backend


def backend_from_spec(spec: str, *,
                      base_dir: Optional[str] = None) -> StorageBackend:
    spec = (spec or "fs").strip()
    kind, _, rest = spec.partition(":")
    if "@" in kind:                       # striped@N / fault@N shorthand
        kind, _, n = kind.partition("@")
        if kind == "fault":               # fault@N:<inner> keeps <inner>
            rest = f"@{n}:{rest}" if rest else f"@{n}"
        else:
            rest = f"@{n}"
    get_backend_cls(kind)                 # fail fast on unknown kinds
    created: List[str] = []
    if kind == "fs":
        return _own_tmpdirs(
            FilesystemBackend(rest or _default_dir(base_dir, created)),
            created)
    if kind == "aio":
        depth = 4
        if "@" in rest:
            rest, _, d = rest.rpartition("@")
            depth = int(d)
        return _own_tmpdirs(
            AioBackend(rest or _default_dir(base_dir, created),
                       queue_depth=depth),
            created)
    if kind == "mem":
        return HostMemoryBackend()
    if kind == "striped":
        if rest.startswith("@"):
            dirs = _stripe_dirs(_default_dir(base_dir, created),
                                int(rest[1:]))
        elif "@" in rest:
            base, _, n = rest.rpartition("@")
            dirs = _stripe_dirs(base, int(n))
        elif rest:
            dirs = [d for d in rest.split(",") if d]
        else:
            dirs = _stripe_dirs(_default_dir(base_dir, created), 2)
        return _own_tmpdirs(StripedBackend(dirs), created)
    if kind == "tiered":
        budget, _, lower_spec = rest.partition(",")
        if not budget:
            raise ValueError("tiered spec needs a RAM budget, e.g. "
                             "'tiered:64mb'")
        lower = backend_from_spec(lower_spec or "fs", base_dir=base_dir)
        created += list(getattr(lower, "owned_tmpdirs", ()))
        return _own_tmpdirs(
            TieredBackend(lower, capacity_bytes=parse_bytes(budget)),
            created)
    if kind == "managed":
        # imported here, not at module top: the manager module itself
        # imports repro.io.backend, so an eager import would cycle when
        # repro.cache loads first
        from repro.cache.manager import CacheManager
        budget, _, lower_spec = rest.partition(",")
        if not budget:
            raise ValueError("managed spec needs a host-RAM bound, e.g. "
                             "'managed:64mb'")
        lower = backend_from_spec(lower_spec or "fs", base_dir=base_dir)
        created += list(getattr(lower, "owned_tmpdirs", ()))
        return _own_tmpdirs(
            CacheManager(lower, host_bound_bytes=parse_bytes(budget)),
            created)
    if kind == "fault":
        fail_writes = 0
        if rest.startswith("@"):          # fault@N:<inner>
            n, _, rest = rest[1:].partition(":")
            fail_writes = int(n)
        inner = backend_from_spec(rest or "mem", base_dir=base_dir)
        created += list(getattr(inner, "owned_tmpdirs", ()))
        return _own_tmpdirs(
            FaultInjectingBackend(inner, fail_writes=fail_writes),
            created)
    raise ValueError(f"unhandled backend spec {spec!r}")


def build_backend(io_cfg, *,
                  default_dir: Optional[str] = None) -> StorageBackend:
    """Construct a backend from a `repro.configs.base.SpoolIoConfig`
    (duck-typed so `repro.io` stays import-independent of configs)."""
    kind = io_cfg.backend
    if ":" in kind or "@" in kind or kind == "fault":
        # full spec string ("fault@2:striped:/a,/b") — the spec grammar
        # subsumes every per-field knob except the chunk/budget ones,
        # which specs carry inline
        return backend_from_spec(kind,
                                 base_dir=io_cfg.directory or default_dir)
    get_backend_cls(kind)
    created: List[str] = []

    def directory() -> str:
        # resolved lazily: only the branches that actually store to a
        # directory may mkdtemp one
        return io_cfg.directory or _default_dir(default_dir, created)

    if kind == "mem":
        return HostMemoryBackend()
    if kind == "fs":
        return _own_tmpdirs(FilesystemBackend(directory()), created)
    if kind == "aio":
        return _own_tmpdirs(
            AioBackend(directory(),
                       alignment=getattr(io_cfg, "alignment", 4096),
                       queue_depth=getattr(io_cfg, "queue_depth", 4),
                       pool_bytes=getattr(io_cfg, "pool_bytes",
                                          256 << 20)),
            created)
    if kind == "striped":
        dirs = list(io_cfg.stripe_dirs) or _stripe_dirs(directory(), 2)
        return _own_tmpdirs(
            StripedBackend(dirs, chunk_bytes=io_cfg.stripe_chunk_bytes),
            created)
    if kind == "tiered":
        if io_cfg.stripe_dirs:
            lower: StorageBackend = StripedBackend(
                list(io_cfg.stripe_dirs),
                chunk_bytes=io_cfg.stripe_chunk_bytes)
        else:
            lower = FilesystemBackend(directory())
        return _own_tmpdirs(
            TieredBackend(lower,
                          capacity_bytes=io_cfg.host_mem_budget_bytes),
            created)
    if kind == "managed":
        from repro.cache.manager import CacheConfig, CacheManager
        # SSD tier: the --cache-ssd spec when given, else the same
        # stripe-dirs/directory resolution the tiered backend uses
        ssd_spec = getattr(io_cfg, "cache_ssd", None)
        if ssd_spec:
            lower = backend_from_spec(ssd_spec, base_dir=default_dir)
            created += list(getattr(lower, "owned_tmpdirs", ()))
        elif io_cfg.stripe_dirs:
            lower = StripedBackend(list(io_cfg.stripe_dirs),
                                   chunk_bytes=io_cfg.stripe_chunk_bytes)
        else:
            lower = FilesystemBackend(directory())
        cfg = CacheConfig(
            host_bound_bytes=io_cfg.host_mem_budget_bytes,
            promote_depth=getattr(io_cfg, "cache_promote_depth", 2))
        return _own_tmpdirs(CacheManager(lower, config=cfg), created)
    raise ValueError(f"unhandled backend kind {kind!r}")
