from repro.parallel.sharding import (MeshAxes, batch_specs, cache_specs,
                                     param_specs, with_sharding)

__all__ = ["MeshAxes", "param_specs", "batch_specs", "cache_specs",
           "with_sharding"]
