"""Sharding rules: PartitionSpec trees for params, optimizer state, batches
and decode caches, per architecture (DESIGN.md §4).

Layout (GSPMD, production mesh ("pod",)"data","model"):
  * batch / activations   — shard dim 0 (batch) over the dp axes
    ("pod","data"); everything else replicated between ops, XLA propagates.
  * weights               — Megatron TP over "model" (q heads, d_ff, vocab,
    experts) + ZeRO-3/FSDP over "data" on the non-TP contraction dim, so
    per-layer all-gathers ride the scan and the optimizer update is fully
    sharded (ZeRO-1 falls out: moments inherit the param specs).
  * kv heads / odd dims   — sharded over "model" only when divisible
    (qwen kv=2, rg-lru kv=1 stay replicated; gemma2 kv=16 shards).
  * decode caches         — batch over dp when divisible (long_500k B=1
    stays replicated: single-stream latency is not data-parallel).

All rules are name+shape driven so they apply to every architecture's
params pytree without per-arch tables.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class MeshAxes:
    """Logical roles of mesh axes. dp: batch+fsdp axes; tp: tensor axis."""
    dp: Tuple[str, ...] = ("data",)
    tp: str = "model"

    def dp_size(self, mesh) -> int:
        n = 1
        for a in self.dp:
            n *= mesh.shape[a]
        return n

    def tp_size(self, mesh) -> int:
        return mesh.shape[self.tp]


def _key_name(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def _div(dim: int, size: int) -> bool:
    return size > 0 and dim % size == 0


def _axis_if(dim: int, axis, mesh) -> Optional[Any]:
    """axis (str or tuple) if it divides dim, else None (replicated)."""
    if axis is None:
        return None
    if isinstance(axis, tuple):
        if not axis:
            return None
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        if not _div(dim, n):
            return None
        # newer jax canonicalizes 1-tuples to the bare name; do it
        # ourselves so specs compare equal on every version
        return axis[0] if len(axis) == 1 else axis
    return axis if _div(dim, mesh.shape[axis]) else None


def _rule(names: Tuple[str, ...], shape: Tuple[int, ...], mesh,
          axes: MeshAxes, fsdp: bool) -> P:
    """PartitionSpec for one param leaf, identified by its key path."""
    name = names[-1]
    layered = "segments" in names or "enc_segments" in names
    lead: Tuple = (None,) if layered else ()
    body = shape[1:] if layered else shape
    tp, dpx = axes.tp, (axes.dp if fsdp else None)

    def spec(*parts):
        return P(*(lead + parts))

    # ---- scalars / vectors that stay replicated
    if name in ("scale", "b_a", "b_i", "lambda_p", "dt_bias", "A_log",
                "D_skip", "b"):
        return spec(*([None] * len(body)))
    if name == "norm_scale":                        # (I,) — tp if divisible
        return spec(_axis_if(body[0], tp, mesh))

    in_moe = "moe" in names
    in_conv = "conv" in names

    if in_conv and name == "w":                     # (width, C)
        return spec(None, _axis_if(body[1], tp, mesh))

    if name == "embed":                             # (V, D) vocab-sharded
        return spec(_axis_if(body[0], tp, mesh), None)
    if name == "pos_embed":                         # (Pmax, D)
        return spec(None, _axis_if(body[1], tp, mesh))
    if name == "unembed":                           # (D, V)
        return spec(_axis_if(body[0], dpx, mesh),
                    _axis_if(body[1], tp, mesh))
    if name == "frontend_proj":                     # (D, D)
        return spec(_axis_if(body[0], dpx, mesh),
                    _axis_if(body[1], tp, mesh))

    if name == "wq":                                # (D, H, hd)
        return spec(_axis_if(body[0], dpx, mesh),
                    _axis_if(body[1], tp, mesh), None)
    if name in ("wk", "wv"):                        # (D, KV, hd)
        return spec(_axis_if(body[0], dpx, mesh),
                    _axis_if(body[1], tp, mesh), None)
    if name == "wo":                                # (H, hd, D)
        return spec(_axis_if(body[0], tp, mesh), None,
                    _axis_if(body[2], dpx, mesh))
    if name in ("bq", "bk", "bv"):                  # (H, hd)
        return spec(_axis_if(body[0], tp, mesh), None)

    if in_moe:
        if name == "router":                        # (D, E)
            return spec(_axis_if(body[0], dpx, mesh), None)
        if name in ("w_in", "w_gate"):              # (E, D, F) — EP over tp
            return spec(_axis_if(body[0], tp, mesh),
                        _axis_if(body[1], dpx, mesh), None)
        if name == "w_out":                         # (E, F, D)
            return spec(_axis_if(body[0], tp, mesh), None,
                        _axis_if(body[2], dpx, mesh))

    if name in ("w_in", "w_gate", "w_zx", "w_bc", "w_branch_gate"):
        # (D, F)-shaped input projections: contract dim fsdp, out dim tp
        return spec(_axis_if(body[0], dpx, mesh),
                    _axis_if(body[1], tp, mesh))
    if name == "w_dt":                              # (D, H) H rarely divides
        return spec(_axis_if(body[0], dpx, mesh),
                    _axis_if(body[1], tp, mesh))
    if name in ("w_a", "w_i"):                      # (W, W)
        return spec(_axis_if(body[0], dpx, mesh),
                    _axis_if(body[1], tp, mesh))
    if name == "w_out":                             # (F, D)
        return spec(_axis_if(body[0], tp, mesh),
                    _axis_if(body[1], dpx, mesh))

    # fallback: replicate
    return spec(*([None] * len(body)))


def param_specs(cfg: ModelConfig, params_shapes, mesh, axes: MeshAxes,
                *, fsdp: bool = True):
    """PartitionSpec tree matching a params pytree (of arrays or
    ShapeDtypeStructs). fsdp=False keeps weights TP-only (serving)."""

    def one(path, leaf):
        names = tuple(_key_name(k) for k in path)
        return _rule(names, tuple(leaf.shape), mesh, axes, fsdp)

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def batch_specs(batch_shapes, mesh, axes: MeshAxes):
    """Shard dim 0 (global batch) over dp where divisible; scalars and
    indivisible batches replicate."""

    def one(leaf):
        if not leaf.shape:
            return P()
        b = leaf.shape[0]
        ax = _axis_if(b, axes.dp, mesh)
        return P(*((ax,) + (None,) * (len(leaf.shape) - 1)))

    return jax.tree.map(one, batch_shapes)


def cache_specs(cache_shapes, mesh, axes: MeshAxes):
    """Decode caches are (L, B, ...): batch over dp, kv heads over tp when
    divisible (dim 3 of attention caches)."""

    def one(leaf):
        nd = len(leaf.shape)
        parts = [None] * nd
        if nd >= 2:
            parts[1] = _axis_if(leaf.shape[1], axes.dp, mesh)
        if nd == 5:  # (L, B, S, KV, hd) attention cache
            parts[3] = _axis_if(leaf.shape[3], axes.tp, mesh)
        return P(*parts)

    return jax.tree.map(one, cache_shapes)


def with_sharding(shapes_tree, specs_tree, mesh):
    """Attach NamedShardings to a ShapeDtypeStruct tree."""

    def one(s, p):
        return jax.ShapeDtypeStruct(s.shape, s.dtype,
                                    sharding=NamedSharding(mesh, p))

    return jax.tree.map(one, shapes_tree, specs_tree)


def spec_tree_for_optstate(param_spec_tree, opt_shapes):
    """Optimizer state specs: step replicated; moments inherit param specs
    (=> ZeRO: moments are dp+tp sharded exactly like the weights)."""
    from repro.optim.optimizers import OptState

    mu = opt_shapes.mu and param_spec_tree
    nu = opt_shapes.nu and param_spec_tree
    return OptState(step=P(), mu=mu, nu=nu)
