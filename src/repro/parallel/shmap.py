"""shard_map compatibility shim.

jax renamed `check_rep` to `check_vma` (and moved shard_map out of
experimental) across versions; callers here always say `check_vma` and
this wrapper translates to whatever the installed jax understands.
"""
from __future__ import annotations

import inspect

try:
    from jax import shard_map as _impl          # jax >= 0.4.35
except ImportError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _impl

_PARAMS = set(inspect.signature(_impl).parameters)


def shard_map(f, **kwargs):
    if "check_vma" in kwargs and "check_vma" not in _PARAMS:
        flag = kwargs.pop("check_vma")
        if "check_rep" in _PARAMS:
            kwargs["check_rep"] = flag
    return _impl(f, **kwargs)
