"""shard_map compatibility shim + mesh bookkeeping helpers.

jax renamed `check_rep` to `check_vma` (and moved shard_map out of
experimental) across versions; callers here always say `check_vma` and
this wrapper translates to whatever the installed jax understands.

The helpers below are the mesh arithmetic the sharded offload hooks
(`repro.core.hooks`) need: a linearized per-device shard index computed
*inside* a shard_map body, and the local (per-shard) shape implied by a
PartitionSpec.
"""
from __future__ import annotations

import inspect
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

try:
    from jax import shard_map as _impl          # jax >= 0.4.35
except ImportError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _impl

_PARAMS = set(inspect.signature(_impl).parameters)


def shard_map(f, **kwargs):
    if "check_vma" in kwargs and "check_vma" not in _PARAMS:
        flag = kwargs.pop("check_vma")
        if "check_rep" in _PARAMS:
            kwargs["check_rep"] = flag
    return _impl(f, **kwargs)


def mesh_size(mesh) -> int:
    """Device count of a mesh; 1 for None (no mesh = one device)."""
    if mesh is None:
        return 1
    n = 1
    for s in mesh.shape.values():
        n *= int(s)
    return n


def axes_size(mesh, axes: Sequence[str]) -> int:
    """Product of the listed mesh axis sizes (1 for an empty list)."""
    n = 1
    for a in axes:
        n *= int(mesh.shape[a])
    return n


def linear_axis_index(mesh, axes: Sequence[str]):
    """Traced linearized index of the calling shard over `axes` (row
    major in the listed order). Only valid inside a shard_map body over
    a mesh where every listed axis is manual. Returns int32 0 for an
    empty axis list — callers use that as 'there is one shard'."""
    idx = jnp.zeros((), jnp.int32)
    for name in axes:
        idx = idx * mesh.shape[name] + jax.lax.axis_index(name)
    return idx


def spec_axes(spec) -> Tuple[str, ...]:
    """Mesh axis names a PartitionSpec mentions, in spec order."""
    out = []
    for entry in spec:
        if entry is None:
            continue
        for a in (entry if isinstance(entry, tuple) else (entry,)):
            out.append(a)
    return tuple(out)


def local_shape(global_shape: Tuple[int, ...], spec, mesh) \
        -> Tuple[int, ...]:
    """Per-shard block shape of a value sharded as `spec` on `mesh`."""
    dims = list(global_shape)
    for d, entry in enumerate(spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        dims[d] //= axes_size(mesh, axes)
    return tuple(dims)


def canonical_axis_entry(axes: Sequence[str]) -> Optional[Any]:
    """A PartitionSpec dim entry for `axes`: None when empty, the bare
    name for one axis (newer jax canonicalizes 1-tuples — doing it
    ourselves keeps specs comparable across versions), else the tuple."""
    axes = tuple(axes)
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes
