"""Pipeline parallelism: GPipe fill–drain microbatch schedule expressed as
shard_map + lax.ppermute over a "pipe" mesh axis.

The forward schedule is written explicitly (stage s processes microbatch
m = t - s at tick t; activations hop stages through ppermute); the backward
schedule falls out of jax.grad — the transpose of ppermute is the reverse
ppermute, so AD derives the drain-order backward pipeline automatically.
This composes with the TBA activation spool at the driver level: the
per-microbatch residuals the schedule keeps alive are exactly the
activations the paper's §4.4 argument offloads.

1F1B note: with AD-generated backward the memory profile is GPipe's
(all M microbatch residuals live at the fill/drain boundary); 1F1B
interleaving is a memory optimization the TBA offload substitutes for —
offloading the fill-phase residuals achieves the same peak with a simpler
schedule (DESIGN.md §4).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.shmap import shard_map as _shard_map


def pipeline_apply(stage_fn: Callable, params_stacked, x_mb, mesh,
                   axis: str = "pipe"):
    """Run microbatches through a stage pipeline.

    stage_fn(stage_params, x) -> y        (same shape as x)
    params_stacked: pytree with leading dim = n_stages (sharded over axis)
    x_mb: (M, mb, ...) microbatched input
    Returns (M, mb, ...) outputs of the final stage.
    """
    n_stages = mesh.shape[axis]
    M = x_mb.shape[0]
    T = M + n_stages - 1
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def per_stage(params_s, xs):
        # params_s: (1, ...) slice; xs: (M, mb, ...) only stage 0's real
        params_s = jax.tree.map(lambda a: a[0], params_s)
        sid = jax.lax.axis_index(axis)
        act_shape = xs.shape[1:]
        out = jnp.zeros((M,) + act_shape, xs.dtype)
        recv = jnp.zeros(act_shape, xs.dtype)

        def tick(carry, t):
            recv, out = carry
            m = t - sid                       # microbatch index here
            x_in = jnp.where(sid == 0, xs[jnp.clip(t, 0, M - 1)], recv)
            y = stage_fn(params_s, x_in)
            active = (m >= 0) & (m < M)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # last stage collects its result; others forward it
            out = jax.lax.cond(
                active & (sid == n_stages - 1),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(m, 0, M - 1), 0),
                lambda o: o, out)
            recv = jax.lax.ppermute(y, axis, perm) if perm else y
            return (recv, out), None

        (recv, out), _ = jax.lax.scan(tick, (recv, out), jnp.arange(T))
        return out[None]                      # (1, M, mb, ...) per stage

    specs_p = jax.tree.map(lambda _: P(axis), params_stacked)
    out = _shard_map(
        per_stage, mesh=mesh,
        in_specs=(specs_p, P()),              # x replicated; stage 0 reads
        out_specs=P(axis),
        check_vma=False,
    )(params_stacked, x_mb)
    return out[-1]                            # final stage's collection


def pipeline_loss_fn(stage_fn: Callable, loss_head: Callable, mesh,
                     axis: str = "pipe"):
    """Compose pipeline_apply with a loss head into a grad-able scalar fn:
    loss(params_stacked, x_mb, batch_aux) -> scalar."""

    def loss(params_stacked, x_mb, aux):
        y = pipeline_apply(stage_fn, params_stacked, x_mb, mesh, axis)
        return loss_head(y, aux)

    return loss
