"""Int8 error-feedback gradient compression for the DP all-reduce
(beyond-paper distributed-optimization feature; off by default).

Scheme (1-bit-Adam-family, simplified to int8):
  1. e += g                      (fold in the error-feedback residual)
  2. q = round(e / scale), scale = max|e| / 127     (per-leaf)
  3. e  = e - q * scale          (new residual: what quantization lost)
  4. all-gather (q, scale) over the dp axis, dequantize, mean

Wire cost per device: N bytes * (dp-1)/dp (int8 gather) + dp scales,
vs 2 * 2N * (dp-1)/dp for a bf16 ring all-reduce — a ~4x reduction.
Error feedback keeps the *accumulated* quantization error bounded, so SGD
converges to the same neighborhood (verified by tests/test_compress.py).
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.shmap import shard_map as _shard_map


def init_error_state(grads_like) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_like)


def _quantize(e):
    scale = jnp.max(jnp.abs(e)) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(e / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _leaf_compressed_mean(g, e, axis: str):
    """Inside shard_map: per-device grad g -> mean over `axis` via int8."""
    e = e + g.astype(jnp.float32)
    q, scale = _quantize(e)
    e_new = e - q.astype(jnp.float32) * scale
    qs = jax.lax.all_gather(q, axis)                 # (n, ...)
    ss = jax.lax.all_gather(scale, axis)             # (n,)
    n = qs.shape[0]
    deq = (qs.astype(jnp.float32)
           * ss.reshape((n,) + (1,) * (qs.ndim - 1)))
    return deq.mean(axis=0).astype(g.dtype), e_new


def compressed_mean_grads(grads, err_state, mesh, axis: str):
    """Mean per-device grads over the dp `axis` with int8 error feedback.

    grads/err_state: pytrees of per-device (unreduced) gradients living
    replicated over the other axes. Returns (mean_grads, new_err_state).
    """

    def body(g_tree, e_tree):
        pairs = jax.tree.map(
            lambda g, e: _leaf_compressed_mean(g, e, axis), g_tree, e_tree)
        means = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        errs = jax.tree.map(lambda p: p[1], pairs,
                            is_leaf=lambda x: isinstance(x, tuple))
        return means, errs

    spec = jax.tree.map(lambda _: P(), grads)
    return _shard_map(
        body, mesh=mesh,
        in_specs=(spec, spec), out_specs=(spec, spec),
        check_vma=False,
    )(grads, err_state)


def exact_mean_grads(grads, mesh, axis: str):
    """Reference bf16/f32 psum-mean (what compression replaces)."""

    def body(g_tree):
        return jax.tree.map(
            lambda g: (jax.lax.psum(g.astype(jnp.float32), axis)
                       / mesh.shape[axis]).astype(g.dtype), g_tree)

    spec = jax.tree.map(lambda _: P(), grads)
    return _shard_map(body, mesh=mesh, in_specs=(spec,), out_specs=spec,
                      check_vma=False)(grads)
