"""Public model API: build_model(config) -> ModelApi.

A ModelApi bundles the functional pieces every launcher/benchmark needs:
  init(rng)                          -> params
  forward(params, batch, settings)   -> (logits_f32, aux)        # full seq
  loss(params, batch, settings)      -> (scalar, metrics)
  prefill(params, batch, settings)   -> (last_logits, cache)
  decode_step(params, cache, batch, pos, settings) -> (logits, cache)
  input_specs(shape)                 -> batch of ShapeDtypeStructs

Embeddings note (DESIGN.md §2): input and output embeddings are stored
untied even for archs that tie them (sharding: the input table is gathered
row-wise, the output table is a vocab-sharded matmul; tying would force one
of the two into a pathological layout). The vocab is padded to a multiple of
256 and padded logits are masked to -inf before the softmax.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.layers import (dtype_of, embed_init, hint, init_norm,
                                 rms_norm, softcap)
from repro.models.transformer import (BlockDef, RunSettings, SegmentDef,
                                      apply_block, apply_block_decode,
                                      apply_block_decode_paged,
                                      build_segments, init_block, init_cache,
                                      remat_policy)

Params = Dict[str, Any]

MOE_LB_COEF = 0.01
MOE_Z_COEF = 0.001


@dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    segments: Tuple[SegmentDef, ...]
    enc_segments: Tuple[SegmentDef, ...]  # empty unless encoder-decoder
    init: Callable
    forward: Callable
    loss: Callable
    prefill: Callable
    decode_step: Callable
    decode_step_paged: Callable
    input_specs: Callable
    init_cache: Callable


# -------------------------------------------------------------- helpers

def _init_segments(key, segs, cfg, dtype):
    out = []
    for seg in segs:
        keys = jax.random.split(key, seg.n_repeat + 1)
        key = keys[0]
        def one(k, seg=seg):
            ks = jax.random.split(k, len(seg.blocks))
            return {f"b{i}": init_block(ks[i], b, cfg, dtype)
                    for i, b in enumerate(seg.blocks)}
        out.append(jax.vmap(one)(keys[1:]))
    return out


def _run_segments(x, seg_params, segs, cfg, settings, *, enc_states=None,
                  emit_cache=False, positions=None, cache_len=0,
                  hook_step=None, hook_base=0):
    """Apply all segments. Returns (x, caches, aux_totals).

    When the "spool" activation policy is active and a traced step
    counter is supplied (the jit engine's train step), each scanned
    layer is wrapped in the repro.core.hooks custom_vjp so its autograd
    residuals stream through the ActivationSpool instead of living in
    device memory for the whole step. `settings.spool_stages` (decoder
    stream only, i.e. hook_base == 0) may keep a subset of layers on
    device; a scanned stack is then split into contiguous runs because
    a scan body's residual structure must be uniform."""
    wrap = remat_policy(settings)
    aux_tot: Dict[str, jnp.ndarray] = {}
    caches = []
    hooked = (settings.activation_policy == "spool"
              and settings.hook_bridge is not None
              and hook_step is not None and not emit_cache)
    # Grad-tap mode rides the same per-layer custom_vjp machinery but
    # needs no spool offload: segments (or runs) that are not hooked get
    # a tap-only wrapper so the opt sink still sees every layer's grads.
    tapping = (settings.opt_sink is not None
               and hook_step is not None and not emit_cache)
    if hooked or tapping:
        from repro.core.hooks import (run_splits, spooled_scan_body,
                                      tapped_scan_body)
        step_f = jnp.asarray(hook_step, jnp.float32)
        mask = (settings.spool_stages
                if hooked and hook_base == 0 else None)
    layer0 = 0

    for seg, p_stack in zip(segs, seg_params):
        def body(x, p_layer, seg=seg):
            aux: Dict[str, jnp.ndarray] = {}
            cache_entries = {}
            for i, bdef in enumerate(seg.blocks):
                x, c = apply_block(bdef, p_layer[f"b{i}"], x, cfg, settings,
                                   positions=positions, enc_kv=enc_states,
                                   aux=aux)
                if emit_cache:
                    cache_entries[f"b{i}"] = _to_decode_cache(
                        bdef, c, cfg, cache_len)
            return x, (cache_entries if emit_cache else None, aux)

        if hooked or tapping:
            # enc_states must be an EXPLICIT custom_vjp input (a
            # closed-over differentiable value raises at trace time and
            # its cotangent would be lost), so cross-attention segments
            # carry (x, enc) through the scan — enc passes through
            # unchanged and its per-layer cotangents accumulate on the
            # backward carry exactly like the staged engine's enc_grad.
            def seg_fn(p_layer, carry_in, seg=seg):
                x_, enc_ = (carry_in if enc_states is not None
                            else (carry_in, None))
                aux: Dict[str, jnp.ndarray] = {}
                for i, bdef in enumerate(seg.blocks):
                    x_, _ = apply_block(bdef, p_layer[f"b{i}"], x_, cfg,
                                        settings, positions=positions,
                                        enc_kv=enc_, aux=aux)
                out = (x_, enc_) if enc_states is not None else x_
                return out, aux

            if hooked:
                wrapped = spooled_scan_body(seg_fn, settings.hook_bridge,
                                            mesh=settings.mesh,
                                            dp_axes=settings.dp_axes,
                                            tp_axis=settings.tp_axis,
                                            opt_sink=settings.opt_sink)
            if tapping:
                # remat_policy still applies to tap-only bodies so
                # "remat" keeps its memory profile under the tap
                tap_wrapped = tapped_scan_body(wrap(seg_fn),
                                               settings.opt_sink,
                                               mesh=settings.mesh)
            seg_mask = [hooked and (bool(mask[layer0 + i])
                        if mask is not None and layer0 + i < len(mask)
                        else True)
                        for i in range(seg.n_repeat)]
            carry = (x, enc_states) if enc_states is not None else x
            for start, end, offl in run_splits(seg_mask):
                p_run = jax.tree.map(lambda a: a[start:end], p_stack)
                if offl:
                    idxs = (jnp.arange(start, end, dtype=jnp.float32)
                            + (hook_base + layer0))

                    def scan_body(c, inp, wrapped=wrapped):
                        p_layer, idx = inp
                        return wrapped(p_layer, c, step_f, idx)

                    carry, aux_stack = jax.lax.scan(scan_body, carry,
                                                    (p_run, idxs))
                elif tapping:
                    idxs = (jnp.arange(start, end, dtype=jnp.float32)
                            + (hook_base + layer0))

                    def scan_body(c, inp, tap_wrapped=tap_wrapped):
                        p_layer, idx = inp
                        return tap_wrapped(p_layer, c, step_f, idx)

                    carry, aux_stack = jax.lax.scan(scan_body, carry,
                                                    (p_run, idxs))
                else:

                    def scan_body(c, p_layer, seg_fn=seg_fn):
                        return seg_fn(p_layer, c)

                    carry, aux_stack = jax.lax.scan(scan_body, carry,
                                                    p_run)
                for k, v in aux_stack.items():
                    aux_tot[k] = aux_tot.get(k, 0.0) + jnp.sum(v)
            x = carry[0] if enc_states is not None else carry
            caches.append(None)
            layer0 += seg.n_repeat
            continue

        body = wrap(body)
        x, (cache_stack, aux_stack) = jax.lax.scan(
            lambda c, p: body(c, p), x, p_stack)
        caches.append(cache_stack)
        for k, v in aux_stack.items():
            aux_tot[k] = aux_tot.get(k, 0.0) + jnp.sum(v)
        layer0 += seg.n_repeat
    return x, caches, aux_tot


def _to_decode_cache(bdef: BlockDef, cache, cfg: ModelConfig,
                     cache_len: int):
    """Convert a prefill cache entry to the decode layout.

    Attention caches are sized min(window, cache_len) (ring for windowed
    layers): token at position p lives at slot p % W, so a prefill of S
    tokens contributes its last W via a roll of (S - W) % W."""
    if bdef.mixer == "attn":
        k, v = cache
        S = k.shape[1]
        target = min(bdef.window, cache_len) if bdef.window else cache_len
        if S >= target:
            k, v = k[:, -target:], v[:, -target:]
            shift = (S - target) % target
            if shift:
                k = jnp.roll(k, shift, axis=1)
                v = jnp.roll(v, shift, axis=1)
        else:
            pad = [(0, 0), (0, target - S), (0, 0), (0, 0)]
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        return {"k": k, "v": v}
    if bdef.mixer == "cross":
        k, v = cache
        return {"k": k, "v": v}
    return cache  # rglru / ssm already in decode layout


def _embed_in(params, batch, cfg: ModelConfig, settings):
    dtype = dtype_of(settings.param_dtype)
    if cfg.input_kind == "embeddings":
        x = batch["embeddings"].astype(dtype)
        x = jnp.einsum("bsd,de->bse", x, params["frontend_proj"])
    else:
        x = params["embed"][batch["tokens"]]
    if cfg.scale_embed:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dtype)
    if not cfg.use_rope:
        S = x.shape[1]
        x = x + params["pos_embed"][:S][None].astype(dtype)
    # gathers from the vocab-sharded table come out with ambiguous layout;
    # pin batch to the dp axes so the whole stack keeps it (layers.hint).
    return hint(x, settings, "b", None, None)


def _head(params, x, cfg: ModelConfig, settings=None):
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
    logits = logits.astype(jnp.float32)
    # batch over dp, vocab over tp — without this GSPMD materialised the
    # full-batch fp32 logits (40 GB/device) on the 256-chip dry-run.
    logits = hint(logits, settings, "b", None, "m")
    if cfg.final_logit_softcap:
        logits = softcap(logits, cfg.final_logit_softcap)
    # mask the padded vocab tail
    if cfg.padded_vocab != cfg.vocab_size:
        bias = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab_size,
                         0.0, -1e30).astype(jnp.float32)
        logits = logits + bias
    return logits


# -------------------------------------------------------------- build

def build_model(cfg: ModelConfig) -> ModelApi:
    cfg = cfg.validate()
    segs = tuple(build_segments(cfg))
    enc_segs: Tuple[SegmentDef, ...] = ()
    if cfg.family == "encdec":
        enc_cfg = dataclasses.replace(cfg, causal=False)
        enc_segs = tuple(build_segments(enc_cfg))
        dec_blocks = (BlockDef("attn", mlp=None),
                      BlockDef("cross", mlp="dense"))
        segs = (SegmentDef(dec_blocks, cfg.num_decoder_layers),)

    def init(rng) -> Params:
        dtype = dtype_of(cfg.dtype)
        ks = jax.random.split(rng, 8)
        params: Params = {"final_norm": init_norm(cfg.d_model, dtype)}
        if cfg.input_kind == "embeddings":
            eye = jnp.eye(cfg.d_model, dtype=jnp.float32)
            noise = 0.02 * jax.random.normal(ks[0],
                                             (cfg.d_model, cfg.d_model))
            params["frontend_proj"] = (eye + noise).astype(dtype)
        else:
            params["embed"] = embed_init(
                ks[0], (cfg.padded_vocab, cfg.d_model), dtype)
        params["unembed"] = embed_init(
            ks[1], (cfg.d_model, cfg.padded_vocab), dtype)
        if not cfg.use_rope:
            params["pos_embed"] = embed_init(
                ks[2], (cfg.max_position, cfg.d_model), dtype)
        params["segments"] = _init_segments(ks[3], segs, cfg, dtype)
        if enc_segs:
            params["enc_segments"] = _init_segments(ks[4], enc_segs,
                                                    dataclasses.replace(
                                                        cfg, causal=False),
                                                    dtype)
            params["enc_norm"] = init_norm(cfg.d_model, dtype)
        return params

    def _encode(params, batch, settings):
        from repro.core.hooks import ENC_STAGE_BASE
        enc_cfg = dataclasses.replace(cfg, causal=False)
        x = _embed_in(params, {"tokens": batch["enc_tokens"]}, enc_cfg,
                      settings)
        pos = jnp.arange(x.shape[1])
        x, _, _ = _run_segments(x, params["enc_segments"], enc_segs,
                                enc_cfg, settings, positions=pos,
                                hook_step=batch.get("_spool_step"),
                                hook_base=ENC_STAGE_BASE)
        return rms_norm(x, params["enc_norm"]["scale"], cfg.norm_eps)

    def _enc_states(params, batch, settings):
        if cfg.family == "encdec":
            return _encode(params, batch, settings)
        if cfg.family == "vlm":
            # stub frontend: precomputed patch embeddings at d_model
            return batch["enc_embeddings"].astype(dtype_of(cfg.dtype))
        return None

    def forward(params, batch, settings: RunSettings, *, emit_cache=False,
                cache_len=0):
        enc_states = _enc_states(params, batch, settings)
        x = _embed_in(params, batch, cfg, settings)
        positions = jnp.arange(x.shape[1]) if cfg.use_rope else None
        x, caches, aux = _run_segments(
            x, params["segments"], segs, cfg, settings,
            enc_states=enc_states, emit_cache=emit_cache,
            positions=positions, cache_len=cache_len or x.shape[1],
            hook_step=batch.get("_spool_step"))
        logits = _head(params, x, cfg, settings)
        return (logits, caches, aux) if emit_cache else (logits, aux)

    def _ce_terms(logits, labels):
        """(sum nll, token count) — vocab-parallel-friendly label pick:
        take_along_axis is a gather along the tp-sharded vocab dim and
        makes GSPMD all-gather the logits; the masked reduction
        partitions cleanly (Megatron's vocab-parallel cross-entropy)."""
        mask = (labels >= 0).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        vmask = (jnp.arange(logits.shape[-1],
                            dtype=labels.dtype)[None, None]
                 == jnp.maximum(labels, 0)[..., None])
        picked = jnp.sum(jnp.where(vmask, logits, 0.0), axis=-1)
        return ((lse - picked) * mask).sum(), mask.sum()

    def forward_hidden(params, batch, settings: RunSettings):
        """Backbone only: final hidden states (pre-head), aux losses."""
        enc_states = _enc_states(params, batch, settings)
        x = _embed_in(params, batch, cfg, settings)
        positions = jnp.arange(x.shape[1]) if cfg.use_rope else None
        x, _, aux = _run_segments(
            x, params["segments"], segs, cfg, settings,
            enc_states=enc_states, positions=positions,
            cache_len=x.shape[1], hook_step=batch.get("_spool_step"))
        return x, aux

    def loss(params, batch, settings: RunSettings):
        labels = batch["labels"]
        S = labels.shape[1]
        if settings.ce_chunk and S % settings.ce_chunk == 0 \
                and S > settings.ce_chunk:
            # chunked CE: the (B, S, V) fp32 logits never materialise —
            # each chunk's head matmul + CE runs under remat, so backward
            # recomputes one chunk of logits at a time. At V=152k, B=256,
            # S=4096 this removes ~2.5 GB/device of fp32 logits (x3 with
            # AD buffers) from the dry-run peak.
            x, aux = forward_hidden(params, batch, settings)
            nc = S // settings.ce_chunk
            xc = x.reshape(x.shape[0], nc, settings.ce_chunk, -1)
            lc = labels.reshape(labels.shape[0], nc, settings.ce_chunk)

            @jax.checkpoint
            def chunk_terms(args):
                xi, li = args
                logits = _head(params, xi, cfg, settings)
                return _ce_terms(logits, li)

            nll, toks = jax.lax.map(
                chunk_terms, (xc.swapaxes(0, 1), lc.swapaxes(0, 1)))
            ce = nll.sum() / jnp.maximum(toks.sum(), 1.0)
            tokens = toks.sum()
        else:
            logits, aux = forward(params, batch, settings)
            nll, tokens = _ce_terms(logits, labels)
            ce = nll / jnp.maximum(tokens, 1.0)
        total = ce
        metrics = {"ce": ce, "tokens": tokens}
        if "moe_lb" in aux:
            total = total + MOE_LB_COEF * aux["moe_lb"] \
                          + MOE_Z_COEF * aux["moe_z"]
            metrics.update(moe_lb=aux["moe_lb"], moe_z=aux["moe_z"])
        metrics["loss"] = total
        return total, metrics

    def prefill(params, batch, settings: RunSettings, *, emit_cache=True,
                cache_len=0):
        out = forward(params, batch, settings, emit_cache=emit_cache,
                      cache_len=cache_len)
        if emit_cache:
            logits, caches, _ = out
            return logits[:, -1:], caches
        logits, _ = out
        return logits[:, -1:], None

    def _decode_embed(params, batch, pos, settings: RunSettings):
        """Embed one decode token per row. pos: scalar or (B,) int32."""
        if cfg.input_kind == "embeddings":
            x = batch["embeddings"].astype(dtype_of(settings.param_dtype))
            x = jnp.einsum("bsd,de->bse", x, params["frontend_proj"])
        else:
            x = params["embed"][batch["tokens"]]
        if cfg.scale_embed:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
        if not cfg.use_rope:
            pos = jnp.asarray(pos)
            if pos.ndim == 1:           # per-row positions: (B, 1, D)
                x = x + params["pos_embed"][pos][:, None].astype(x.dtype)
            else:
                x = x + jax.lax.dynamic_slice_in_dim(
                    params["pos_embed"], pos, 1,
                    axis=0)[None].astype(x.dtype)
        return x

    def decode_step(params, cache, batch, pos, settings: RunSettings):
        """One token for the whole batch. batch: {"tokens": (B, 1)} (or
        {"embeddings"}). pos: scalar int32 position of this token, or a
        (B,) int32 vector of per-row positions (continuous batching:
        each serving slot decodes its own sequence)."""
        x = _decode_embed(params, batch, pos, settings)
        new_caches = []
        for seg, p_stack, c_stack in zip(segs, params["segments"], cache):
            def body(x1, inp, seg=seg):
                p_layer, c_layer = inp
                new_c = {}
                for i, bdef in enumerate(seg.blocks):
                    x1, nc = apply_block_decode(
                        bdef, p_layer[f"b{i}"], x1, c_layer[f"b{i}"], pos,
                        cfg, settings)
                    new_c[f"b{i}"] = nc
                return x1, new_c
            x, nc_stack = jax.lax.scan(body, x, (p_stack, c_stack))
            new_caches.append(nc_stack)
        logits = _head(params, x, cfg, settings)
        return logits, new_caches

    def decode_step_paged(params, pools, resident, tables, batch, pos,
                          settings: RunSettings):
        """One token per serving slot against a paged KV cache
        (repro.kvcache). Layers whose cache is pageable (full-attention)
        read/write the shared device page pools through each row's page
        table; the rest (ring attention, rglru/ssm state, cross K/V)
        keep per-slot dense entries in `resident`.

          pools:    per-segment {f"b{i}": {"k","v"}} page-pool stacks,
                    leading dim n_repeat, only for paged blocks.
          resident: per-segment {f"b{i}": cache} stacks for the rest.
          tables:   (B, max_pages) int32 physical page table per row.
          pos:      (B,) int32 per-row absolute positions.

        Returns (logits, new_pools, new_resident).
        """
        x = _decode_embed(params, batch, pos, settings)
        new_pools, new_resident = [], []
        for seg, p_stack, pool_stack, res_stack in zip(
                segs, params["segments"], pools, resident):
            def body(x1, inp, seg=seg):
                p_layer, pool_layer, res_layer = inp
                np_, nr_ = {}, {}
                for i, bdef in enumerate(seg.blocks):
                    bid = f"b{i}"
                    if bid in pool_layer:
                        x1, np_[bid] = apply_block_decode_paged(
                            bdef, p_layer[bid], x1, pool_layer[bid],
                            tables, pos, cfg, settings)
                    else:
                        x1, nr_[bid] = apply_block_decode(
                            bdef, p_layer[bid], x1, res_layer[bid], pos,
                            cfg, settings)
                return x1, (np_, nr_)
            x, (npool, nres) = jax.lax.scan(
                body, x, (p_stack, pool_stack, res_stack))
            new_pools.append(npool)
            new_resident.append(nres)
        logits = _head(params, x, cfg, settings)
        return logits, new_pools, new_resident

    def input_specs(shape: ShapeConfig, *, for_loss: bool = True):
        """ShapeDtypeStruct stand-ins for every model input of this cell."""
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        bf16 = jnp.bfloat16
        if shape.kind == "decode":
            batch = ({"embeddings": jax.ShapeDtypeStruct((B, 1, cfg.d_model),
                                                         bf16)}
                     if cfg.input_kind == "embeddings"
                     else {"tokens": jax.ShapeDtypeStruct((B, 1), i32)})
            cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
            return {"batch": batch, "cache": cache,
                    "pos": jax.ShapeDtypeStruct((), i32)}
        batch: Dict[str, Any] = {}
        if cfg.input_kind == "embeddings":
            batch["embeddings"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                       bf16)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        if cfg.family == "vlm":
            batch["enc_embeddings"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq_len, cfg.d_model), bf16)
        if cfg.family == "encdec":
            batch["enc_tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        if for_loss and shape.kind == "train":
            batch["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        return {"batch": batch}

    return ModelApi(
        cfg=cfg, segments=segs, enc_segments=enc_segs, init=init,
        forward=forward, loss=loss, prefill=prefill,
        decode_step=decode_step, decode_step_paged=decode_step_paged,
        input_specs=input_specs,
        init_cache=lambda B, S, dtype=jnp.bfloat16: init_cache(
            cfg, B, S, dtype),
    )
