"""RG-LRU recurrent block (Griffin / recurrentgemma). [arXiv:2402.19427]

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)
a_t = exp(-c * softplus(Lambda) * r_t),  r_t, i_t input-dependent gates.

Training/prefill uses an associative scan over the sequence (XLA path; the
Pallas kernel in kernels/rglru_scan.py is the chunked TPU version); decode is
a single-step update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_conv1d, dense_init, init_conv1d

_C = 8.0  # Griffin's fixed gate temperature


def init_rglru(key, cfg: ModelConfig, dtype):
    D = cfg.d_model
    W = cfg.rglru_width or D
    ks = jax.random.split(key, 6)
    return {
        "w_branch_gate": dense_init(ks[0], (D, W), D, dtype),
        "w_in": dense_init(ks[1], (D, W), D, dtype),
        "conv": init_conv1d(ks[2], cfg.rglru_conv_width, W, dtype),
        "w_a": dense_init(ks[3], (W, W), W, dtype),
        "b_a": jnp.zeros((W,), jnp.float32),
        "w_i": dense_init(ks[4], (W, W), W, dtype),
        "b_i": jnp.zeros((W,), jnp.float32),
        # softplus(lambda_p) ~ 0.3..1 -> slow decay at init
        "lambda_p": jnp.full((W,), 0.5, jnp.float32),
        "w_out": dense_init(ks[5], (W, D), W, dtype),
    }


def _gates(p, u):
    """u: (..., W) post-conv signal -> (log_a, scaled_input) fp32."""
    u32 = u.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", u32,
                                  p["w_a"].astype(jnp.float32)) + p["b_a"])
    i = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", u32,
                                  p["w_i"].astype(jnp.float32)) + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lambda_p"]) * r          # (..., W) < 0
    a2 = jnp.exp(2.0 * log_a)
    scaled = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-9)) * (i * u32)
    return log_a, scaled


def rglru_scan_xla(log_a, x):
    """Associative scan of h_t = a_t h_{t-1} + x_t over axis 1.

    log_a, x: (B, S, W) fp32. Returns h: (B, S, W)."""
    def combine(c1, c2):
        (la1, b1), (la2, b2) = c1, c2
        return la1 + la2, jnp.exp(la2) * b1 + b2
    la, h = jax.lax.associative_scan(combine, (log_a, x), axis=1)
    return h


def apply_rglru(p, x, cfg: ModelConfig, *, impl: str = "xla"):
    """Training/prefill. x: (B, S, D) -> (y, cache)."""
    gate = jax.nn.gelu(jnp.einsum("...d,dw->...w", x, p["w_branch_gate"])
                       .astype(jnp.float32))
    u = jnp.einsum("...d,dw->...w", x, p["w_in"])
    u, conv_state = apply_conv1d(p["conv"], u)
    log_a, scaled = _gates(p, u)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops
        h = kops.rglru_scan(log_a, scaled,
                            interpret=(impl == "pallas_interpret"))
    else:
        h = rglru_scan_xla(log_a, scaled)
    y = (h * gate).astype(x.dtype)
    out = jnp.einsum("...w,wd->...d", y, p["w_out"])
    cache = {"conv": conv_state, "h": h[:, -1]}
    return out, cache


def decode_rglru(p, x1, cache, cfg: ModelConfig):
    """One-token decode. x1: (B, 1, D)."""
    gate = jax.nn.gelu(jnp.einsum("...d,dw->...w", x1, p["w_branch_gate"])
                       .astype(jnp.float32))
    u = jnp.einsum("...d,dw->...w", x1, p["w_in"])
    u, conv_state = apply_conv1d(p["conv"], u, cache["conv"])
    log_a, scaled = _gates(p, u)
    h = jnp.exp(log_a[:, 0]) * cache["h"] + scaled[:, 0]      # (B, W)
    y = (h[:, None] * gate).astype(x1.dtype)
    out = jnp.einsum("...w,wd->...d", y, p["w_out"])
    return out, {"conv": conv_state, "h": h}
