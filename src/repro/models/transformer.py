"""Model assembly: blocks -> segments (scanned super-layers) -> full models.

Every architecture is a list of *segments*; a segment is a tuple of
heterogeneous blocks (a "super-layer") repeated n times via lax.scan with
stacked parameters. This keeps the HLO small for 100-layer models while
supporting mixed-kind stacks (gemma2 local/global alternation,
recurrentgemma's rglru-rglru-attn pattern, llama-3.2-vision's every-5th
cross-attention layer, kimi's leading dense layer).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ModelConfig
from repro.models import mamba2 as m2
from repro.models import rglru as rg
from repro.models.attention import attend, attend_decode
from repro.models.layers import (apply_mlp, apply_rope, dense_init, dtype_of,
                                 embed_init, init_mlp, init_norm, rms_norm,
                                 softcap)
from repro.models.moe import MoESettings, apply_moe, init_moe

Params = Dict[str, Any]


# ====================================================================
# Segment construction
# ====================================================================

@dataclass(frozen=True)
class BlockDef:
    mixer: str                    # "attn" | "cross" | "rglru" | "ssm"
    window: int = 0               # sliding window for attn (0 = full)
    mlp: Optional[str] = "dense"  # "dense" | "moe" | None
    dense_ff: int = 0             # override d_ff for this block's dense MLP


@dataclass(frozen=True)
class SegmentDef:
    blocks: Tuple[BlockDef, ...]
    n_repeat: int


def build_segments(cfg: ModelConfig) -> List[SegmentDef]:
    if cfg.family == "ssm":
        return [SegmentDef((BlockDef("ssm", mlp=None),), cfg.num_layers)]

    if cfg.hybrid_pattern:
        pat = tuple(
            BlockDef("attn", window=cfg.sliding_window) if k == "attn"
            else BlockDef("rglru") for k in cfg.hybrid_pattern)
        full, rem = divmod(cfg.num_layers, len(pat))
        segs = [SegmentDef(pat, full)] if full else []
        if rem:
            segs.append(SegmentDef(pat[:rem], 1))
        return segs

    if cfg.cross_attn_period:
        k = cfg.cross_attn_period
        assert cfg.num_layers % k == 0
        blocks = tuple([BlockDef("attn")] * (k - 1) + [BlockDef("cross")])
        return [SegmentDef(blocks, cfg.num_layers // k)]

    mlp_kind = "moe" if cfg.moe_num_experts else "dense"
    if cfg.local_global_period:
        p = cfg.local_global_period
        assert cfg.num_layers % p == 0
        blocks = tuple(
            BlockDef("attn",
                     window=cfg.sliding_window if i < p - 1 else 0,
                     mlp=mlp_kind)
            for i in range(p))
        return [SegmentDef(blocks, cfg.num_layers // p)]

    segs = []
    n_dense = cfg.moe_first_dense_layers if mlp_kind == "moe" else 0
    if n_dense:
        segs.append(SegmentDef(
            (BlockDef("attn", window=cfg.sliding_window, mlp="dense",
                      dense_ff=cfg.moe_dense_ff or cfg.d_ff),), n_dense))
    segs.append(SegmentDef(
        (BlockDef("attn", window=cfg.sliding_window, mlp=mlp_kind),),
        cfg.num_layers - n_dense))
    return segs


# ====================================================================
# Run-time settings (how to execute, orthogonal to what the model is)
# ====================================================================

@dataclass(frozen=True)
class RunSettings:
    attn_impl: str = "xla"            # xla | pallas | pallas_interpret
    attn_chunk: int = 1024
    # Activation placement: "keep" | "remat" | "offload" | "offload_ssd"
    # (the paper's three ROK strategies + the in-graph host-offload tier)
    # | "spool" (per-layer residuals stream through the ActivationSpool
    # via io_callback hooks — repro.core.hooks; requires hook_bridge).
    activation_policy: str = "keep"
    offload_names: Tuple[str, ...] = ("blk_in",)
    # "spool" policy only: the HookBridge the hooks talk to, and an
    # optional per-decoder-layer offload mask (None = offload every
    # layer; False entries keep that layer's residuals on device —
    # AdaptivePolicy.plan_for_jit() emits these).
    hook_bridge: Any = None
    spool_stages: Optional[Tuple[bool, ...]] = None
    # Eager optimizer overlap: a sink with `on_grads(step, stage,
    # leaves)` — when set (and a hook step is provided), every scanned
    # segment's backward taps its per-layer parameter grads to it the
    # moment they materialize (repro.core.hooks._tap_grads). Segments
    # that are not spool-offloaded get a tap-only wrapper.
    opt_sink: Any = None
    mesh: Any = None                  # jax Mesh (sharding hints + EP)
    ep_axis: Optional[str] = None     # expert-parallel axis (MoE shard_map)
    tp_axis: Optional[str] = None     # tensor-parallel axis (hints)
    dp_axes: Tuple[str, ...] = ()
    param_dtype: str = "bfloat16"
    moe_capacity_factor: float = 1.25
    # chunked cross-entropy: compute the vocab projection + CE per
    # sequence chunk under remat (logits never fully materialise; bwd
    # recomputes each chunk's logits). 0 = off.
    ce_chunk: int = 0


def remat_policy(settings: RunSettings):
    """Returns (wrap_segment_body) implementing the placement strategy."""
    pol = settings.activation_policy
    if pol == "keep":
        return lambda f: f
    if pol == "spool":
        # the spool hooks are applied by _run_segments itself (they need
        # the traced step/stage scalars); outside a hooked train step —
        # serving, eval, a loss call with no step counter — residuals
        # simply stay on device
        return lambda f: f
    if pol == "remat":
        return lambda f: jax.checkpoint(f, prevent_cse=False)
    if pol in ("offload", "offload_ssd"):
        policy = jax.checkpoint_policies.save_and_offload_only_these_names(
            names_which_can_be_saved=[],
            names_which_can_be_offloaded=list(settings.offload_names),
            offload_src="device", offload_dst="pinned_host")
        return lambda f: jax.checkpoint(f, policy=policy, prevent_cse=False)
    if pol == "save_names":
        policy = jax.checkpoint_policies.save_only_these_names(
            *settings.offload_names)
        return lambda f: jax.checkpoint(f, policy=policy, prevent_cse=False)
    raise ValueError(f"unknown activation policy {pol!r}")


# ====================================================================
# Block init
# ====================================================================

def _init_attn(key, cfg: ModelConfig, dtype) -> Params:
    D, Hq, KV, hd = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                     cfg.resolved_head_dim)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, Hq, hd), D, dtype),
        "wk": dense_init(ks[1], (D, KV, hd), D, dtype),
        "wv": dense_init(ks[2], (D, KV, hd), D, dtype),
        "wo": dense_init(ks[3], (Hq, hd, D), Hq * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hq, hd), dtype)
        p["bk"] = jnp.zeros((KV, hd), dtype)
        p["bv"] = jnp.zeros((KV, hd), dtype)
    return p


def init_block(key, bdef: BlockDef, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"norm": init_norm(cfg.d_model, dtype)}
    if bdef.mixer in ("attn", "cross"):
        p["attn"] = _init_attn(ks[0], cfg, dtype)
    elif bdef.mixer == "rglru":
        p["rglru"] = rg.init_rglru(ks[0], cfg, dtype)
    elif bdef.mixer == "ssm":
        p["ssm"] = m2.init_mamba2(ks[0], cfg, dtype)
    if cfg.post_block_norm:
        p["post_norm"] = init_norm(cfg.d_model, dtype)
    if bdef.mlp == "dense":
        ff = bdef.dense_ff or cfg.d_ff
        p["mlp"] = init_mlp(ks[1], cfg.d_model, ff, cfg.mlp_glu, dtype)
        p["mlp_norm"] = init_norm(cfg.d_model, dtype)
        if cfg.post_block_norm:
            p["mlp_post_norm"] = init_norm(cfg.d_model, dtype)
    elif bdef.mlp == "moe":
        p["moe"] = init_moe(ks[1], cfg.d_model, cfg.d_ff,
                            cfg.moe_num_experts, dtype)
        if cfg.moe_shared_experts:
            p["moe_shared"] = init_mlp(
                ks[2], cfg.d_model, cfg.d_ff * cfg.moe_shared_experts,
                True, dtype)
        p["mlp_norm"] = init_norm(cfg.d_model, dtype)
        if cfg.post_block_norm:
            p["mlp_post_norm"] = init_norm(cfg.d_model, dtype)
    return p


# ====================================================================
# Block apply — full sequence (train / prefill)
# ====================================================================

def _qkv(p, x, cfg, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.use_rope and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mlp_sublayer(bdef: BlockDef, p, x, cfg: ModelConfig,
                  settings: RunSettings, aux: Dict):
    if bdef.mlp is None:
        return x
    h = rms_norm(x, p["mlp_norm"]["scale"], cfg.norm_eps)
    if bdef.mlp == "dense":
        m = apply_mlp(p["mlp"], h, cfg.act, cfg.mlp_glu)
    else:
        moe_set = MoESettings(cfg.moe_num_experts, cfg.moe_top_k,
                              settings.moe_capacity_factor, cfg.act)
        m, moe_aux = apply_moe(p["moe"], h, moe_set, mesh=settings.mesh,
                               ep_axis=settings.ep_axis,
                               dp_axes=settings.dp_axes)
        for k2, v2 in moe_aux.items():
            aux[k2] = aux.get(k2, 0.0) + v2
        if "moe_shared" in p:
            m = m + apply_mlp(p["moe_shared"], h, cfg.act, True)
    if cfg.post_block_norm:
        m = rms_norm(m, p["mlp_post_norm"]["scale"], cfg.norm_eps)
    return x + m


def apply_block(bdef: BlockDef, p, x, cfg: ModelConfig,
                settings: RunSettings, *, positions=None, enc_kv=None,
                aux: Dict) -> Tuple[jnp.ndarray, Any]:
    """Full-sequence block. Returns (x, cache_entry)."""
    x = checkpoint_name(x, "blk_in")
    h = rms_norm(x, p["norm"]["scale"], cfg.norm_eps)
    cache = None
    if bdef.mixer == "attn":
        q, k, v = _qkv(p["attn"], h, cfg, positions)
        o = attend(q, k, v, causal=cfg.causal, window=bdef.window,
                   logit_cap=cfg.attn_logit_softcap,
                   chunk=settings.attn_chunk, impl=settings.attn_impl,
                   settings=settings)
        o = checkpoint_name(o, "attn_out")
        mix = jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
        cache = (k, v)
    elif bdef.mixer == "cross":
        # enc_kv: encoder hidden states (B, Se, D); each cross layer
        # projects its own K/V (no RoPE on cross attention).
        q = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wq"])
        ek = jnp.einsum("bsd,dhk->bshk", enc_kv, p["attn"]["wk"])
        ev = jnp.einsum("bsd,dhk->bshk", enc_kv, p["attn"]["wv"])
        o = attend(q, ek, ev, causal=False, chunk=settings.attn_chunk,
                   impl=settings.attn_impl, settings=settings)
        mix = jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
        cache = (ek, ev)
    elif bdef.mixer == "rglru":
        mix, cache = rg.apply_rglru(p["rglru"], h, cfg,
                                    impl=settings.attn_impl)
    elif bdef.mixer == "ssm":
        mix, cache = m2.apply_mamba2(p["ssm"], h, cfg,
                                     impl=settings.attn_impl)
    else:
        raise ValueError(bdef.mixer)
    if cfg.post_block_norm:
        mix = rms_norm(mix, p["post_norm"]["scale"], cfg.norm_eps)
    x = x + mix
    x = _mlp_sublayer(bdef, p, x, cfg, settings, aux)
    return x, cache


# ====================================================================
# Block apply — single-token decode against caches
# ====================================================================

def _decode_positions(pos, cfg: ModelConfig):
    """RoPE positions for one decode token: (1, 1) for a shared scalar
    pos, (B, 1) for per-row positions (continuous batching)."""
    if not cfg.use_rope:
        return None
    pos = jnp.asarray(pos)
    if pos.ndim == 1:
        return pos[:, None]
    return jnp.full((1,), pos, jnp.int32)[None]


def apply_block_decode(bdef: BlockDef, p, x1, cache, pos, cfg: ModelConfig,
                       settings: RunSettings) -> Tuple[jnp.ndarray, Any]:
    """x1: (B, 1, D). cache: per-mixer pytree. pos: scalar int32, or a
    (B,) int32 vector when each batch row decodes at its own absolute
    position (per-slot continuous batching)."""
    h = rms_norm(x1, p["norm"]["scale"], cfg.norm_eps)
    pos = jnp.asarray(pos)
    if bdef.mixer == "attn":
        ck, cv = cache["k"], cache["v"]
        S = ck.shape[1]
        ring = bool(bdef.window) and S == bdef.window
        q, k, v = _qkv(p["attn"], h, cfg, _decode_positions(pos, cfg))
        slot = jnp.mod(pos, S) if ring else pos
        if pos.ndim == 1:
            rows = jnp.arange(x1.shape[0])
            ck = ck.at[rows, slot].set(k[:, 0].astype(ck.dtype))
            cv = cv.at[rows, slot].set(v[:, 0].astype(cv.dtype))
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(
                ck, k.astype(ck.dtype), slot, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cv, v.astype(cv.dtype), slot, axis=1)
        o = attend_decode(q, ck, cv, pos, window=bdef.window,
                          logit_cap=cfg.attn_logit_softcap, ring=ring)
        mix = jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
        new_cache = {"k": ck, "v": cv}
    elif bdef.mixer == "cross":
        q = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wq"])
        o = attend_decode(q, cache["k"], cache["v"],
                          jnp.asarray(cache["k"].shape[1] - 1))
        mix = jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
        new_cache = cache
    elif bdef.mixer == "rglru":
        mix, new_cache = rg.decode_rglru(p["rglru"], h, cache, cfg)
    elif bdef.mixer == "ssm":
        mix, new_cache = m2.decode_mamba2(p["ssm"], h, cache, cfg)
    else:
        raise ValueError(bdef.mixer)
    if cfg.post_block_norm:
        mix = rms_norm(mix, p["post_norm"]["scale"], cfg.norm_eps)
    x1 = x1 + mix
    aux: Dict = {}
    x1 = _mlp_sublayer(bdef, p, x1, cfg, settings, aux)
    return x1, new_cache


def apply_block_decode_paged(bdef: BlockDef, p, x1, pool, tables, pos,
                             cfg: ModelConfig, settings: RunSettings
                             ) -> Tuple[jnp.ndarray, Any]:
    """Paged-KV decode for one full-attention block (repro.kvcache).

    Instead of a per-slot dense (B, S, H, D) cache, K/V live in a shared
    device page pool and each batch row owns a page table:

      pool:   {"k","v"}: (N, P, Hkv, D) — N physical pages of P tokens
              for THIS layer (page 0 is the reserved null page that
              idle slots scribble into).
      tables: (B, max_pages) int32 — physical page id of each logical
              page; unallocated entries point at the null page.
      pos:    (B,) int32 — absolute position of the current token.

    The step scatters the new K/V into page pos//P at offset pos%P,
    then gathers each row's pages back into a contiguous
    (B, max_pages*P, Hkv, D) view and runs the exact dense decode
    attention on it — token s of row b lives at gathered index s, so
    the masked scores (and therefore the logits) are bitwise identical
    to a dense cache of length max_pages*P holding the same sequence.
    Returns (x1, new_pool).
    """
    h = rms_norm(x1, p["norm"]["scale"], cfg.norm_eps)
    ck, cv = pool["k"], pool["v"]
    P = ck.shape[1]
    B = x1.shape[0]
    n_pages = tables.shape[1]
    q, k, v = _qkv(p["attn"], h, cfg, _decode_positions(pos, cfg))
    rows = jnp.arange(B)
    phys = tables[rows, pos // P]
    off = pos % P
    ck = ck.at[phys, off].set(k[:, 0].astype(ck.dtype))
    cv = cv.at[phys, off].set(v[:, 0].astype(cv.dtype))
    # gather the rows' logical sequences: (B, n_pages, P, H, D) ->
    # (B, n_pages*P, H, D); positions beyond pos are masked by
    # attend_decode, so stale bytes in recycled pages never score
    gk = ck[tables].reshape(B, n_pages * P, *ck.shape[2:])
    gv = cv[tables].reshape(B, n_pages * P, *cv.shape[2:])
    o = attend_decode(q, gk, gv, pos, window=bdef.window,
                      logit_cap=cfg.attn_logit_softcap)
    mix = jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
    if cfg.post_block_norm:
        mix = rms_norm(mix, p["post_norm"]["scale"], cfg.norm_eps)
    x1 = x1 + mix
    aux: Dict = {}
    x1 = _mlp_sublayer(bdef, p, x1, cfg, settings, aux)
    return x1, {"k": ck, "v": cv}


# ====================================================================
# Decode-cache construction
# ====================================================================

def init_block_cache(bdef: BlockDef, cfg: ModelConfig, batch: int,
                     seq_len: int, dtype) -> Any:
    """Zeroed cache entry for one block (shapes only matter for dry-run)."""
    if bdef.mixer == "attn":
        hd = cfg.resolved_head_dim
        S = min(bdef.window, seq_len) if bdef.window else seq_len
        shape = (batch, S, cfg.num_kv_heads, hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if bdef.mixer == "cross":
        hd = cfg.resolved_head_dim
        shape = (batch, cfg.encoder_seq_len, cfg.num_kv_heads, hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if bdef.mixer == "rglru":
        W = cfg.rglru_width or cfg.d_model
        return {"conv": jnp.zeros((batch, cfg.rglru_conv_width - 1, W),
                                  dtype),
                "h": jnp.zeros((batch, W), jnp.float32)}
    if bdef.mixer == "ssm":
        dims = m2.ssm_dims(cfg)
        return {"conv": jnp.zeros((batch, cfg.ssm_conv_width - 1,
                                   dims.conv_channels), dtype),
                "state": jnp.zeros((batch, dims.n_heads, dims.head_dim,
                                    dims.state), jnp.float32)}
    raise ValueError(bdef.mixer)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16):
    segs = build_segments(cfg)
    cache = []
    for seg in segs:
        entries = {}
        for i, bdef in enumerate(seg.blocks):
            one = init_block_cache(bdef, cfg, batch, seq_len, dtype)
            entries[f"b{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (seg.n_repeat,) + a.shape),
                one)
        cache.append(entries)
    return cache
