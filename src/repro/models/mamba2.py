"""Mamba-2 (SSD — state-space duality) mixer block. [arXiv:2405.21060]

Training/prefill uses the chunked SSD algorithm (intra-chunk quadratic term +
inter-chunk state recurrence); decode uses the O(1) recurrent update. The
XLA path here is the oracle for kernels/ssd_scan.py.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_conv1d, dense_init, init_conv1d


class SSMDims(NamedTuple):
    d_inner: int
    n_heads: int
    head_dim: int
    state: int
    conv_channels: int


def ssm_dims(cfg: ModelConfig) -> SSMDims:
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    conv_channels = d_inner + 2 * cfg.ssm_state_dim  # x, B, C convolved
    return SSMDims(d_inner, n_heads, cfg.ssm_head_dim, cfg.ssm_state_dim,
                   conv_channels)


def init_mamba2(key, cfg: ModelConfig, dtype):
    dims = ssm_dims(cfg)
    ks = jax.random.split(key, 6)
    D = cfg.d_model
    return {
        "w_zx": dense_init(ks[0], (D, 2 * dims.d_inner), D, dtype),
        "w_bc": dense_init(ks[1], (D, 2 * dims.state), D, dtype),
        "w_dt": dense_init(ks[2], (D, dims.n_heads), D, dtype),
        "dt_bias": jnp.zeros((dims.n_heads,), jnp.float32),
        "conv": init_conv1d(ks[3], cfg.ssm_conv_width, dims.conv_channels,
                            dtype),
        "A_log": jnp.zeros((dims.n_heads,), jnp.float32),
        "D_skip": jnp.ones((dims.n_heads,), jnp.float32),
        "norm_scale": jnp.zeros((dims.d_inner,), dtype),
        "w_out": dense_init(ks[4], (dims.d_inner, D), dims.d_inner, dtype),
    }


def _gated_norm(y, z, scale, eps):
    y32 = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y32), axis=-1, keepdims=True)
    return (y32 * jax.lax.rsqrt(var + eps)
            * (1.0 + scale.astype(jnp.float32)))


def _split_proj(p, x, dims: SSMDims):
    zx = jnp.einsum("...d,de->...e", x, p["w_zx"])
    z, xs = jnp.split(zx, 2, axis=-1)
    bc = jnp.einsum("...d,de->...e", x, p["w_bc"])
    dt = jax.nn.softplus(
        jnp.einsum("...d,dh->...h", x, p["w_dt"]).astype(jnp.float32)
        + p["dt_bias"])
    return z, xs, bc, dt


def ssd_chunked(xh, dA_log, B_s, C_s, chunk: int,
                state0: Optional[jnp.ndarray] = None):
    """Chunked SSD scan (pure JAX oracle).

    xh:    (B, S, H, P)  inputs scaled by dt
    dA_log:(B, S, H)     log decay per step (dt * A, A < 0)
    B_s:   (B, S, N)     input projection (n_groups=1, shared over heads)
    C_s:   (B, S, N)     output projection
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    B, S, H, Pd = xh.shape
    N = B_s.shape[-1]
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    nc = S // chunk
    xc = xh.reshape(B, nc, chunk, H, Pd).astype(jnp.float32)
    ac = dA_log.reshape(B, nc, chunk, H).astype(jnp.float32)
    bc = B_s.reshape(B, nc, chunk, N).astype(jnp.float32)
    cc = C_s.reshape(B, nc, chunk, N).astype(jnp.float32)

    La = jnp.cumsum(ac, axis=2)                       # (B,nc,Q,H) cumulative
    # --- intra-chunk (quadratic) term ---
    g = jnp.einsum("bcin,bcjn->bcij", cc, bc)         # (B,nc,Q,Q)
    dd = La[:, :, :, None, :] - La[:, :, None, :, :]  # (B,nc,Q,Q,H) Li - Lj
    iq = jnp.arange(chunk)
    causal = (iq[:, None] >= iq[None, :])
    m = jnp.where(causal[None, None, :, :, None], jnp.exp(dd), 0.0)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", g, m, xc)

    # --- chunk states ---
    # state contribution of step j to end of its chunk: exp(La_last - La_j)
    decay_to_end = jnp.exp(La[:, :, -1:, :] - La)     # (B,nc,Q,H)
    s_chunk = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", decay_to_end, bc, xc)

    # --- inter-chunk recurrence over nc (sequential scan) ---
    chunk_decay = jnp.exp(La[:, :, -1, :])            # (B,nc,H)
    if state0 is None:
        state0 = jnp.zeros((B, H, Pd, N), jnp.float32)

    def body(state, inp):
        dec, s_new = inp                              # (B,H), (B,H,P,N)
        state_in = state                              # state BEFORE chunk
        state = state * dec[:, :, None, None] + s_new
        return state, state_in

    (final_state, states_in) = jax.lax.scan(
        body, state0.astype(jnp.float32),
        (chunk_decay.swapaxes(0, 1), s_chunk.swapaxes(0, 1)))
    states_in = states_in.swapaxes(0, 1)              # (B,nc,H,P,N)

    # --- inter-chunk output term ---
    y_inter = jnp.einsum("bcih,bcin,bchpn->bcihp",
                         jnp.exp(La), cc, states_in)
    y = (y_intra + y_inter).reshape(B, S, H, Pd)
    return y, final_state


def apply_mamba2(p, x, cfg: ModelConfig, *, impl: str = "xla"):
    """Training/prefill. x: (B, S, D) -> (y, final_cache)."""
    dims = ssm_dims(cfg)
    B, S, D = x.shape
    z, xs, bc, dt = _split_proj(p, x, dims)
    conv_in = jnp.concatenate([xs, bc], axis=-1)
    conv_out, conv_state = apply_conv1d(p["conv"], conv_in)
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    xs = conv_out[..., :dims.d_inner]
    B_s = conv_out[..., dims.d_inner:dims.d_inner + dims.state]
    C_s = conv_out[..., dims.d_inner + dims.state:]

    A = -jnp.exp(p["A_log"])                           # (H,) negative
    dA_log = dt * A                                    # (B,S,H)
    xh = xs.reshape(B, S, dims.n_heads, dims.head_dim)
    xh_dt = xh.astype(jnp.float32) * dt[..., None]

    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops
        y, final_state = kops.ssd_scan(
            xh_dt, dA_log, B_s, C_s, chunk=cfg.ssm_chunk,
            interpret=(impl == "pallas_interpret"))
    else:
        y, final_state = ssd_chunked(xh_dt, dA_log, B_s, C_s, cfg.ssm_chunk)
    y = y + xh.astype(jnp.float32) * p["D_skip"][:, None]
    y = y.reshape(B, S, dims.d_inner)
    y = _gated_norm(y, z, p["norm_scale"], cfg.norm_eps).astype(x.dtype)
    out = jnp.einsum("...e,ed->...d", y, p["w_out"])
    cache = {"conv": conv_state, "state": final_state}
    return out, cache


def decode_mamba2(p, x1, cache, cfg: ModelConfig):
    """One-token decode. x1: (B, 1, D); cache {conv (B,W-1,C), state}."""
    dims = ssm_dims(cfg)
    B = x1.shape[0]
    z, xs, bc, dt = _split_proj(p, x1, dims)
    conv_in = jnp.concatenate([xs, bc], axis=-1)
    conv_out, conv_state = apply_conv1d(p["conv"], conv_in, cache["conv"])
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x1.dtype)
    xs = conv_out[..., :dims.d_inner]
    B_s = conv_out[..., dims.d_inner:dims.d_inner + dims.state]
    C_s = conv_out[..., dims.d_inner + dims.state:]

    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[:, 0] * A)                         # (B,H)
    xh = xs.reshape(B, dims.n_heads, dims.head_dim).astype(jnp.float32)
    xh_dt = xh * dt[:, 0, :, None]
    state = cache["state"]
    state = (state * dA[:, :, None, None]
             + jnp.einsum("bn,bhp->bhpn", B_s[:, 0].astype(jnp.float32),
                          xh_dt))
    y = jnp.einsum("bn,bhpn->bhp", C_s[:, 0].astype(jnp.float32), state)
    y = y + xh * p["D_skip"][:, None]
    y = y.reshape(B, 1, dims.d_inner)
    y = _gated_norm(y, z, p["norm_scale"], cfg.norm_eps).astype(x1.dtype)
    out = jnp.einsum("...e,ed->...d", y, p["w_out"])
    return out, {"conv": conv_state, "state": state}
