"""Mixture-of-Experts FFN with true expert parallelism.

Two execution paths with identical math:
  * local: every device computes all experts (smoke tests / 1-device CPU);
  * ep: `shard_map` over the mesh — experts sharded over the `model` axis,
    tokens over the data axes; each shard gathers its local experts' tokens
    into a capacity buffer, runs batched GEMMs, and a psum over `model`
    combines the partial outputs. This is the real EP dataflow (the psum is
    the combine all-reduce), so the dry-run roofline sees honest collectives
    and honest FLOPs (capacity-padded, not E-times overcounted).

Routing: full-softmax then top-k, renormalised (Mixtral-style); capacity
factor with drop (GShard-style, per data shard). Aux losses: load-balance
(Switch) + router z-loss.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import activation, dense_init

from repro.parallel.shmap import shard_map as _shard_map
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class MoESettings:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    act: str = "silu"


def init_moe(key, d_model, d_ff, num_experts, dtype):
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d_model, num_experts), d_model,
                             jnp.float32),
        "w_in": dense_init(ks[1], (num_experts, d_model, d_ff), d_model, dtype),
        "w_gate": dense_init(ks[2], (num_experts, d_model, d_ff), d_model,
                             dtype),
        "w_out": dense_init(ks[3], (num_experts, d_ff, d_model), d_ff, dtype),
    }


def _route(x, router_w, settings: MoESettings):
    """Returns (eids (T,k) int32, weights (T,k) f32, aux losses)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, eids = jax.lax.top_k(probs, settings.top_k)
    weights = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # Switch load-balance loss + z-loss.
    E = settings.num_experts
    me = probs.mean(axis=0)                                    # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[eids.reshape(-1)].add(
        1.0 / eids.size)
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return eids, weights, {"moe_lb": lb_loss, "moe_z": z_loss}


def _expert_ranks(eids_flat, num_experts):
    """Rank of each routed token within its expert, memory-light
    (sort-based, no (T*k, E) one-hot)."""
    tk = eids_flat.shape[0]
    order = jnp.argsort(eids_flat, stable=True)
    counts = jnp.zeros((num_experts,), jnp.int32).at[eids_flat].add(1)
    starts = jnp.cumsum(counts) - counts                        # exclusive
    rank_sorted = jnp.arange(tk, dtype=jnp.int32) - starts[eids_flat[order]]
    rank = jnp.zeros((tk,), jnp.int32).at[order].set(rank_sorted)
    return rank


def _expert_ffn(buf, p_in, p_gate, p_out, act_name):
    """buf: (E_local, C, D) capacity buffer -> same shape output."""
    act = activation(act_name)
    h = jnp.einsum("ecd,edf->ecf", buf, p_in)
    g = jnp.einsum("ecd,edf->ecf", buf, p_gate)
    from jax.ad_checkpoint import checkpoint_name
    h = checkpoint_name(act(g) * h, "moe_hidden")
    return jnp.einsum("ecf,efd->ecd", h, p_out)


def _moe_shard_body(x, eids, weights, w_in, w_gate, w_out, *,
                    settings: MoESettings, e0, num_local: int,
                    capacity: int, ep_axis: Optional[str]):
    """Per-shard MoE compute. x: (T, D) local tokens; w_*: local experts."""
    T, D = x.shape
    k = settings.top_k
    ef = eids.reshape(-1)                                       # (T*k,)
    rank = _expert_ranks(ef, settings.num_experts)
    local = (ef >= e0) & (ef < e0 + num_local)
    le = jnp.where(local, ef - e0, num_local)                   # OOB -> drop
    slot = jnp.where(local & (rank < capacity), rank, capacity)
    xk = jnp.repeat(x, k, axis=0)                               # (T*k, D)
    buf = jnp.zeros((num_local + 1, capacity + 1, D), x.dtype)
    buf = buf.at[le, slot].add(xk, mode="drop")
    buf = buf[:num_local, :capacity]
    out_buf = _expert_ffn(buf, w_in, w_gate, w_out, settings.act)
    out_buf = jnp.pad(out_buf, ((0, 1), (0, 1), (0, 0)))
    yk = out_buf[le, slot] * weights.reshape(-1)[:, None].astype(x.dtype)
    y = yk.reshape(T, k, D).sum(axis=1)
    if ep_axis is not None:
        y = jax.lax.psum(y, ep_axis)
    return y


def apply_moe(params, x, settings: MoESettings, *,
              mesh=None, ep_axis: Optional[str] = None,
              dp_axes: Tuple[str, ...] = ()):
    """x: (B, S, D) -> (y (B, S, D), aux dict)."""
    B, S, D = x.shape
    xf = x.reshape(B * S, D)
    eids, weights, aux = _route(xf, params["router"], settings)
    E, k = settings.num_experts, settings.top_k

    if mesh is None or ep_axis is None:
        # Dropless on the single-device path: an expert can receive at
        # most one assignment per token, so capacity B*S covers the
        # worst case. Capacity-factor drops here would make the output
        # depend on batch composition — a full-sequence forward and a
        # prefill of the same prefix would drop *different* tokens,
        # breaking prefill/decode consistency (the serving invariant).
        # Cost: the dispatch buffer is (E, B*S, D) instead of
        # (E, ~B*S*k/E, D); at large single-device scale a sort-based
        # ragged dispatch would avoid the E-fold worst case (capacity
        # must be trace-static under jit, so it cannot adapt to the
        # routed load). The EP shard_map path below keeps GShard
        # capacity semantics.
        capacity = B * S
        y = _moe_shard_body(xf, eids, weights, params["w_in"],
                            params["w_gate"], params["w_out"],
                            settings=settings, e0=0, num_local=E,
                            capacity=capacity, ep_axis=None)
        return y.reshape(B, S, D), aux

    ep = mesh.shape[ep_axis]
    assert E % ep == 0, (E, ep)
    num_local = E // ep
    dp = math.prod(mesh.shape[a] for a in dp_axes) if dp_axes else 1
    local_tokens = (B * S) // dp
    capacity = int(math.ceil(local_tokens * k / E *
                             settings.capacity_factor))

    def body(xl, el, wl, w_in, w_gate, w_out):
        e0 = jax.lax.axis_index(ep_axis) * num_local
        return _moe_shard_body(xl, el, wl, w_in, w_gate, w_out,
                               settings=settings, e0=e0,
                               num_local=num_local, capacity=capacity,
                               ep_axis=ep_axis)

    dp_spec = P(dp_axes) if dp_axes else P(None)
    y = _shard_map(
        body, mesh=mesh,
        in_specs=(dp_spec, dp_spec, dp_spec,
                  P(ep_axis, None, None), P(ep_axis, None, None),
                  P(ep_axis, None, None)),
        out_specs=dp_spec,
        check_vma=False,
    )(xf, eids, weights, params["w_in"], params["w_gate"], params["w_out"])
    return y.reshape(B, S, D), aux
