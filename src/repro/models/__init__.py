from repro.models.api import ModelApi, build_model
from repro.models.transformer import RunSettings, build_segments

__all__ = ["ModelApi", "build_model", "RunSettings", "build_segments"]
