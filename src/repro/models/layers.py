"""Shared neural-net building blocks (pure JAX, functional params-as-pytrees)."""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------- init


def dense_init(key, shape, in_axis_size, dtype):
    """Truncated-normal fan-in init (matches Megatron's scaled init)."""
    std = 1.0 / math.sqrt(in_axis_size)
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * 0.02).astype(dtype)


# ---------------------------------------------------------------- norms
#
# rms_norm and the activations below carry custom_vjp rules that save only
# their *inputs* and recompute the rest in backward. Without this, the
# eager-vjp residual set (what the TBA spool offloads) holds every
# primitive intermediate — measured 36*h elements/token/layer on BERT vs
# the fused-op count of ~16*h that PyTorch/Megatron (the paper's
# substrate) materialises. With these rules the offload traffic matches
# the paper's llm-analysis estimate (benchmarks/table4_offload.py).


def _rms_norm_impl(x, scale, eps):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x, scale, eps):
    return _rms_norm_impl(x, scale, eps)


def _rms_fwd(x, scale, eps):
    return _rms_norm_impl(x, scale, eps), (x, scale)


def _rms_bwd(eps, res, g):
    x, scale = res
    x32 = x.astype(jnp.float32)
    g32 = g.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + eps)
    xhat = x32 * r
    gs = g32 * (1.0 + scale.astype(jnp.float32))
    dx = r * (gs - xhat * jnp.mean(gs * xhat, axis=-1, keepdims=True))
    dscale = jnp.sum(g32 * xhat,
                     axis=tuple(range(x.ndim - 1))).astype(scale.dtype)
    return dx.astype(x.dtype), dscale


rms_norm.defvjp(_rms_fwd, _rms_bwd)


def init_norm(d, dtype):
    # Stored as "scale - 1" (gemma convention) so zeros == identity.
    return {"scale": jnp.zeros((d,), dtype)}


# ---------------------------------------------------------------- RoPE


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- misc


def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


# ------------------------------------------------- sharding hints
#
# GSPMD propagation loses the batch sharding inside nested scans (the
# attention chunk loop) and on gathers from vocab-sharded tables; these
# pathologies replicate the global batch per device (measured: 48 GB/device
# attention carries on qwen train_4k). `hint` pins activations to the
# settings' dp/tp axes wherever a dimension is divisible, and is a no-op
# when no mesh is configured (single-device tests).

def hint(x, settings, *dims):
    """dims: one of 'b' (batch -> dp axes), 'h'/'m' (heads/model -> tp
    axis), None (replicated) per array dimension."""
    mesh = getattr(settings, "mesh", None)
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec
    parts = []
    for dim, size in zip(dims, x.shape):
        if dim == "b" and settings.dp_axes:
            n = 1
            for a in settings.dp_axes:
                n *= mesh.shape[a]
            parts.append(settings.dp_axes if size % n == 0 else None)
        elif dim in ("h", "m") and settings.tp_axis:
            n = mesh.shape[settings.tp_axis]
            parts.append(settings.tp_axis if size % n == 0 else None)
        else:
            parts.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*parts)))


# input-saving activations (see the norms note above): one residual, not
# the 3-4 primitive intermediates of the composite jax.nn forms.

@jax.custom_vjp
def gelu(x):
    return jax.nn.gelu(x, approximate=False)


def _gelu_fwd(x):
    return jax.nn.gelu(x, approximate=False), x


def _gelu_bwd(x, g):
    x32 = x.astype(jnp.float32)
    cdf = 0.5 * (1.0 + jax.lax.erf(x32 / jnp.sqrt(jnp.float32(2.0))))
    pdf = jnp.exp(-0.5 * x32 * x32) / jnp.sqrt(jnp.float32(2.0 * math.pi))
    return ((g.astype(jnp.float32) * (cdf + x32 * pdf)).astype(x.dtype),)


gelu.defvjp(_gelu_fwd, _gelu_bwd)


@jax.custom_vjp
def silu(x):
    return jax.nn.silu(x)


def _silu_fwd(x):
    return jax.nn.silu(x), x


def _silu_bwd(x, g):
    x32 = x.astype(jnp.float32)
    s = jax.nn.sigmoid(x32)
    return ((g.astype(jnp.float32) * s * (1.0 + x32 * (1.0 - s)))
            .astype(x.dtype),)


silu.defvjp(_silu_fwd, _silu_bwd)


def activation(name: str):
    return {"silu": silu, "gelu": gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------- MLP


def init_mlp(key, d_model, d_ff, glu: bool, dtype) -> Params:
    ks = jax.random.split(key, 3)
    p = {
        "w_in": dense_init(ks[0], (d_model, d_ff), d_model, dtype),
        "w_out": dense_init(ks[1], (d_ff, d_model), d_ff, dtype),
    }
    if glu:
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), d_model, dtype)
    return p


def apply_mlp(p: Params, x, act_name: str, glu: bool):
    act = activation(act_name)
    h = jnp.einsum("...d,df->...f", x, p["w_in"])
    if glu:
        g = jnp.einsum("...d,df->...f", x, p["w_gate"])
        h = act(g) * h
    else:
        h = act(h)
    from jax.ad_checkpoint import checkpoint_name
    h = checkpoint_name(h, "mlp_hidden")
    return jnp.einsum("...f,fd->...d", h, p["w_out"])


# ---------------------------------------------------------------- conv1d (causal, depthwise)


def init_conv1d(key, width, channels, dtype) -> Params:
    return {"w": dense_init(key, (width, channels), width, dtype),
            "b": jnp.zeros((channels,), dtype)}


def apply_conv1d(p: Params, x, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. x: (B, S, C). state: (B, W-1, C) or None.

    Returns (y, new_state). With state=None, left-pads with zeros (training/
    prefill); new_state is the last W-1 inputs for streaming decode.
    """
    w = p["w"]
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros(x.shape[:1] + (width - 1,) + x.shape[2:], x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)             # (B, S+W-1, C)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width))
    y = y + p["b"]
    new_state = xp[:, -(width - 1):] if width > 1 else None
    return y, new_state
