"""GQA attention: memory-efficient chunked online-softmax (XLA path),
decode-step attention against full or ring KV caches, and dispatch to the
Pallas flash kernel on TPU.

The chunked XLA path is mathematically identical to the Pallas kernel
(kernels/flash_attention.py) and serves as its oracle; it never materialises
an (Sq, Skv) score tensor larger than (Sq, chunk), which is what makes the
32k/500k cells lowerable.

Layout notes (measured on the 256-chip dry-run): KV heads are expanded to
the query head count *inside* each chunk iteration, so every score/carry
tensor keeps a clean (batch@dp, heads@tp) layout — reshaping q to
(B, S, Hkv, G, D) instead makes GSPMD split heads across two tiny dims and
replicate the batch (48 GB/device of f32 carries on qwen train_4k). The
expansion is a broadcast of already-replicated KV, fused into the einsum.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import hint, softcap

NEG_INF = -1.0e30


def _pick_chunk(skv: int, requested: int) -> int:
    if skv <= requested:
        return skv
    c = requested
    while skv % c:
        c //= 2
    return max(c, 1)


def _expand_kv(blk, G: int):
    """(B, C, Hkv, D) -> (B, C, Hkv*G, D) by repeating each kv head G x."""
    if G == 1:
        return blk
    B, C, Hkv, D = blk.shape
    blk = jnp.broadcast_to(blk[:, :, :, None, :], (B, C, Hkv, G, D))
    return blk.reshape(B, C, Hkv * G, D)


MAX_Q_BLOCKS = 8


def attend_blocked(q, k, v, *, causal: bool, window: int = 0,
                   logit_cap: float = 0.0, chunk: int = 1024,
                   settings: Any = None, n_blocks: int = MAX_Q_BLOCKS):
    """Causal/windowed attention with *static triangular KV extents*.

    The plain chunked path computes every (q, kv) tile and masks — half
    the MXU work of a causal layer is thrown away (and for sliding-window
    layers at long context, almost all of it). Splitting queries into
    unrolled blocks gives each block a statically-sliced KV range:

        causal:  kv in [0, (i+1)*qblk)                (~(n+1)/2n of full)
        window:  kv in [floor_to_chunk(lo), hi)       (~(w+qblk)/S of full)

    This is the flash-kernel block-skipping trick expressed at the XLA
    graph level, so the dry-run roofline (and a real TPU run of the XLA
    path) sees the reduced FLOPs. Unroll factor is capped so the HLO
    stays small (inner online-softmax scans are shared per extent).
    """
    B, Sq, Hq, D = q.shape
    Skv = k.shape[1]
    n_blocks = min(n_blocks, Sq)
    while Sq % n_blocks:
        n_blocks -= 1
    qblk = Sq // n_blocks
    outs = []
    for i in range(n_blocks):
        lo_q = i * qblk
        hi_kv = min((i + 1) * qblk, Skv) if causal else Skv
        lo_kv = 0
        if window:
            lo_kv = max(0, lo_q - window + 1)
            lo_kv = (lo_kv // chunk) * chunk        # chunk-aligned
        qi = jax.lax.slice_in_dim(q, lo_q, lo_q + qblk, axis=1)
        ki = jax.lax.slice_in_dim(k, lo_kv, hi_kv, axis=1)
        vi = jax.lax.slice_in_dim(v, lo_kv, hi_kv, axis=1)
        outs.append(attend_chunked(
            qi, ki, vi, causal=causal, window=window,
            logit_cap=logit_cap, q_offset=lo_q - lo_kv, chunk=chunk,
            settings=settings))
    return jnp.concatenate(outs, axis=1)


def attend_chunked(q, k, v, *, causal: bool, window: int = 0,
                   logit_cap: float = 0.0, q_offset=0,
                   kv_len: Optional[jnp.ndarray] = None,
                   chunk: int = 1024, settings: Any = None):
    """Online-softmax attention.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D); Hq % Hkv == 0.
    window: 0 = unbounded; >0 = keys within [i - window + 1, i].
    q_offset: absolute position of q[0] (decode/prefill continuation).
    kv_len: optional scalar/array — keys at index >= kv_len are invalid.
    Returns (B, Sq, Hq, D) in q.dtype.
    """
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qf = q.astype(jnp.float32) * (D ** -0.5)
    qf = hint(qf, settings, "b", None, "h", None)

    C = _pick_chunk(Skv, chunk)
    n_chunks = Skv // C
    kc = k.reshape(B, n_chunks, C, Hkv, D)
    vc = v.reshape(B, n_chunks, C, Hkv, D)

    iq = (jnp.arange(Sq) + q_offset)[:, None]            # (Sq, 1)

    def body(carry, inputs):
        m, l, acc = carry
        c_idx, k_blk, v_blk = inputs                     # (B, C, Hkv, D)
        k_blk = _expand_kv(k_blk.astype(jnp.float32), G)
        v_blk = _expand_kv(v_blk.astype(jnp.float32), G)
        s = jnp.einsum("bqhd,bchd->bqhc", qf, k_blk)     # (B,Sq,Hq,C)
        if logit_cap:
            s = softcap(s, logit_cap)
        jc = c_idx * C + jnp.arange(C)[None, :]          # (1, C)
        mask = jnp.ones((Sq, C), bool)
        if causal:
            mask &= jc <= iq
        if window:
            mask &= jc > iq - window
        if kv_len is not None:
            mask &= jc < kv_len
        s = jnp.where(mask[None, :, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bqhc,bchd->bqhd", p, v_blk)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = hint(jnp.full((B, Sq, Hq), NEG_INF, jnp.float32), settings,
              "b", None, "h")
    l0 = hint(jnp.zeros((B, Sq, Hq), jnp.float32), settings,
              "b", None, "h")
    a0 = hint(jnp.zeros((B, Sq, Hq, D), jnp.float32), settings,
              "b", None, "h", None)
    if n_chunks == 1:
        (m, l, acc), _ = body((m0, l0, a0),
                              (jnp.array(0), kc[:, 0], vc[:, 0]))
    else:
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0),
            (jnp.arange(n_chunks), kc.swapaxes(0, 1), vc.swapaxes(0, 1)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def attend_decode(q, cache_k, cache_v, pos, *, window: int = 0,
                  logit_cap: float = 0.0, ring: bool = False,
                  settings: Any = None):
    """One-step decode attention. q: (B, 1, Hq, D); cache: (B, S, Hkv, D).

    pos: absolute position of the current token (already written into
    the cache by the caller) — a scalar int32, or a (B,) int32 vector
    when every batch row sits at its own position (continuous batching:
    each serving slot decodes a different sequence). With ring=True the
    cache length S equals the window and slot s holds absolute position
    `s + S*floor((pos - s)/S)` (i.e. the most recent token congruent to s).
    """
    B, _, Hq, D = q.shape
    S, Hkv = cache_k.shape[1], cache_k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32) * (D ** -0.5)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, cache_k.astype(jnp.float32))
    if logit_cap:
        s = softcap(s, logit_cap)
    slots = jnp.arange(S)
    pos = jnp.asarray(pos)
    # per-row positions mask as (B, S); a scalar keeps the shared (S,)
    # mask (broadcast over batch) — same values either way
    posk = pos[:, None] if pos.ndim == 1 else pos
    if ring:
        slot_pos = slots + S * ((posk - slots) // S)     # absolute positions
        valid = (slot_pos >= 0) & (slot_pos <= posk)
        if window:
            valid &= slot_pos > posk - window
    else:
        valid = slots <= posk
        if window:
            valid &= slots > posk - window
    if valid.ndim == 1:
        valid = valid[None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, cache_v.astype(jnp.float32))
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


def attend(q, k, v, *, causal: bool, window: int = 0, logit_cap: float = 0.0,
           q_offset=0, kv_len=None, chunk: int = 1024, impl: str = "xla",
           settings: Any = None):
    """Dispatcher: xla (chunked scan, blocked for causal/window) |
    pallas | pallas_interpret."""
    if impl == "xla":
        import os
        Sq, Skv = q.shape[1], k.shape[1]
        if ((causal or window) and Sq == Skv and kv_len is None
                and isinstance(q_offset, int) and q_offset == 0
                and Sq > chunk
                and not os.environ.get("REPRO_NO_BLOCKED_ATTN")):
            return attend_blocked(q, k, v, causal=causal, window=window,
                                  logit_cap=logit_cap, chunk=chunk,
                                  settings=settings)
        return attend_chunked(q, k, v, causal=causal, window=window,
                              logit_cap=logit_cap, q_offset=q_offset,
                              kv_len=kv_len, chunk=chunk, settings=settings)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops
        return kops.flash_attention(
            q, k, v, causal=causal, window=window, logit_cap=logit_cap,
            interpret=(impl == "pallas_interpret"))
    raise ValueError(f"unknown attention impl {impl!r}")
