"""The one "next needed" computation (reuse horizon) shared by every
prefetch site.

Before the cache manager, the staged backward walker, the jit hook
bridge, and the kvcache refill loop each computed their own "what is
needed next" prefix — same idea, three copies, and the horizon is also
exactly the signal the `CacheManager` wants as its reuse-distance hint.
One helper, three call sites, and the manager's `hint_next` consumes
the same prefix.
"""
from __future__ import annotations

from typing import Iterable, List, TypeVar

T = TypeVar("T")


def reuse_horizon(upcoming: Iterable[T], *, depth: int = 1) -> List[T]:
    """The prefix of `upcoming` a prefetcher should cover right now.

    `upcoming` is whatever the caller predicts will be accessed next, in
    access order: the remaining backward stages (``range(si - 1, -1,
    -1)``) for activation residuals, or the resume queue for parked KV
    sequences. `depth` bounds how far ahead to act — 1 is the paper's
    one-module-ahead backward prefetch (§3.3.2); the kvcache uses its
    configured ``prefetch_depth``. An exhausted iterable yields an empty
    horizon (stage 0's backward, an empty resume queue) — the caller
    needs no bounds check of its own.
    """
    if depth <= 0:
        return []
    out: List[T] = []
    for item in upcoming:
        out.append(item)
        if len(out) >= depth:
            break
    return out
