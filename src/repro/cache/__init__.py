"""repro.cache — the unified heterogeneous cache layer.

`reuse_horizon` and `PlacementEngine` are import-light and eagerly
exported. `CacheManager`/`CacheConfig`/`plan_residency` live in
`repro.cache.manager`, which imports `repro.io` — and `repro.io.backends`
imports `repro.cache.placement` — so the manager is exposed lazily
(PEP 562) to keep the import graph acyclic.
"""
from __future__ import annotations

from repro.cache.horizon import reuse_horizon
from repro.cache.placement import PlacementEngine

__all__ = [
    "reuse_horizon",
    "PlacementEngine",
    "CacheManager",
    "CacheConfig",
    "DEFAULT_CLASS_DISTANCES",
    "plan_residency",
]

_LAZY = ("CacheManager", "CacheConfig", "DEFAULT_CLASS_DISTANCES",
         "plan_residency")


def __getattr__(name: str):
    if name in _LAZY:
        from repro.cache import manager
        return getattr(manager, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
