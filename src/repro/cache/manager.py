"""CacheManager — the cost-model-driven storage brain over device-spill
/ pinned-host-RAM / SSD for every tensor class the spool carries.

Before this module, each tensor class drove the spool independently and
tier placement was a static byte-budget spill inside the `tiered`
backend. The manager replaces that placement engine (now extracted into
`repro.cache.placement.PlacementEngine`) with one that sees every
blob's *class* and predicted *reuse distance*:

  activation  residuals, reused within the step in backward order —
              the spool's LIFO pattern, nearest reuse
  opt_state   optimizer moments staged between steps — reused exactly
              one step later (step parity)
  kv_page     evicted KV pages of parked sequences — reused when the
              sequence re-enters the scheduler's refill horizon,
              typically farthest of the three

Classes are recognised by lease-key prefix (``opt{step}_*``,
``kv{rid}_*``; everything else is an activation) and clients can
register their own. Eviction picks the earliest-stored blob of the
farthest-reuse class (Belady's choice under per-class access order),
never a blob on the hinted reuse horizon; `hint_next` — fed by the same
`reuse_horizon` prefix the prefetchers act on — marks imminent reuse
and queues background *promotion* of lowered blobs back into host RAM
when the calibrated `TierBandwidth` numbers say the SSD read would
otherwise be the slower path. The pinned-host tier is bounded by
`host_bound_bytes` (MemAscend's pinned-memory footprint concern made a
hard knob: `peak_host_bytes` must never exceed it — checked by
``benchmarks/cache_manager.py --check``), and a failing SSD tier
degrades to host-RAM residency instead of losing data
(`fallback_to_upper`).

The manager IS a `StorageBackend` (kind ``"managed"``), so the
existing spool data plane (bufpool, vectored writes, aio lower tiers)
and the transactional lease contract carry over unchanged; training,
fine-tuning, and serving share one brain by sharing one backend.
"""
from __future__ import annotations

import queue
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro import obs
from repro.cache.placement import PlacementEngine
from repro.core.adaptive import TierBandwidth

#: nominal reuse-distance rank per class (unitless ordering; larger =
#: reused farther in the future = evicted earlier). AdaptivePolicy
#: overwrites the activation entry with measured per-step seconds.
DEFAULT_CLASS_DISTANCES = {
    "activation": 1.0,
    "opt_state": 2.0,
    "kv_page": 3.0,
}

_DEFAULT_PREFIXES = (("opt", "opt_state"), ("kv", "kv_page"))


@dataclass(frozen=True)
class CacheConfig:
    """Knobs of the storage brain (the ``--cache-*`` CLI family)."""
    host_bound_bytes: int = 256 << 20   # MemAscend-style pinned bound
    promote_depth: int = 2              # hinted keys promoted per hint
    promote: bool = True                # background promotion on/off
    hint_capacity: int = 512            # live hinted-key window

    def validate(self) -> "CacheConfig":
        assert self.host_bound_bytes >= 0, self.host_bound_bytes
        assert self.promote_depth >= 0, self.promote_depth
        assert self.hint_capacity >= 1, self.hint_capacity
        return self


@dataclass
class ClassStats:
    bytes_written: int = 0
    writes: int = 0


# register under the backend registry so spec strings ("managed:64mb")
# and SpoolIoConfig(backend="managed") resolve like any other kind
from repro.io.backend import NOMINAL_WRITE_BW  # noqa: E402
from repro.io.backend import StorageBackend, register_backend  # noqa: E402
from repro.io.backends import HostMemoryBackend  # noqa: E402

NOMINAL_WRITE_BW.setdefault("managed", NOMINAL_WRITE_BW.get("tiered",
                                                            20e9))


@register_backend("managed")
class CacheManager(StorageBackend):
    def __init__(self, lower: StorageBackend, *,
                 config: Optional[CacheConfig] = None,
                 host_bound_bytes: Optional[int] = None,
                 upper: Optional[HostMemoryBackend] = None):
        super().__init__()
        if config is None:
            config = CacheConfig()
        if host_bound_bytes is not None:
            config = CacheConfig(
                host_bound_bytes=host_bound_bytes,
                promote_depth=config.promote_depth,
                promote=config.promote,
                hint_capacity=config.hint_capacity)
        self.config = config.validate()
        self.upper = upper if upper is not None else HostMemoryBackend()
        self.lower = lower
        self.engine = PlacementEngine(
            self.upper, lower,
            capacity_bytes=self.config.host_bound_bytes,
            victim_fn=self._pick_victim,
            fallback_to_upper=True,
            note_copy=self._note_copy)
        self._cls_lock = threading.Lock()
        self._distances = dict(DEFAULT_CLASS_DISTANCES)
        self._prefixes: List[Tuple[str, str]] = list(_DEFAULT_PREFIXES)
        self._by_class: Dict[str, ClassStats] = {}
        self._hinted: "OrderedDict[str, bool]" = OrderedDict()
        self.host_hits = 0
        self.ssd_hits = 0
        self.hints = 0
        self._promo_q: "queue.Queue[Optional[str]]" = queue.Queue()
        self._promo_thread = None
        if self.config.promote:
            self._promo_thread = threading.Thread(
                target=self._promo_worker, daemon=True,
                name="cache-promote")
            self._promo_thread.start()

    def attach_health(self, health) -> None:
        """Report failing lower-tier (SSD) writes into a
        `repro.resilience.BackendHealth`, so tier fallbacks show up as
        degradation events next to spool retry failures. The fallback
        itself already happened (the blob stayed host-resident) — this
        only makes the demotion visible to re-planning subscribers."""
        def note(exc: BaseException) -> None:
            health.record_failure("write", exc)
        self.engine.on_lower_error = note

    # back-compat with TieredBackend duck-typing (benchmarks, planner)
    @property
    def capacity_bytes(self) -> int:
        return self.config.host_bound_bytes

    @property
    def resident_bytes(self) -> int:
        return self.engine.resident_bytes

    @property
    def peak_host_bytes(self) -> int:
        return self.engine.peak_resident_bytes

    # ------------------------------------------------- class registry

    def register_class(self, name: str, *, prefix: Optional[str] = None,
                       distance: Optional[float] = None) -> None:
        """Declare a tensor class: keys starting with `prefix` belong to
        it (None: the default 'activation' bucket) at nominal reuse
        `distance`. Idempotent — clients call this unconditionally."""
        with self._cls_lock:
            if distance is not None:
                self._distances[name] = float(distance)
            else:
                self._distances.setdefault(
                    name, DEFAULT_CLASS_DISTANCES.get(name, 1.0))
            if prefix is not None:
                pairs = [p for p in self._prefixes if p[1] != name
                         or p[0] == prefix]
                if (prefix, name) not in pairs:
                    pairs.append((prefix, name))
                # longest prefix wins the classification scan
                self._prefixes = sorted(pairs, key=lambda p: -len(p[0]))

    def hint_class_distance(self, name: str, distance: float) -> None:
        """Update a class's measured reuse distance (e.g. AdaptivePolicy
        feeding profiled seconds-until-backward for activations)."""
        with self._cls_lock:
            self._distances[name] = float(distance)

    def classify(self, key: str) -> str:
        s = str(key)
        for prefix, name in self._prefixes:
            if s.startswith(prefix):
                return name
        return "activation"

    # -------------------------------------------------- reuse signals

    def hint_next(self, keys: Sequence[str]) -> None:
        """The caller's reuse horizon: these keys are needed soonest.
        Hinted keys are protected from eviction, and lowered ones are
        queued for background promotion (bounded by `promote_depth`)
        when the tier bandwidths price the promotion as a win."""
        promoted = 0
        with self._cls_lock:
            for key in keys:
                key = str(key)
                self._hinted.pop(key, None)
                self._hinted[key] = True
                self.hints += 1
                while len(self._hinted) > self.config.hint_capacity:
                    self._hinted.popitem(last=False)
        if self._promo_thread is not None:
            for key in keys:
                if promoted >= self.config.promote_depth:
                    break
                self._promo_q.put(str(key))
                promoted += 1

    def note_access(self, key: str) -> None:
        with self._cls_lock:
            self._hinted.pop(str(key), None)

    def _pick_victim(self, resident: "OrderedDict[str, int]") \
            -> Optional[str]:
        """Evict the earliest-stored blob of the farthest-reuse class,
        skipping the hinted horizon. Iteration is insertion order, so
        the first key of a class seen is that class's farthest reuse
        under the spool's LIFO access pattern. Called under the engine
        lock; falls back to FIFO when everything resident is hinted."""
        with self._cls_lock:
            hinted = self._hinted
            distances = self._distances
            max_d = max(distances.values()) if distances else 1.0
            best_k, best_d = None, float("-inf")
            for k in resident:
                if k in hinted:
                    continue
                d = distances.get(self.classify(k), 1.0)
                if d > best_d:
                    best_k, best_d = k, d
                    if d >= max_d:
                        break
        return best_k        # None -> engine FIFO fallback

    def _promotion_pays(self, nbytes: int) -> bool:
        """Price the move with measured tier bandwidths: promoting only
        pays when the eventual read would come off a lower tier that is
        slower than host RAM. Unmeasured tiers (no traffic yet) are
        priced optimistically — the first fetches calibrate them."""
        low = self.lower.stats
        up = self.upper.stats
        lower_bw = low.read_bandwidth if low.read_time else \
            low.write_bandwidth
        upper_bw = up.write_bandwidth
        if lower_bw <= 0 or upper_bw <= 0:
            return True
        return lower_bw < upper_bw

    def _promo_worker(self) -> None:
        while True:
            key = self._promo_q.get()
            if key is None:
                return
            try:
                nb = self.engine.size(key)
                if nb is not None and self._promotion_pays(nb):
                    self.engine.promote(key)
            except Exception:
                pass            # best-effort background migration

    # ------------------------------------------------ StorageBackend

    def _note_write(self, key: str, nbytes: int) -> None:
        cls = self.classify(key)
        with self._cls_lock:
            st = self._by_class.setdefault(cls, ClassStats())
            st.bytes_written += nbytes
            st.writes += 1
        if obs.is_enabled():
            obs.gauge("cache.host_bytes", self.engine.resident_bytes)

    def _write(self, key: str, data: bytes) -> None:
        # a pre-joined blob is stored by reference in RAM: no join copy
        self.engine.put(key, len(data),
                        lambda tier: tier.write(key, data))
        self._note_write(key, len(data))

    def _write_parts(self, key: str, parts: List[memoryview]) -> None:
        nbytes = sum(len(p) for p in parts)
        self.engine.put(key, nbytes,
                        lambda tier: tier.write_parts(key, parts),
                        ram_copy=True)
        self._note_write(key, nbytes)

    def _read(self, key: str) -> bytes:
        self.note_access(key)
        try:
            data = self.upper.read(key)
            self.host_hits += 1
            return data
        except FileNotFoundError:
            data = self.lower.read(key)
            self.ssd_hits += 1
            return data

    def _readinto(self, key: str, buf: memoryview) -> int:
        self.note_access(key)
        try:
            n = len(self.upper.readinto(key, buf))
            self.host_hits += 1
            return n
        except FileNotFoundError:
            n = len(self.lower.readinto(key, buf))
            self.ssd_hits += 1
            return n

    def _size(self, key: str) -> Optional[int]:
        return self.engine.size(key)

    def _delete(self, key: str) -> None:
        self.note_access(key)
        self.engine.delete(key)

    def flush(self) -> None:
        self.lower.flush()

    def reset_stats(self) -> None:
        super().reset_stats()
        self.upper.reset_stats()
        self.lower.reset_stats()

    def calibrate(self, data: bytes, repeats: int = 2) -> None:
        """Burst both tiers (same rationale as the tiered backend: a
        small burst fits the RAM budget, so the lower tier would read
        as infinitely fast if only the front door were measured)."""
        self.reset_stats()
        for i in range(repeats):
            self.upper.write(f"_calibrate{i}", data)
        for i in range(repeats):
            self.upper.delete(f"_calibrate{i}")
        self.lower.calibrate(data, repeats)

    def close(self) -> None:
        if self._promo_thread is not None:
            self._promo_q.put(None)
            self._promo_thread.join(timeout=5.0)
            self._promo_thread = None
        self.lower.close()

    def tier_bandwidths(self) -> List[TierBandwidth]:
        up = TierBandwidth("host-ram", self.upper.stats.write_bandwidth,
                           self.config.host_bound_bytes)
        return [up] + self.lower.tier_bandwidths()

    # -------------------------------------------------- observability

    def residency(self) -> Dict[str, Dict[str, int]]:
        """Exact per-tier, per-class resident bytes right now."""
        upper, lowered = self.engine.tier_items()
        out: Dict[str, Dict[str, int]] = {"host-ram": {}, "ssd": {}}
        for k, nb in upper.items():
            cls = self.classify(k)
            out["host-ram"][cls] = out["host-ram"].get(cls, 0) + nb
        for k, nb in lowered.items():
            cls = self.classify(k)
            out["ssd"][cls] = out["ssd"].get(cls, 0) + nb
        return out

    def cache_stats(self) -> Dict[str, object]:
        """Flat counters + residency snapshot (the `cache_*` block's
        source; monotonic counters are diffed per step by the
        session)."""
        e = self.engine
        res = self.residency()
        stats = {
            "host_bytes": sum(res["host-ram"].values()),
            "ssd_bytes": sum(res["ssd"].values()),
            "host_peak_bytes": e.peak_resident_bytes,
            "host_bound_bytes": self.config.host_bound_bytes,
            "evictions": e.evictions,
            "bytes_evicted": e.bytes_evicted,
            "promotions": e.promotions,
            "bytes_promoted": e.bytes_promoted,
            "fallbacks": e.fallbacks,
            "bytes_fallback": e.bytes_fallback,
            "host_hits": self.host_hits,
            "ssd_hits": self.ssd_hits,
            "hints": self.hints,
            "residency": res,
        }
        if obs.is_enabled():
            obs.gauge("cache.host_bytes", stats["host_bytes"])
            obs.gauge("cache.ssd_bytes", stats["ssd_bytes"])
            for cls, nb in res["host-ram"].items():
                obs.gauge(f"cache.host_bytes.{cls}", nb)
        return stats

    #: counters in cache_stats() that are diffed into per-step deltas;
    #: everything else is a point-in-time gauge
    MONOTONIC = ("evictions", "bytes_evicted", "promotions",
                 "bytes_promoted", "fallbacks", "bytes_fallback",
                 "host_hits", "ssd_hits", "hints")

    def metrics_delta(self, prev: Optional[Dict[str, object]]) \
            -> Tuple[Dict[str, object], Dict[str, object]]:
        """(per-step cache block, new snapshot): counters are deltas
        against `prev`, residency/peak fields pass through as gauges."""
        cur = self.cache_stats()
        block = dict(cur)
        if prev:
            for k in self.MONOTONIC:
                block[k] = cur[k] - prev.get(k, 0)
        return block, cur


def plan_residency(class_bytes: Dict[str, int], *,
                   host_bound_bytes: int,
                   distances: Optional[Dict[str, float]] = None) \
        -> Dict[str, Dict[str, int]]:
    """Predicted steady-state placement: classes claim the bounded
    pinned-host tier in ascending reuse-distance order (nearest reuse
    keeps RAM); whatever overflows the MemAscend-style bound lands on
    SSD. Shares the manager's class-distance table, so
    ``launch/dryrun.py``'s `predicted_residency` block pairs key-for-key
    with the measured `cache_*` residency in the metrics JSONL."""
    d = dict(DEFAULT_CLASS_DISTANCES)
    if distances:
        d.update(distances)
    room = max(0, int(host_bound_bytes))
    out: Dict[str, Dict[str, int]] = {}
    for cls, nbytes in sorted(class_bytes.items(),
                              key=lambda kv: (d.get(kv[0], 1.0), kv[0])):
        nbytes = max(0, int(nbytes))
        take = min(room, nbytes)
        out[cls] = {"host_ram_bytes": take, "ssd_bytes": nbytes - take}
        room -= take
    return out
