"""Tier-placement engine: the concurrency core extracted from the old
``TieredBackend._put`` so one protocol serves both the legacy static
`tiered` backend and the class-aware `CacheManager`.

The engine owns placement of keyed blobs across an upper (host-RAM)
store bounded by `capacity_bytes` and an unbounded lower (SSD) store.
The invariants are unchanged from the tiered backend that grew them:

  * victims are chosen under the lock, spilled OUTSIDE it (lower-tier
    writes are the slow part; serializing every store thread behind one
    eviction would reduce the hierarchy to single-threaded SSD speed);
  * a spill writes lower BEFORE deleting upper, so a concurrent read
    always finds the blob on one side without taking the lock;
  * oversize blobs (> capacity) bypass RAM, waiting out any in-flight
    migration of their key first;
  * deletes of mid-migration keys are completed by the migrating thread
    (`_kill`), and a key re-written while its old blob spills is
    detected (`readmitted`) so the stale copy never shadows fresh data.

New over the tiered original: a pluggable victim policy (`victim_fn` —
the CacheManager plugs in reuse-distance ordering; default is FIFO
front-pop, Belady's choice under the spool's LIFO access pattern), an
upward migration (`promote`, the demotion protocol run in reverse for
blobs the reuse horizon says are needed soon), exact per-tier byte
accounting (`_lowered` carries sizes, `peak_resident_bytes` records the
high-water pinned-host footprint for the MemAscend-style bound), and an
optional `fallback_to_upper` mode where a failing lower tier degrades
to host-RAM residency instead of losing data.
"""
from __future__ import annotations

import collections
import threading
from typing import Callable, Dict, Optional, Tuple

from repro import obs


class PlacementEngine:
    def __init__(self, upper, lower, *, capacity_bytes: int,
                 victim_fn: Optional[Callable] = None,
                 fallback_to_upper: bool = False,
                 note_copy: Optional[Callable[[int], None]] = None,
                 on_lower_error: Optional[
                     Callable[[BaseException], None]] = None):
        if capacity_bytes < 0:
            raise ValueError("capacity_bytes must be >= 0")
        self.upper = upper
        self.lower = lower
        self.capacity_bytes = capacity_bytes
        self.victim_fn = victim_fn
        self.fallback_to_upper = fallback_to_upper
        self._note_copy = note_copy or (lambda n: None)
        # failing-lower-tier observer (CacheManager feeds BackendHealth)
        self.on_lower_error = on_lower_error
        self._lock = threading.Lock()
        self._migration_done = threading.Condition(self._lock)
        # key -> nbytes, in store order (front = default evict-first)
        self._resident: "collections.OrderedDict[str, int]" = \
            collections.OrderedDict()
        self._spilling: set = set()      # victims mid-flight to lower
        self._promoting: set = set()     # keys mid-flight to upper
        self._kill: set = set()          # deleted while spilling
        self._lowered: Dict[str, int] = {}   # key -> nbytes in lower
        self._resident_bytes = 0         # running sum of _resident
        self.peak_resident_bytes = 0
        self.evictions = 0
        self.bytes_evicted = 0
        self.promotions = 0
        self.bytes_promoted = 0
        self.fallbacks = 0
        self.bytes_fallback = 0

    # ------------------------------------------------------ accounting

    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return self._resident_bytes

    @property
    def lowered_bytes(self) -> int:
        with self._lock:
            return sum(self._lowered.values())

    def tier_items(self) -> Tuple[Dict[str, int], Dict[str, int]]:
        """Snapshot of (upper, lower) key -> nbytes maps."""
        with self._lock:
            return dict(self._resident), dict(self._lowered)

    def _admit_locked(self, key: str, nbytes: int) -> None:
        prev = self._resident.pop(key, 0)
        self._resident[key] = nbytes
        self._resident_bytes += nbytes - prev
        self.peak_resident_bytes = max(self.peak_resident_bytes,
                                       self._resident_bytes)

    def _pick_victim(self) -> str:
        if self.victim_fn is not None:
            k = self.victim_fn(self._resident)
            if k is not None:
                return k
        return next(iter(self._resident))

    # ------------------------------------------------------- put / get

    def put(self, key: str, nbytes: int, put: Callable,
            ram_copy: bool = False) -> None:
        """Place a payload: `put(tier)` lands it on the chosen store.
        `ram_copy` marks a part-list payload whose RAM placement joins
        (one host copy), reported through `note_copy` so the owner's
        copies-per-byte stays honest; lower-tier copies live on the
        lower store's own stats."""
        if nbytes > self.capacity_bytes:
            self._put_oversize(key, nbytes, put)
            return
        with self._lock:
            victims = []
            while self._resident and \
                    self._resident_bytes + nbytes > self.capacity_bytes:
                k = self._pick_victim()
                nb = self._resident.pop(k)
                self._resident_bytes -= nb
                self._spilling.add(k)
                victims.append(k)
            put(self.upper)
            if ram_copy:
                self._note_copy(nbytes)
            self._admit_locked(key, nbytes)
            # a stale lower copy from an earlier oversize lease of this
            # key must not outlive the resident-only delete path
            stale_lower = self._lowered.pop(key, None) is not None
        if stale_lower:
            self.lower.delete(key)
        for k in victims:
            self._spill(k)

    def _put_oversize(self, key: str, nbytes: int, put: Callable) -> None:
        # Oversize blobs bypass RAM. Wait out any in-flight migration of
        # this key first — a migrator's stale copy must neither clobber
        # nor delete the new lower-tier blob — and claim the key out of
        # _resident so no evictor picks it up meanwhile.
        with self._migration_done:
            while key in self._spilling or key in self._promoting:
                self._migration_done.wait()
            nb = self._resident.pop(key, None)
            if nb is not None:
                self._resident_bytes -= nb
            self._lowered[key] = nbytes
        try:
            put(self.lower)
        except Exception as e:
            if self.on_lower_error is not None:
                self.on_lower_error(e)
            if not self.fallback_to_upper:
                with self._migration_done:
                    self._lowered.pop(key, None)
                raise
            # degraded lower tier: hold the blob in host RAM even over
            # budget — losing an activation loses the step
            with self._migration_done:
                self._lowered.pop(key, None)
            put(self.upper)
            with self._migration_done:
                self._admit_locked(key, nbytes)
                self.fallbacks += 1
                self.bytes_fallback += nbytes
            obs.count("cache.fallback")
            return
        if nb is not None:
            self.upper.delete(key)

    def _spill(self, k: str) -> None:
        """Demote one chosen victim (outside the lock; see module doc)."""
        try:
            blob = self.upper.read(k)
        except FileNotFoundError:
            with self._migration_done:
                self._spilling.discard(k)
                self._kill.discard(k)
                self._migration_done.notify_all()
            return
        try:
            with obs.span("cache.demote", cat="cache", key=str(k),
                          bytes=len(blob)):
                # write lower BEFORE deleting upper, so a concurrent
                # read always finds the blob on one side
                self.lower.write(k, blob)
        except Exception as e:
            if self.on_lower_error is not None:
                self.on_lower_error(e)
            with self._migration_done:
                self._spilling.discard(k)
                killed = k in self._kill
                self._kill.discard(k)
                readmitted = k in self._resident
                if self.fallback_to_upper and not (killed or readmitted):
                    # lower tier failing: re-admit at evict-first
                    # position — the blob stays host-resident (possibly
                    # over budget) rather than lost
                    self._resident[k] = len(blob)
                    self._resident.move_to_end(k, last=False)
                    self._resident_bytes += len(blob)
                    self.peak_resident_bytes = max(
                        self.peak_resident_bytes, self._resident_bytes)
                    self.fallbacks += 1
                    self.bytes_fallback += len(blob)
                self._migration_done.notify_all()
            if killed and not readmitted:
                self.upper.delete(k)
            if not self.fallback_to_upper:
                raise
            obs.count("cache.fallback")
            return
        with self._migration_done:
            self._spilling.discard(k)
            killed = k in self._kill
            self._kill.discard(k)
            # spool keys are reused across steps: the key may have been
            # re-written (a fresh resident blob) while we were spilling
            # the old one
            readmitted = k in self._resident
            if not (killed or readmitted):
                self._lowered[k] = len(blob)
            self.evictions += 1
            self.bytes_evicted += len(blob)
            self._migration_done.notify_all()
        if killed or readmitted:
            # our spilled copy is stale — it must not shadow the
            # re-admitted blob (or survive a drop)
            self.lower.delete(k)
            if killed and not readmitted:
                self.upper.delete(k)
        else:
            self.upper.delete(k)

    def promote(self, key: str) -> bool:
        """Migrate one lowered blob back to the upper tier (the reuse
        horizon says it is needed soon). Best-effort: returns False
        without side effects when the key is gone, already resident,
        mid-migration, or would not fit the budget."""
        with self._lock:
            nb = self._lowered.get(key)
            if nb is None or key in self._resident \
                    or key in self._spilling or key in self._promoting:
                return False
            if self._resident_bytes + nb > self.capacity_bytes:
                return False
            self._promoting.add(key)
        try:
            with obs.span("cache.promote", cat="cache", key=str(key),
                          bytes=nb):
                blob = self.lower.read(key)
        except Exception:
            with self._migration_done:
                self._promoting.discard(key)
                self._migration_done.notify_all()
            return False
        claimed = False
        with self._lock:
            # deleted or re-written while we were reading?
            if key in self._lowered and key not in self._resident \
                    and self._resident_bytes + len(blob) \
                    <= self.capacity_bytes:
                # RAM-store insert: cheap enough to hold the lock, and
                # it keeps read()'s find-it-on-one-side guarantee
                self.upper.write(key, blob)
                del self._lowered[key]
                self._admit_locked(key, len(blob))
                self.promotions += 1
                self.bytes_promoted += len(blob)
                claimed = True
        if claimed:
            self.lower.delete(key)
        with self._migration_done:
            self._promoting.discard(key)
            self._migration_done.notify_all()
        return claimed

    # ---------------------------------------------------------- reads

    def read(self, key: str) -> bytes:
        # Try RAM first and fall through on miss: a migration always
        # keeps the blob on at least one side (see module doc)
        try:
            return self.upper.read(key)
        except FileNotFoundError:
            return self.lower.read(key)

    def readinto(self, key: str, buf: memoryview) -> int:
        try:
            return len(self.upper.readinto(key, buf))
        except FileNotFoundError:
            return len(self.lower.readinto(key, buf))

    def size(self, key: str) -> Optional[int]:
        with self._lock:
            nb = self._resident.get(key)
            if nb is None:
                nb = self._lowered.get(key)
        if nb is not None:
            return nb
        # mid-migration: the same upper-then-lower order as reads
        n = self.upper.size(key)
        return n if n is not None else self.lower.size(key)

    def delete(self, key: str) -> None:
        with self._lock:
            nb = self._resident.pop(key, None)
            resident = nb is not None
            if resident:
                self._resident_bytes -= nb
            spilling = key in self._spilling
            if spilling:
                self._kill.add(key)    # the spiller finishes the delete
            lowered = self._lowered.pop(key, None) is not None
        if resident:
            self.upper.delete(key)
        if not spilling and (lowered or not resident):
            self.lower.delete(key)
