"""Deterministic synthetic token pipeline with sharded host loading.

The paper trains on OSCAR; on this container the data substrate is a
deterministic synthetic corpus with real pipeline mechanics:

  * SyntheticMarkovLM — a seeded first-order Markov language over `vocab`
    tokens (Zipf-ish transition rows). It has learnable bigram structure,
    so example drivers show a genuinely decreasing loss, and it is a pure
    function of (seed, shard, step): restarting from a checkpoint
    reproduces the exact stream (fault-tolerance requirement).
  * pack_documents — EOS-separated document packing to fixed seq_len
    (the standard LM pretraining treatment).
  * ShardedLoader — host-sharded batches (host i of N gets rows
    i::N), background prefetch thread with bounded queue, and a
    state_dict()/load_state_dict() pair so the trainer checkpoints the
    data position alongside the model.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np


class SyntheticMarkovLM:
    """Seeded Markov chain over the vocab; deterministic per (shard, step)."""

    def __init__(self, vocab_size: int, *, seed: int = 0, branch: int = 8):
        self.vocab = vocab_size
        self.seed = seed
        self.branch = branch
        rng = np.random.default_rng(seed)
        # each token transitions to `branch` candidates with Zipf weights
        self._next = rng.integers(0, vocab_size,
                                  size=(vocab_size, branch)).astype(np.int32)
        w = 1.0 / np.arange(1, branch + 1)
        self._w = (w / w.sum()).astype(np.float64)

    def sample(self, shard: int, step: int, batch: int, seq_len: int) \
            -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, shard, step]))
        toks = np.empty((batch, seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, batch)
        choices = rng.choice(self.branch, size=(batch, seq_len), p=self._w)
        for t in range(seq_len):
            toks[:, t + 1] = self._next[toks[:, t], choices[:, t]]
        return toks

    def batch(self, shard: int, step: int, batch: int,
              seq_len: int) -> Dict[str, np.ndarray]:
        toks = self.sample(shard, step, batch, seq_len)
        return {"tokens": toks[:, :-1],
                "labels": toks[:, 1:].astype(np.int32)}


def pack_documents(docs: Sequence[np.ndarray], seq_len: int,
                   eos_id: int, pad_id: int = 0) -> np.ndarray:
    """Pack variable-length docs into (n, seq_len) rows, EOS-separated.

    Greedy first-fit in arrival order; a doc longer than seq_len is split.
    The final partial row is padded with pad_id."""
    rows: List[np.ndarray] = []
    cur: List[int] = []
    for doc in docs:
        toks = list(doc) + [eos_id]
        while toks:
            space = seq_len - len(cur)
            cur.extend(toks[:space])
            toks = toks[space:]
            if len(cur) == seq_len:
                rows.append(np.asarray(cur, np.int32))
                cur = []
    if cur:
        cur.extend([pad_id] * (seq_len - len(cur)))
        rows.append(np.asarray(cur, np.int32))
    return np.stack(rows) if rows else np.zeros((0, seq_len), np.int32)


@dataclass
class PackedDataset:
    """Fixed array of packed rows served batch-by-batch (eval sets)."""
    rows: np.ndarray

    def batches(self, batch: int) -> Iterator[Dict[str, np.ndarray]]:
        n = (len(self.rows) // batch) * batch
        for i in range(0, n, batch):
            rows = self.rows[i:i + batch]
            yield {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


class ShardedLoader:
    """Host-sharded, prefetching, checkpointable loader.

    Each host pulls only its shard of the global batch (host i gets
    global_batch // num_hosts rows); `state_dict()` captures the step
    cursor so restarts resume the exact stream.
    """

    def __init__(self, source: SyntheticMarkovLM, *, global_batch: int,
                 seq_len: int, host_id: int = 0, num_hosts: int = 1,
                 prefetch: int = 2, start_step: int = 0):
        assert global_batch % num_hosts == 0
        self.source = source
        self.global_batch = global_batch
        self.local_batch = global_batch // num_hosts
        self.seq_len = seq_len
        self.host_id = host_id
        self.num_hosts = num_hosts
        self._step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        if prefetch > 0:
            self._thread = threading.Thread(target=self._worker,
                                            daemon=True)
            self._thread.start()

    def _make(self, step: int) -> Dict[str, np.ndarray]:
        return self.source.batch(self.host_id, step, self.local_batch,
                                 self.seq_len)

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._make(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        if self._thread is None:
            batch = self._make(self._step)
            self._step += 1
            return batch
        while True:
            step, batch = self._q.get()
            if step < self._step:      # stale after load_state_dict
                continue
            self._step = step + 1
            return batch

    def state_dict(self) -> Dict:
        return {"step": self._step, "host_id": self.host_id,
                "num_hosts": self.num_hosts}

    def load_state_dict(self, state: Dict) -> None:
        # note: resharding to a different host count is allowed — the
        # stream is a pure function of (shard, step), so elastically
        # resized restarts stay deterministic per shard.
        self._step = int(state["step"])

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
