from repro.data.pipeline import (PackedDataset, ShardedLoader,
                                 SyntheticMarkovLM, pack_documents)

__all__ = ["SyntheticMarkovLM", "ShardedLoader", "PackedDataset",
           "pack_documents"]
