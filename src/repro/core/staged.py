"""Host-staged trainer: the runnable TBA path (paper §3.1–§3.3).

Executes a training step as a chain of jitted per-module stages
(encoder stages -> embed -> super-layer x L -> loss head). After each
module's forward, its *actual autograd residuals* — the tensors jax.vjp
saves for backward, extracted by flattening the vjp closure — are handed
to the ActivationSpool, which stores them asynchronously; backward walks
the chain in reverse, prefetching one module ahead. This is the
pack/unpack-hook dataflow of the paper realised JAX-natively:

  pack hook      -> vjp-residual extraction + spool.offload()
  unpack hook    -> spool.fetch() (blocking, with tensor forwarding)
  param exclusion-> trace-time tracer-identity detection of parameter
                    leaves (paper §3.3.1)
  scope stack    -> the explicit stage list
  backward prefetch (§3.3.2) -> spool.prefetch(prev stage)
  adaptive offloading (§3.3.3) -> profile step 0, plan_offload(), keep-set

Encoder-decoder (T5) and VLM archs thread a second value — the encoder
states `enc` — through the chain: every cross-attention stage consumes
it, and its cotangents accumulate across stages before flowing back into
the encoder stages (`enc` is referenced by many scopes but offloaded
once — the paper's §3.3.1 dedup scenario).

Residual placement is decided by an `OffloadPolicy` object
(`repro.core.policies`, re-exported by `repro.session`) — KeepPolicy /
SpoolPolicy / RecomputePolicy / AdaptivePolicy are the ROK axes of §4.3.
The legacy `strategy: str` + `adaptive: bool` kwargs still work as a
deprecation shim via `resolve_policy`.

Spool access goes through transactional step leases
(`spool.step(step_id)`): key construction and drop bookkeeping live in
the transaction, and an exception mid-step drops every still-live
record instead of leaking blobs on the backend.
"""
from __future__ import annotations

import shutil
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.cache.horizon import reuse_horizon
from repro.core.accounting import MemoryTracker
from repro.core.adaptive import ModuleProfile, OffloadPlan
from repro.core.policies import OffloadPolicy, resolve_policy
from repro.core.report import StepReport
from repro.core.spool import build_spool
from repro.models.api import ModelApi
from repro.models.layers import rms_norm
from repro.models.transformer import RunSettings, apply_block


def _nbytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)
               if hasattr(x, "size"))


class _Stage:
    """One module of the chain, with faithful fwd/bwd splitting.

    role: enc_embed | enc_layer | enc_final | vlm_enc | embed | layer
          | head.  takes_enc: stage fn is f(p, x, enc)."""

    def __init__(self, name: str, fn: Callable, role: str,
                 takes_enc: bool = False):
        self.name = name
        self.fn = fn
        self.role = role
        self.takes_enc = takes_enc
        self.cell: Dict[str, Any] = {}

        def fwd(p, *args):
            out, vjp = jax.vjp(fn, p, *args)
            leaves, treedef = jax.tree.flatten(vjp)
            pids = {id(t) for t in jax.tree.leaves(p)}
            self.cell["treedef"] = treedef
            self.cell["param_idx"] = tuple(
                i for i, l in enumerate(leaves) if id(l) in pids)
            self.cell["n_leaves"] = len(leaves)
            return out, tuple(leaves)

        def bwd(leaves, g):
            vjp = jax.tree.unflatten(self.cell["treedef"], list(leaves))
            return vjp(g)

        def bwd_recompute(p, args, g):
            _, vjp = jax.vjp(fn, p, *args)
            return vjp(g)

        self.fwd = jax.jit(fwd)
        self.bwd = jax.jit(bwd)
        self.bwd_recompute = jax.jit(bwd_recompute)

    def split_leaves(self, leaves):
        """(param_leaves_by_idx, residual_leaves_by_idx)"""
        pidx = set(self.cell["param_idx"])
        params = {i: l for i, l in enumerate(leaves) if i in pidx}
        resid = {i: l for i, l in enumerate(leaves) if i not in pidx}
        return params, resid


# Back-compat: StepReport used to be defined here; it now lives in
# repro.core.report as the schema shared by both engines.
__all__ = ["StagedTrainer", "StepReport"]


class StagedTrainer:
    def __init__(self, api: ModelApi, settings: RunSettings, optimizer,
                 *, policy: Optional[OffloadPolicy] = None,
                 strategy: Optional[str] = None,
                 spool_dir: Optional[str] = None,
                 backend=None, io_config=None, codec: Optional[str] = None,
                 store_threads: Optional[int] = None,
                 load_threads: Optional[int] = None,
                 bandwidth_limit: Optional[float] = None,
                 adaptive: Optional[bool] = None,
                 num_microbatches: int = 1,
                 min_offload_elements: Optional[int] = None,
                 on_fetch_fail: Optional[str] = None):
        self.api = api
        self.cfg = api.cfg
        self.settings = settings
        self.optimizer = optimizer
        # `strategy`/`adaptive` are the legacy kwargs; resolve_policy
        # maps them (and the seed defaults) onto a policy object.
        self.policy = resolve_policy(policy, strategy=strategy,
                                     adaptive=adaptive)
        self.strategy = self.policy.strategy      # legacy string view
        self.num_microbatches = num_microbatches
        self.tracker = MemoryTracker()
        self._closed = False
        self.spool, self._owned_tmpdirs = build_spool(
            io_config, backend=backend, spool_dir=spool_dir,
            codec=codec, store_threads=store_threads,
            load_threads=load_threads, bandwidth_limit=bandwidth_limit,
            tracker=self.tracker,
            min_offload_elements=min_offload_elements)
        # Degradation ladder (repro.resilience): when a residual fetch
        # ultimately fails (blob lost, device gone), "recompute" re-runs
        # the stage's forward from a host-RAM copy of its input kept
        # during forward; "raise" keeps the seed behavior (the step
        # dies). The host copy costs RAM, never device memory.
        self.on_fetch_fail = (on_fetch_fail
                              or getattr(io_config, "on_fetch_fail", None)
                              or "recompute")
        assert self.on_fetch_fail in ("recompute", "raise")
        # mid-run re-plan: the policy watches the spool's health monitor
        if hasattr(self.policy, "attach_health"):
            self.policy.attach_health(self.spool.health)
        self._profiles: Optional[List[ModuleProfile]] = None
        self._stages = self._build_stages()
        self._step = 0

    @property
    def plan(self) -> Optional[OffloadPlan]:
        return self.policy.plan

    @property
    def adaptive(self) -> bool:
        """Legacy view: is the policy profile-driven?"""
        return self.policy.wants_profile or self.policy.plan is not None

    # ------------------------------------------------------ stage chain

    def _build_stages(self) -> List[_Stage]:
        api, cfg, settings = self.api, self.cfg, self.settings
        stages: List[_Stage] = []

        from repro.models.api import _embed_in, _head  # internal reuse
        import dataclasses as _dc

        # ---- encoder stream (T5) / stub frontend (VLM)
        if cfg.family == "encdec":
            enc_cfg = _dc.replace(cfg, causal=False)

            def enc_embed_fn(p, batch):
                return _embed_in(p, {"tokens": batch["enc_tokens"]},
                                 enc_cfg, settings)

            stages.append(_Stage("enc_embed", enc_embed_fn, "enc_embed"))
            for si, seg in enumerate(api.enc_segments):
                def enc_layer_fn(p_layer, x, seg=seg):
                    aux: Dict[str, Any] = {}
                    positions = (jnp.arange(x.shape[1])
                                 if enc_cfg.use_rope else None)
                    for i, bdef in enumerate(seg.blocks):
                        x, _ = apply_block(bdef, p_layer[f"b{i}"], x,
                                           enc_cfg, settings,
                                           positions=positions, aux=aux)
                    return x
                for rep in range(seg.n_repeat):
                    stages.append(_Stage(f"enc{si}_l{rep}", enc_layer_fn,
                                         "enc_layer"))

            def enc_final_fn(p, x):
                return rms_norm(x, p["enc_norm"]["scale"], cfg.norm_eps)

            stages.append(_Stage("enc_final", enc_final_fn, "enc_final"))
        elif cfg.family == "vlm":
            def vlm_enc_fn(p, batch):
                from repro.models.layers import dtype_of
                return batch["enc_embeddings"].astype(
                    dtype_of(settings.param_dtype))

            stages.append(_Stage("vlm_enc", vlm_enc_fn, "vlm_enc"))

        # ---- decoder stream
        stages.append(_Stage("embed",
                             lambda p, b: _embed_in(p, b, cfg, settings),
                             "embed"))

        has_enc = cfg.family in ("encdec", "vlm")
        for si, seg in enumerate(api.segments):
            takes_enc = has_enc and any(b.mixer == "cross"
                                        for b in seg.blocks)

            def layer_fn(p_layer, x, *rest, seg=seg):
                enc = rest[0] if rest else None
                aux: Dict[str, Any] = {}
                positions = (jnp.arange(x.shape[1]) if cfg.use_rope
                             else None)
                for i, bdef in enumerate(seg.blocks):
                    x, _ = apply_block(bdef, p_layer[f"b{i}"], x, cfg,
                                       settings, positions=positions,
                                       enc_kv=enc, aux=aux)
                return x
            for rep in range(seg.n_repeat):
                stages.append(_Stage(f"seg{si}_l{rep}", layer_fn,
                                     "layer", takes_enc=takes_enc))

        def head_fn(p, x, labels):
            logits = _head(p, x, cfg)
            mask = (labels >= 0).astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            picked = jnp.take_along_axis(
                logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
            return ((lse - picked) * mask).sum() / jnp.maximum(mask.sum(),
                                                               1.0)
        stages.append(_Stage("head", head_fn, "head"))
        return stages

    def _stage_params(self, params) -> List[Any]:
        """Slice the model params into per-stage param trees (same order
        as self._stages)."""
        emb = {k: params[k] for k in ("embed", "pos_embed",
                                      "frontend_proj") if k in params}
        out: List[Any] = []
        for stage in self._stages:
            if stage.role in ("enc_embed", "embed"):
                out.append(emb)
            elif stage.role == "enc_final":
                out.append({"enc_norm": params["enc_norm"]})
            elif stage.role == "vlm_enc":
                out.append({})
            elif stage.role == "head":
                out.append({"final_norm": params["final_norm"],
                            "unembed": params["unembed"]})
            elif stage.role == "enc_layer":
                si, rep = self._seg_pos(stage.name)
                out.append(jax.tree.map(lambda a: a[rep],
                                        params["enc_segments"][si]))
            else:  # layer
                si, rep = self._seg_pos(stage.name)
                out.append(jax.tree.map(lambda a: a[rep],
                                        params["segments"][si]))
        return out

    @staticmethod
    def _seg_pos(name: str) -> Tuple[int, int]:
        """'seg0_l3' / 'enc1_l2' -> (segment index, repeat index)."""
        left, rep = name.split("_l")
        si = int("".join(ch for ch in left if ch.isdigit()) or 0)
        return si, int(rep)

    # ------------------------------------------------------------ step

    def _args_for(self, stage: _Stage, batch, x, xe, enc):
        if stage.role in ("enc_embed", "vlm_enc", "embed"):
            return (batch,)
        if stage.role in ("enc_layer", "enc_final"):
            return (xe,)
        if stage.role == "head":
            return (x, batch["labels"])
        if stage.takes_enc:
            return (x, enc)
        return (x,)

    def train_step(self, params, opt_state, batches: Sequence[Dict]) \
            -> Tuple[Any, Any, StepReport]:
        """One optimizer step over `batches` micro-batches."""
        t0 = time.perf_counter()
        self.tracker.reset_peak()
        stage_params = self._stage_params(params)
        n_stages = len(self._stages)
        grads = None
        loss_total = 0.0
        profiles = [ModuleProfile(s.name, 0, 0.0) for s in self._stages]
        bwd_begin_bytes = 0

        with obs.span("engine.step", cat="engine", step=self._step,
                      engine="staged"):
            for mb, batch in enumerate(batches):
                with self.spool.step(f"mb{mb}") as tx:
                    grads, loss_total, bwd_begin_bytes = \
                        self._run_microbatch(
                            tx, mb, batch, stage_params, n_stages, grads,
                            loss_total, profiles, bwd_begin_bytes)

            # ---------------- optimizer ----------------
            with obs.span("engine.update", cat="engine", step=self._step):
                grads_tree = self._unstage_grads(grads)
                scale = 1.0 / len(batches)
                grads_tree = jax.tree.map(lambda g_: g_ * scale,
                                          grads_tree)
                params, opt_state = self.optimizer.update(
                    grads_tree, opt_state, params)
                jax.block_until_ready(jax.tree.leaves(params)[0])
        # The store tail is NOT synchronised here: adaptive offloading
        # (§3.3.3) schedules writes to complete inside the backward pass,
        # and any residue overlaps the next step's forward. Only the
        # profiling step drains the queue (to measure write bandwidth).
        profiling = self.policy.wants_profile and self._step == 0
        if profiling:
            self.spool.wait_io()
        step_time = time.perf_counter() - t0

        if profiling:
            self._profiles = profiles
            # Plan against the backend's measured per-tier bandwidths
            # (a tiered/striped store is not one scalar). The profiling
            # step's own writes raced jit compilation, so re-measure
            # with an uncontended burst sized like the largest module.
            max_bytes = max((p.bytes for p in profiles), default=0)
            self.spool.calibrate_backend(min(max_bytes, 8 << 20))
            cm = getattr(self.spool, "cache_manager", None)
            if cm is not None and \
                    hasattr(self.policy, "attach_cache_manager"):
                self.policy.attach_cache_manager(cm)
            self.policy.on_profile(profiles,
                                   self.spool.planner_bandwidth())
        self._step += 1
        return params, opt_state, StepReport(
            loss=loss_total / len(batches), step_time=step_time,
            peak_activation_bytes=self.tracker.peak,
            backward_begin_bytes=bwd_begin_bytes,
            stats=self.spool.stats, plan=self.plan,
            step=self._step, engine="staged")

    def _run_microbatch(self, tx, mb, batch, stage_params, n_stages,
                        grads, loss_total, profiles, bwd_begin_bytes):
        """Forward + backward for one microbatch under step lease `tx`."""
        # ---------------- forward ----------------
        x = xe = enc = None
        kept: Dict[int, Any] = {}
        recompute_in: Dict[int, Any] = {}
        # offloaded stages' inputs as host numpy — the recompute
        # fallback's raw material if the blob is later unreadable
        fallback_in: Dict[int, Any] = {}
        loss = None
        fwd_sp = obs.span("engine.fwd", cat="engine", step=self._step,
                          mb=mb)
        fwd_sp.__enter__()
        for si, stage in enumerate(self._stages):
            args = self._args_for(stage, batch, x, xe, enc)
            tin = time.perf_counter()
            if self.policy.recomputes(stage.role):
                out = stage.fn(stage_params[si], *args)
                recompute_in[si] = args
                self.tracker.alloc((tx.key(si), "k"), _nbytes(args),
                                   tag=f"ckpt:{tx.key(si)}")
                leaves = None
            else:
                out, leaves = stage.fwd(stage_params[si], *args)
                if self.policy.wants_profile and mb == 0:
                    # Profiling step: the first call of every stage
                    # paid jit compilation, which inflates the
                    # planner's deadline by orders of magnitude and
                    # makes it overcommit the store path. Release
                    # the cold call's buffers (so the footprint is
                    # not transiently doubled), then re-run warm and
                    # let `dt` below time that call.
                    jax.block_until_ready(out)
                    out = leaves = None
                    tin = time.perf_counter()
                    out, leaves = stage.fwd(stage_params[si], *args)
            if stage.role == "head":
                loss = out
            elif stage.role in ("enc_embed", "enc_layer"):
                xe = out
                jax.block_until_ready(xe)
            elif stage.role in ("enc_final", "vlm_enc"):
                enc = out
                jax.block_until_ready(enc)
            else:
                x = out
                jax.block_until_ready(x)
            dt = time.perf_counter() - tin

            if leaves is not None:
                p_leaves, r_leaves = stage.split_leaves(leaves)
                kept[si] = p_leaves      # params: never offloaded
                profile = ModuleProfile(
                    stage.name, _nbytes(list(r_leaves.values())), dt)
                if self.policy.should_offload(si, profile):
                    tx.offload(si, list(r_leaves.values()))
                    if self.on_fetch_fail == "recompute":
                        # host copies, off the device: the footprint the
                        # offload bought back is not spent again here
                        fallback_in[si] = jax.tree.map(np.asarray, args)
                else:
                    tx.keep(si, list(r_leaves.values()))
                profiles[si] = profile
                stage.cell.setdefault("resid_idx", tuple(r_leaves))
            del leaves

        fwd_sp.__exit__(None, None, None)
        self.tracker.mark(f"backward_begin_{tx.step_id}")
        bwd_begin_bytes = max(bwd_begin_bytes, self.tracker.current)

        # ---------------- backward ----------------
        g = jnp.ones((), jnp.float32)   # d loss
        mb_grads: List[Any] = [None] * n_stages
        carry_g = g
        enc_grad = None
        bwd_sp = obs.span("engine.bwd", cat="engine", step=self._step,
                          mb=mb)
        bwd_sp.__enter__()
        for si in range(n_stages - 1, -1, -1):
            stage = self._stages[si]
            # one module ahead (§3.3.2) — including stage 0: the embed
            # stage's residuals were a cold blocking load under an old
            # `> 0` off-by-one. reuse_horizon is empty at si == 0.
            for s in reuse_horizon(range(si - 1, -1, -1)):
                tx.prefetch(s)
            if si in recompute_in:
                outs = stage.bwd_recompute(stage_params[si],
                                           recompute_in[si], carry_g)
                self.tracker.free((tx.key(si), "k"),
                                  tag=f"ckpt_done:{tx.key(si)}")
                recompute_in.pop(si)
            else:
                try:
                    r_list = tx.fetch(si)
                except (RuntimeError, OSError) as e:
                    # the blob is truly gone (retries exhausted, device
                    # dead): degrade to recomputing this stage's forward
                    # from the host copy of its input kept at offload
                    # time — the bottom rung of the ladder
                    if (self.on_fetch_fail != "recompute"
                            or si not in fallback_in):
                        raise
                    self.spool.stats.fetch_fallbacks += 1
                    if obs.is_enabled():
                        obs.count("resilience.fetch_fallback")
                        obs.instant("resilience.fetch_fallback",
                                    cat="resilience", stage=stage.name,
                                    key=tx.key(si), error=repr(e))
                    r_list = None
                if r_list is None:
                    args_dev = jax.tree.map(jnp.asarray,
                                            fallback_in.pop(si))
                    outs = stage.bwd_recompute(stage_params[si],
                                               args_dev, carry_g)
                    jax.block_until_ready(outs[0])
                else:
                    leaves = [None] * stage.cell["n_leaves"]
                    for i, l in kept[si].items():
                        leaves[i] = l
                    for i, l in zip(stage.cell["resid_idx"], r_list):
                        leaves[i] = l
                    outs = stage.bwd(tuple(leaves), carry_g)
                    jax.block_until_ready(outs[0])
                tx.drop(si)
                kept.pop(si)
                fallback_in.pop(si, None)
            dp, dargs = outs[0], outs[1:]
            mb_grads[si] = dp
            # ---- cotangent routing
            if stage.role == "head":
                carry_g = dargs[0]
            elif stage.role == "layer":
                carry_g = dargs[0]
                if stage.takes_enc:
                    denc = dargs[1]
                    enc_grad = denc if enc_grad is None else \
                        jax.tree.map(jnp.add, enc_grad, denc)
            elif stage.role == "embed":
                # decoder stream exhausted; switch to encoder stream
                carry_g = enc_grad
            elif stage.role in ("enc_final", "enc_layer"):
                carry_g = dargs[0]
            # enc_embed / vlm_enc: chain ends
        bwd_sp.__exit__(None, None, None)
        loss_total += float(loss)
        if grads is None:
            grads = mb_grads
        else:
            grads = [jax.tree.map(jnp.add, a, b)
                     for a, b in zip(grads, mb_grads)]
        return grads, loss_total, bwd_begin_bytes

    def _unstage_grads(self, grads: List[Any]):
        """Reassemble per-stage grads into the model params structure
        (shared leaves — e.g. the embed table used by both encoder and
        decoder embed stages — accumulate by addition)."""
        out: Dict[str, Any] = {}

        def merge(d: Dict[str, Any]):
            for k, v in d.items():
                if k in out:
                    out[k] = jax.tree.map(jnp.add, out[k], v)
                else:
                    out[k] = v

        seg_reps: Dict[Tuple[str, int], List[Any]] = {}
        for stage, g in zip(self._stages, grads):
            if stage.role in ("enc_layer", "layer"):
                si, rep = self._seg_pos(stage.name)
                kind = "enc" if stage.role == "enc_layer" else "dec"
                seg_reps.setdefault((kind, si), []).append(g)
            elif stage.role != "vlm_enc":
                merge(g)

        dec_sis = sorted(s for k, s in seg_reps if k == "dec")
        if dec_sis:
            out["segments"] = [
                jax.tree.map(lambda *xs: jnp.stack(xs),
                             *seg_reps[("dec", si)]) for si in dec_sis]
        enc_sis = sorted(s for k, s in seg_reps if k == "enc")
        if enc_sis:
            out["enc_segments"] = [
                jax.tree.map(lambda *xs: jnp.stack(xs),
                             *seg_reps[("enc", si)]) for si in enc_sis]
        return out

    def close(self):
        """Idempotent: drain + join the spool, then remove any spool
        directories this trainer created (the seed leaked its
        `tba_spool_*` temp dirs)."""
        if self._closed:
            return
        self._closed = True
        self.spool.close()
        for d in self._owned_tmpdirs:
            shutil.rmtree(d, ignore_errors=True)
