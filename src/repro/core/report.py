"""Unified per-step report emitted by both training engines.

One schema for the staged (TBA) engine and the whole-step jit engine, so
`TrainSession` callers, the metrics JSONL, and the benchmarks read the
same fields regardless of which engine produced a step. The staged
engine fills every field; the jit engine leaves the activation-footprint
fields at 0 (XLA owns device memory there) and fills the spool fields
only when the host-offload path is active.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional


@dataclass
class StepReport:
    loss: float
    step_time: float
    peak_activation_bytes: int = 0
    backward_begin_bytes: int = 0
    stats: Any = None                  # SpoolStats (or None: no spool)
    plan: Any = None                   # OffloadPlan (staged+adaptive only)
    step: int = -1                     # optimizer step index (-1: unset)
    engine: str = ""                   # "staged" | "jit"
    tokens_per_s: float = 0.0
    # engine-specific scalar metrics (jit: the step's full aux dict —
    # ce, tokens, moe_lb/moe_z on MoE archs, ...); merged into the JSONL
    extra: Dict[str, float] = field(default_factory=dict)

    def to_metrics(self) -> Dict[str, Any]:
        """Flat JSON-able dict — the unified metrics-JSONL schema."""
        rec: Dict[str, Any] = {
            "step": self.step,
            "engine": self.engine,
            "loss": float(self.loss),
            "step_time_s": float(self.step_time),
            "tokens_per_s": float(self.tokens_per_s),
            "peak_activation_bytes": int(self.peak_activation_bytes),
            "backward_begin_bytes": int(self.backward_begin_bytes),
        }
        if self.stats is not None:
            rec["bytes_offloaded"] = int(self.stats.bytes_offloaded)
            rec["bytes_loaded"] = int(self.stats.bytes_loaded)
            rec["bytes_forwarded"] = int(self.stats.bytes_forwarded)
            rec["fetch_wait_s"] = float(self.stats.fetch_wait_time)
        if self.plan is not None:
            rec["plan_last_offloaded"] = int(self.plan.last_offloaded)
        for k, v in self.extra.items():
            rec.setdefault(k, v)
        return rec
