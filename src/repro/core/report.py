"""Unified per-step report emitted by both training engines.

One schema for the staged (TBA) engine and the whole-step jit engine, so
`TrainSession` callers, the metrics JSONL, and the benchmarks read the
same fields regardless of which engine produced a step. The staged
engine fills every field; the jit engine leaves the activation-footprint
fields at 0 (XLA owns device memory there) and fills the spool fields
only when the host-offload path is active.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional


@dataclass
class StepReport:
    loss: float
    step_time: float
    peak_activation_bytes: int = 0
    backward_begin_bytes: int = 0
    stats: Any = None                  # SpoolStats (or None: no spool)
    plan: Any = None                   # OffloadPlan (staged+adaptive only)
    step: int = -1                     # optimizer step index (-1: unset)
    engine: str = ""                   # "staged" | "jit"
    tokens_per_s: float = 0.0
    # engine-specific scalar metrics (jit: the step's full aux dict —
    # ce, tokens, moe_lb/moe_z on MoE archs, ...); merged into the JSONL
    extra: Dict[str, float] = field(default_factory=dict)
    # repro.obs overlap analysis for THIS step's trace window (see
    # repro.obs.overlap.analyze); emitted with an obs_ prefix
    obs: Optional[Dict[str, Any]] = None
    # per-shard HookBridge traffic deltas for this step, keyed by shard
    # id ("global" on a single device)
    shard_stats: Optional[Dict[str, Dict[str, int]]] = None
    # cache-manager block for this step (managed backend only): counter
    # deltas + residency gauges from CacheManager.metrics_delta; emitted
    # with a cache_ prefix
    cache: Optional[Dict[str, Any]] = None
    # resilience block for this step (any spool): retry / fallback /
    # re-plan / rebalance counter deltas plus backend-health gauges
    # (repro.resilience); emitted with a resilience_ prefix
    resilience: Optional[Dict[str, Any]] = None

    def to_metrics(self) -> Dict[str, Any]:
        """Flat JSON-able dict — the unified metrics-JSONL schema.

        The spool fields are PER-STEP deltas: both engines snapshot
        `SpoolStats` at step boundaries and hand the report the
        difference, so a JSONL row describes its own step, not the run
        so far."""
        rec: Dict[str, Any] = {
            "step": self.step,
            "engine": self.engine,
            "loss": float(self.loss),
            "step_time_s": float(self.step_time),
            "tokens_per_s": float(self.tokens_per_s),
            "peak_activation_bytes": int(self.peak_activation_bytes),
            "backward_begin_bytes": int(self.backward_begin_bytes),
        }
        if self.stats is not None:
            rec["bytes_offloaded"] = int(self.stats.bytes_offloaded)
            rec["bytes_loaded"] = int(self.stats.bytes_loaded)
            rec["bytes_forwarded"] = int(self.stats.bytes_forwarded)
            rec["fetch_wait_s"] = float(self.stats.fetch_wait_time)
        if self.plan is not None:
            rec["plan_last_offloaded"] = int(self.plan.last_offloaded)
        if self.obs:
            for k, v in self.obs.items():
                rec[f"obs_{k}"] = v
        if self.shard_stats:
            rec["shards"] = self.shard_stats
        if self.cache:
            for k, v in self.cache.items():
                rec[f"cache_{k}"] = v
        if self.resilience is not None:
            for k, v in self.resilience.items():
                rec[f"resilience_{k}"] = v
        for k, v in self.extra.items():
            rec.setdefault(k, v)
        return rec
