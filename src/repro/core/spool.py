"""ActivationSpool — the tensor cache's I/O engine (paper §3.2–3.3.2).

Two FIFO thread pools (store / load), exactly the paper's structure:

  * offload(key, arrays): enqueue an async store; the spool holds the only
    strong reference to the arrays, so device memory is reclaimed the moment
    the write completes and the reference is dropped (pack-hook semantics).
  * prefetch(key): enqueue an async load (issued by the backward walker one
    module ahead, §3.3.2).
  * fetch(key): blocking acquire for backward. If the store is still queued
    or in flight, the in-memory reference is *forwarded* (§3.3.2) and the
    pending store is cancelled (adaptive-offloading feature 1, §3.3.3).
  * deduplication: arrays whose storage is already tracked (or registered as
    parameters) are recorded as aliases and not written twice (§3.3.1).

The "SSD" behind the spool is a pluggable `repro.io.StorageBackend`:
a real directory (default, the seed behavior), a striped multi-SSD
array, a host-RAM tier, or a capacity-budgeted RAM-over-SSD hierarchy.
Payloads go through a pluggable `Codec` (raw / zlib). An optional
bandwidth_limit still simulates a slower tier for the ROK sweeps.
"""
from __future__ import annotations

import queue
import tempfile
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Union

import numpy as np

import jax

from repro import obs
from repro.core.accounting import MemoryTracker
from repro.core.adaptive import TierBandwidth
from repro.core.ids import TensorIdRegistry, _buffer_key
from repro.io import (Codec, FilesystemBackend, StorageBackend,
                      encode_parts, get_codec, pack_parts, unpack,
                      unpack_aliased)
from repro.io.backend import classify_io_error
from repro.io.bufpool import DEFAULT_ALIGNMENT, AlignedBufferPool
from repro.io.serde import (deserialize_leaves, serialize_leaves,
                            serialize_parts)
from repro.resilience.health import BackendHealth
from repro.resilience.retry import RetryPolicy

# job states
QUEUED, RUNNING, DONE, CANCELED = range(4)


def build_spool(io_config=None, *, backend=None, spool_dir=None,
                codec=None, store_threads=None, load_threads=None,
                bandwidth_limit=None, tracker=None,
                min_offload_elements=None, pool_bytes=None,
                alignment=None):
    """One spool-construction path for every engine.

    Storage selection, most specific wins: an explicit StorageBackend >
    a declarative SpoolIoConfig > the seed behavior (filesystem backend
    in spool_dir / a fresh temp dir). Explicit keyword arguments win
    over the config's fields. Returns (spool, owned_tmpdirs) — the
    caller must rmtree the listed temp dirs on close."""
    owned = []
    retry = None
    if io_config is not None and hasattr(io_config, "retry_attempts"):
        retry = RetryPolicy(
            max_attempts=io_config.retry_attempts,
            backoff_s=io_config.retry_backoff_s,
            backoff_max_s=getattr(io_config, "retry_backoff_max_s",
                                  0.25))
    if backend is None and io_config is not None:
        from repro.io import build_backend
        io_config.validate()
        backend = build_backend(io_config, default_dir=spool_dir)
        owned += list(getattr(backend, "owned_tmpdirs", ()))
        codec = io_config.codec if codec is None else codec
        if store_threads is None:
            store_threads = io_config.store_threads
        if load_threads is None:
            load_threads = io_config.load_threads
        if bandwidth_limit is None:
            bandwidth_limit = io_config.bandwidth_limit
        if pool_bytes is None:
            pool_bytes = getattr(io_config, "pool_bytes", None)
        if alignment is None:
            alignment = getattr(io_config, "alignment", None)
    if backend is None:
        if spool_dir is None:
            spool_dir = tempfile.mkdtemp(prefix="tba_spool_")
            owned.append(spool_dir)
        backend = spool_dir
    spool = ActivationSpool(
        backend, codec=codec,
        store_threads=(4 if store_threads is None else store_threads),
        load_threads=(4 if load_threads is None else load_threads),
        bandwidth_limit=bandwidth_limit, tracker=tracker,
        min_offload_elements=(MIN_OFFLOAD_ELEMENTS
                              if min_offload_elements is None
                              else min_offload_elements),
        pool_bytes=(256 << 20 if pool_bytes is None else pool_bytes),
        alignment=(DEFAULT_ALIGNMENT if alignment is None
                   else alignment),
        retry=retry)
    return spool, owned

# paper Algorithm 2 line 12: tensors smaller than 2**20 elements stay put
MIN_OFFLOAD_ELEMENTS = 2 ** 20

# back-compat aliases for the serialization helpers that used to live here
_serialize = serialize_leaves
_deserialize = deserialize_leaves


def _nbytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


@dataclass
class SpoolStats:
    bytes_offloaded: int = 0
    # pre-codec residual bytes behind bytes_offloaded — their ratio is
    # the codec's measured compression on real activations
    bytes_offloaded_logical: int = 0
    bytes_loaded: int = 0
    bytes_forwarded: int = 0
    bytes_deduped: int = 0
    stores_canceled: int = 0
    store_time: float = 0.0
    load_time: float = 0.0
    num_stores: int = 0
    num_loads: int = 0
    # time the *consumer* (backward pass) spent blocked waiting for a
    # load — the paper's "I/O latency exposed in the critical path".
    fetch_wait_time: float = 0.0
    # resilience: transient-failure retries the workers rode out, and
    # fetches the engines degraded to recompute after a lost blob
    store_retries: int = 0
    load_retries: int = 0
    fetch_fallbacks: int = 0
    # write-back policy: opt-state bytes whose SSD rewrite was skipped
    # because the moments were byte-identical to the staged copy
    # (zero-grad layers, frozen params)
    opt_skipped_bytes: int = 0

    @property
    def write_bandwidth(self) -> float:
        # 0.0, not inf, before the first store completes: dryrun /
        # roofline reports print this, and "inf GB/s" is a lie
        return self.bytes_offloaded / self.store_time \
            if self.store_time else 0.0

    def add(self, other: "SpoolStats") -> "SpoolStats":
        """Field-wise sum — aggregate stats across spools (e.g. one
        spool per shard group, or per-step snapshots)."""
        import dataclasses as _dc
        return SpoolStats(**{
            f.name: getattr(self, f.name) + getattr(other, f.name)
            for f in _dc.fields(SpoolStats)})

    __add__ = add

    def sub(self, other: "SpoolStats") -> "SpoolStats":
        """Field-wise difference — turns two cumulative snapshots into
        a per-step delta (`new.sub(old)`)."""
        import dataclasses as _dc
        return SpoolStats(**{
            f.name: getattr(self, f.name) - getattr(other, f.name)
            for f in _dc.fields(SpoolStats)})

    __sub__ = sub

    def snapshot(self) -> "SpoolStats":
        """Value copy of a live (mutating) stats object, safe to diff
        against later."""
        import dataclasses as _dc
        return _dc.replace(self)


class _Job:
    __slots__ = ("key", "arrays", "state", "cond", "kind", "orphaned",
                 "error", "reg_keys", "prefetched")

    def __init__(self, key, arrays, kind):
        self.key = key
        self.arrays = arrays
        self.state = QUEUED
        self.cond = threading.Condition()
        self.kind = kind  # "store" | "load"
        self.orphaned = False  # dropped while the store was running
        self.error = None      # exception raised by the worker, if any
        # load jobs: issued by an explicit prefetch() hint (vs. fetch's
        # own demand load) — the distinction behind prefetch hit/late/
        # ghost accounting in repro.obs
        self.prefetched = False
        # dedup-registry keys for the spooled leaves; released by
        # whoever drops the last reference to self.arrays (the store
        # worker on success, drop() otherwise) — releasing later than
        # the buffer free would let a recycled allocation false-dedup
        # against a dead entry
        self.reg_keys: tuple = ()


class SpoolStepTransaction:
    """Transactional lease on one training step's spool records.

    The spool's raw protocol (offload/keep/prefetch/fetch/drop on string
    keys) left key construction and drop bookkeeping to every caller —
    and an exception mid-step leaked every record still live. A
    transaction owns both: stages are addressed by index, keys are
    derived once (``{step_id}_s{stage}``, byte-identical to the seed's
    hand-rolled ``f"mb{mb}_s{si}"``), and closing the transaction drops
    every record the caller did not consume — on success *and* on
    exception, so an aborted step never strands blobs on the backend.

        with spool.step(f"mb{mb}") as tx:
            tx.offload(si, residuals)     # forward
            ...
            tx.prefetch(si - 1)           # backward, one module ahead
            residuals = tx.fetch(si)
            tx.drop(si)
    """

    __slots__ = ("_spool", "step_id", "_live", "_closed", "_tlock",
                 "_consumers", "_stage_locks")

    def __init__(self, spool: "ActivationSpool", step_id: str):
        self._spool = spool
        self.step_id = step_id
        self._live: Dict[Any, str] = {}     # stage -> spool key
        # stage -> remaining consume() calls before the stage is dropped
        # (shard-aware leases: a record replicated across N mesh shards
        # is stored once and consumed N times, one fetch per shard)
        self._consumers: Dict[Any, int] = {}
        # stage -> lock serializing concurrent consumers of ONE stage,
        # so a non-final peek never races the final fetch's drop (the
        # drop releases the pooled load buffer the peek's zero-copy
        # views still borrow)
        self._stage_locks: Dict[Any, threading.Lock] = {}
        self._closed = False
        # the jit engine's hooks drive one transaction from XLA
        # host-callback threads; stage bookkeeping must be re-entrant
        self._tlock = threading.Lock()

    def key(self, stage) -> str:
        return f"{self.step_id}_s{stage}"

    def _record(self, stage, consumers: int = 1) -> str:
        if consumers < 1:
            raise ValueError(f"consumers must be >= 1, got {consumers}")
        with self._tlock:
            if self._closed:
                raise RuntimeError(
                    f"spool transaction {self.step_id!r} is closed")
            key = self.key(stage)
            if stage in self._live:
                raise KeyError(f"stage {stage!r} already live in step "
                               f"{self.step_id!r}")
            self._live[stage] = key
            self._consumers[stage] = consumers
            self._stage_locks[stage] = threading.Lock()
        return key

    def offload(self, stage, tree, *, consumers: int = 1) -> None:
        """Async-store a stage's residual pytree under this lease.
        `consumers` is how many `consume()` calls the stage expects
        before it is dropped (one per mesh shard holding a replica)."""
        self._spool.offload(self._record(stage, consumers), tree)

    def keep(self, stage, tree, *, consumers: int = 1) -> None:
        """Record a stage's residuals as kept-in-memory under this
        lease (same drop/accounting lifecycle as offloaded ones)."""
        self._spool.keep(self._record(stage, consumers), tree)

    def has_stage(self, stage) -> bool:
        """True while the stage is recorded and not fully consumed."""
        with self._tlock:
            return stage in self._live

    def prefetch(self, stage) -> None:
        """Hint an async load; a stage this lease never recorded is
        ignored (recompute stages have nothing to load)."""
        with self._tlock:
            key = self._live.get(stage)
        if key is not None:
            self._spool.prefetch(key)

    def fetch(self, stage, *, to_device: bool = True):
        """Blocking: the stage's full residual pytree (forwarded from
        the in-flight store or reloaded from the backend).
        to_device=False keeps reloaded leaves as host numpy arrays —
        for callers (the jit engine's host callbacks) that must not
        enter the jax runtime on their thread."""
        with self._tlock:
            key = self._live.get(stage)
        if key is None:
            raise KeyError(f"stage {stage!r} not recorded in step "
                           f"{self.step_id!r}")
        return self._spool.fetch(key, to_device=to_device)

    def peek(self, stage, *, to_device: bool = True):
        """Non-consuming fetch: materialize the pytree WITHOUT
        cancelling a still-queued store, so a later fetch/drop still
        finds the blob on the backend (checkpoint materialization)."""
        with self._tlock:
            key = self._live.get(stage)
        if key is None:
            raise KeyError(f"stage {stage!r} not recorded in step "
                           f"{self.step_id!r}")
        return self._spool.fetch(key, cancel_pending=False,
                                 to_device=to_device)

    def consume(self, stage, *, to_device: bool = True):
        """Fetch the stage's pytree and count one consumer down; the
        LAST consumer's call also drops the record (memory + blob).
        Concurrent consumers of one stage serialize on a per-stage
        lock, so a non-final materialization never races the final
        drop's pool-lease release."""
        with self._tlock:
            if stage not in self._live:
                raise KeyError(f"stage {stage!r} not recorded in step "
                               f"{self.step_id!r}")
            slock = self._stage_locks[stage]
        with slock:
            with self._tlock:
                remaining = self._consumers.get(stage, 0)
                if remaining <= 0:        # dropped by a racing consumer
                    raise KeyError(f"stage {stage!r} already consumed "
                                   f"in step {self.step_id!r}")
                self._consumers[stage] = remaining - 1
                last = remaining == 1
            if last:
                out = self.fetch(stage, to_device=to_device)
                self.drop(stage)
            else:
                out = self.peek(stage, to_device=to_device)
        return out

    def drop(self, stage) -> None:
        """Consume the stage: free memory and delete the blob."""
        with self._tlock:
            key = self._live.pop(stage, None)
            self._consumers.pop(stage, None)
            self._stage_locks.pop(stage, None)
        if key is not None:
            self._spool.drop(key)

    @property
    def live_stages(self):
        with self._tlock:
            return sorted(self._live)

    def close(self) -> None:
        """Drop every record not consumed yet and release the lease.
        Idempotent; this is the leak-on-exception backstop."""
        with self._tlock:
            if self._closed:
                return
            self._closed = True
            leftover = list(self._live)
        for stage in leftover:
            self.drop(stage)
        self._spool._release_step(self.step_id)

    def __enter__(self) -> "SpoolStepTransaction":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()


class ActivationSpool:
    def __init__(self, backend: Union[str, StorageBackend], *,
                 store_threads: int = 4,
                 load_threads: int = 4,
                 codec: Union[str, Codec, None] = None,
                 bandwidth_limit: Optional[float] = None,
                 tracker: Optional[MemoryTracker] = None,
                 registry: Optional[TensorIdRegistry] = None,
                 min_offload_elements: int = MIN_OFFLOAD_ELEMENTS,
                 pool: Optional[AlignedBufferPool] = None,
                 pool_bytes: int = 256 << 20,
                 alignment: int = DEFAULT_ALIGNMENT,
                 retry: Optional[RetryPolicy] = None,
                 health: Optional[BackendHealth] = None):
        # A bare directory string keeps the seed call shape:
        # ActivationSpool("/path/to/dir") == filesystem backend there.
        if isinstance(backend, str):
            backend = FilesystemBackend(backend)
        self.backend = backend
        self.dir = getattr(backend, "directory", None)
        # A cache-manager backend (repro.cache.CacheManager, duck-typed
        # on hint_next) gets the spool's tensor classes declared up
        # front and its reuse-distance hints fed from prefetch: the same
        # horizon that drives load scheduling drives tier placement.
        self.cache_manager = backend if hasattr(backend, "hint_next") \
            else None
        if self.cache_manager is not None:
            self.cache_manager.register_class("activation")
            self.cache_manager.register_class("opt_state", prefix="opt")
        self.codec = get_codec(codec)
        # One aligned pool serves the whole data plane: loads readinto
        # leased buffers (no per-load blob allocation), and an aio
        # backend stages its O_DIRECT writes from the same pool.
        backend_pool = getattr(backend, "pool", None)
        self.pool = pool or backend_pool or \
            AlignedBufferPool(alignment=alignment, max_bytes=pool_bytes)
        self._owns_pool = pool is None and backend_pool is None
        self.min_offload_elements = min_offload_elements
        self.tracker = tracker or MemoryTracker()
        self.registry = registry or TensorIdRegistry()
        self.stats = SpoolStats()
        # resilience: every backend call in the workers goes through
        # _with_retry, which classifies failures (repro.io.backend),
        # rides out transient ones with bounded backoff, and feeds the
        # health monitor that AdaptivePolicy re-plans from
        self.retry = retry or RetryPolicy()
        self.retry.validate()
        self.health = health or BackendHealth(self.backend.kind)
        if self.cache_manager is not None \
                and hasattr(self.cache_manager, "attach_health"):
            # SSD-tier write failures inside the manager (fallback to
            # host RAM) surface as health events next to spool retries
            self.cache_manager.attach_health(self.health)
        self._bw = bandwidth_limit
        self._lock = threading.Lock()
        self._records: Dict[Any, Dict] = {}     # key -> record
        self._store_q: "queue.Queue[_Job]" = queue.Queue()
        self._load_q: "queue.Queue[_Job]" = queue.Queue()
        self._stop = False
        self._closed = False
        self._store_threads = store_threads
        self._load_threads = load_threads
        self._active_steps: set = set()
        self._threads: List[threading.Thread] = []
        for i in range(store_threads):
            t = threading.Thread(target=self._worker,
                                 args=(self._store_q,), daemon=True,
                                 name=f"spool-store-{i}")
            t.start()
            self._threads.append(t)
        for i in range(load_threads):
            t = threading.Thread(target=self._worker,
                                 args=(self._load_q,), daemon=True,
                                 name=f"spool-load-{i}")
            t.start()
            self._threads.append(t)

    # ------------------------------------------------------------- API

    def step(self, step_id) -> SpoolStepTransaction:
        """Open a transactional lease for one training step's records
        (see `SpoolStepTransaction`). At most one live lease per
        step_id — a collision means the previous step leaked."""
        if self._closed:
            raise RuntimeError("spool is closed")
        step_id = str(step_id)
        with self._lock:
            if step_id in self._active_steps:
                raise RuntimeError(
                    f"step lease {step_id!r} is already active")
            self._active_steps.add(step_id)
        return SpoolStepTransaction(self, step_id)

    def lease(self, lease_id) -> SpoolStepTransaction:
        """Alias of `step` for non-training users. A lease is not tied
        to a training step: the paged KV cache (repro.kvcache) opens one
        long-lived lease per served sequence and uses logical page
        indices as stages, so retiring the sequence (`close`) drops
        every page it ever spooled — the same leak-proof contract, a
        different lifetime."""
        return self.step(lease_id)

    def _release_step(self, step_id: str) -> None:
        with self._lock:
            self._active_steps.discard(step_id)

    def register_parameters(self, params) -> int:
        return self.registry.register_parameters(params)

    def offload(self, key, tree) -> None:
        """Async-store a pytree of arrays under `key`. Small tensors and
        parameter/duplicate storages stay in memory (recorded, not
        written)."""
        leaves, treedef = jax.tree.flatten(tree)
        keep_idx, spool_idx, acquired, spooled_keys = [], [], [], []
        kept_act_bytes = alias_bytes = 0
        for i, leaf in enumerate(leaves):
            if self.registry.is_parameter(leaf):
                keep_idx.append(i)
                continue
            if leaf.size < self.min_offload_elements:
                keep_idx.append(i)
                kept_act_bytes += leaf.size * leaf.dtype.itemsize
                continue
            tid, dup = self.registry.acquire(leaf)
            if dup:
                # alias of a still-live tracked buffer: keep the
                # reference, never write it twice; its key is released
                # when the record drops
                acquired.append(_buffer_key(leaf))
                keep_idx.append(i)
                alias_bytes += leaf.size * leaf.dtype.itemsize
            else:
                # spooled leaves' keys ride the store job instead: the
                # worker frees the array the moment the write lands,
                # and the registry entry must die WITH the buffer or a
                # recycled allocation would false-dedup against it
                spooled_keys.append(_buffer_key(leaf))
                spool_idx.append(i)
        self.stats.bytes_deduped += alias_bytes

        spooled = [leaves[i] for i in spool_idx]
        nbytes = _nbytes(spooled)
        if kept_act_bytes:
            self.tracker.alloc((key, "k"), kept_act_bytes,
                               tag=f"kept_small:{key}")
        if not spool_idx:               # nothing above the threshold
            with self._lock:
                self._records[key] = {
                    "treedef": treedef,
                    "keep": {i: leaves[i] for i in keep_idx},
                    "spool_idx": [], "n_leaves": len(leaves), "job": None,
                    "nbytes": 0, "loaded": None, "load_job": None,
                    "load_lease": None, "acquired": acquired,
                }
            return
        self.tracker.alloc((key, "s"), nbytes, tag=f"residual:{key}")
        job = _Job(key, spooled, "store")
        job.reg_keys = tuple(spooled_keys)
        with self._lock:
            self._records[key] = {
                "treedef": treedef, "keep": {i: leaves[i] for i in keep_idx},
                "spool_idx": spool_idx, "n_leaves": len(leaves),
                "job": job, "nbytes": nbytes, "loaded": None,
                "load_job": None, "load_lease": None,
                "acquired": acquired,
            }
        self._store_q.put(job)
        if obs.is_enabled():
            obs.instant("spool.offload", cat="spool", key=str(key),
                        bytes=nbytes)
            obs.gauge("spool.store_backlog", self._store_q.qsize())

    def keep(self, key, tree) -> None:
        """Record a kept-in-memory pytree (adaptive offloading keeps the
        last modules on device, §3.3.3)."""
        leaves, treedef = jax.tree.flatten(tree)
        nbytes = sum(x.size * x.dtype.itemsize for x in leaves
                     if not self.registry.is_parameter(x))
        self.tracker.alloc((key, "k"), nbytes, tag=f"kept:{key}")
        with self._lock:
            self._records[key] = {
                "treedef": treedef, "keep": dict(enumerate(leaves)),
                "spool_idx": [], "n_leaves": len(leaves), "job": None,
                "nbytes": nbytes, "loaded": None, "load_job": None,
                "load_lease": None, "acquired": [],
            }

    def prefetch(self, key, *, _demand: bool = False) -> None:
        if self.cache_manager is not None:
            # the reuse horizon doubles as the placement hint: protect
            # the blob from eviction and let the manager promote it off
            # SSD ahead of the load worker's read
            self.cache_manager.hint_next([str(key)])
        with self._lock:
            rec = self._records.get(key)
            if rec is None or not rec["spool_idx"]:
                return
            job = rec["job"]
            with job.cond:
                if job.state in (QUEUED, RUNNING):
                    return          # still in memory; forwarding will hit
                if job.arrays is not None:
                    # CANCELED (or failed) store with its arrays still
                    # resident: the blob was never written, so a load
                    # would ghost-read the backend and bury the real
                    # error — fetch() forwards the in-memory reference
                    return
            if rec["load_job"] is not None or rec["loaded"] is not None:
                return
            lj = _Job(key, None, "load")
            lj.prefetched = not _demand
            rec["load_job"] = lj
        if not _demand:
            obs.count("prefetch.issued")
            obs.instant("spool.prefetch", cat="spool", key=str(key))
        self._load_q.put(lj)

    def fetch(self, key, *, cancel_pending: bool = True,
              to_device: bool = True):
        """Blocking: return the full pytree for backward.

        cancel_pending=False is the non-consuming ("peek") variant: a
        still-queued store is forwarded but NOT cancelled, so the write
        still lands and a later consuming fetch finds the blob —
        required when the caller materializes a record it will fetch
        again (e.g. checkpointing a spooled optimizer state).

        to_device=False leaves reloaded arrays as host numpy (still
        detached from pooled buffers) instead of jnp arrays — XLA
        host-callback threads must hand bytes straight back to XLA
        without re-entering the jax runtime."""
        with self._lock:
            rec = self._records.get(key)
            if rec is None:
                raise KeyError(key)
            job = rec["job"]
        spooled = None
        if job is not None and rec["spool_idx"]:
            with job.cond:
                if job.state in (QUEUED, RUNNING) or \
                        (job.state == CANCELED and job.arrays is not None):
                    # ---- tensor forwarding (§3.3.2): the store has not
                    # finished (or was cancelled with its arrays still
                    # resident — a re-fetch after forwarding); upgrade
                    # the in-flight reference. Cancel the write if it
                    # has not started (§3.3.3 feature 1).
                    spooled = job.arrays
                    if not rec.get("fwd_counted"):
                        # a peek-then-fetch (or re-fetch) of one record
                        # is one forwarding event, not two
                        rec["fwd_counted"] = True
                        self.stats.bytes_forwarded += _nbytes(spooled)
                    if job.state == QUEUED and cancel_pending:
                        job.state = CANCELED
                        self.stats.stores_canceled += 1
                        # memory stays resident; keep tracker entry
                elif job.error is not None and job.arrays is not None:
                    # the store failed (e.g. ENOSPC) but the arrays are
                    # still referenced — forward them rather than chase
                    # a blob that was never written
                    spooled = job.arrays
                    if not rec.get("fwd_counted"):
                        # same one-event rule as the healthy branch: a
                        # peek-then-fetch of a failed store is ONE
                        # forwarding, not two
                        rec["fwd_counted"] = True
                        self.stats.bytes_forwarded += _nbytes(spooled)
            if spooled is None:
                with self._lock:
                    lj = rec["load_job"]
                if lj is None:
                    self.prefetch(key, _demand=True)
                    with self._lock:
                        lj = rec["load_job"]
                if lj is not None:
                    if lj.prefetched:
                        # hit: the prefetched load already landed when
                        # the consumer arrived; late: issued but the
                        # consumer still has to wait for it
                        with lj.cond:
                            ready = lj.state in (DONE, CANCELED)
                        obs.count("prefetch.hit" if ready
                                  else "prefetch.late")
                    t_wait = time.perf_counter()
                    with obs.span("spool.fetch_wait", cat="spool",
                                  key=str(key)):
                        with lj.cond:
                            while lj.state not in (DONE, CANCELED):
                                lj.cond.wait()
                    self.stats.fetch_wait_time += (time.perf_counter()
                                                   - t_wait)
                    if lj.error is not None:
                        raise RuntimeError(
                            f"spool load failed for {key!r}") from lj.error
                with self._lock:
                    spooled = rec["loaded"]
                    rec["load_used"] = True
                self.tracker.alloc((key, "s"), rec["nbytes"],
                                   tag=f"reloaded:{key}")
        leaves = [None] * rec["n_leaves"]
        for i, leaf in rec["keep"].items():
            leaves[i] = leaf
        if rec["spool_idx"]:
            for i, leaf in zip(rec["spool_idx"], spooled):
                if isinstance(leaf, np.ndarray):
                    if not leaf.flags.writeable:
                        # copy-on-demand: pooled-load leaves are
                        # zero-copy views over a buffer the pool will
                        # reuse after drop(); jnp.asarray may ALIAS an
                        # aligned host array instead of copying, so
                        # detach here, exactly once, at materialization
                        leaf = leaf.copy()
                    if to_device:
                        leaf = jax.numpy.asarray(leaf)
                leaves[i] = leaf
        return jax.tree.unflatten(rec["treedef"], leaves)

    def drop(self, key) -> None:
        """Consume a record after backward: free memory + delete the
        blob from the backend."""
        with self._lock:
            rec = self._records.pop(key, None)
        if rec is None:
            return
        lj = rec.get("load_job")
        if lj is not None and lj.prefetched and not rec.get("load_used"):
            # ghost: prefetched from the backend but dropped unread —
            # wasted read bandwidth the planner should know about
            obs.count("prefetch.ghost")
        for bkey in rec["acquired"]:
            self.registry.release_key(bkey)
        job = rec["job"]
        if job is not None:
            # spooled-leaf keys the store worker did not release (the
            # store was cancelled, failed, or is still holding arrays
            # for forwarding) die with the record
            with job.cond:
                keys, job.reg_keys = job.reg_keys, ()
            for bkey in keys:
                self.registry.release_key(bkey)
        self.tracker.free((key, "s"), tag=f"consumed:{key}")
        self.tracker.free((key, "k"), tag=f"consumed:{key}")
        lease = rec.get("load_lease")
        if lease is not None:
            # the record's loaded views die with the record; hand the
            # pooled buffer to the next load
            rec["loaded"] = None
            rec["load_lease"] = None
            lease.release()
        if not rec["spool_idx"]:
            return
        job = rec["job"]
        if job is not None:
            with job.cond:
                if job.state == QUEUED:
                    # never written; cancel so the worker skips the
                    # (now pointless) write entirely
                    job.state = CANCELED
                    self.stats.stores_canceled += 1
                    return
                if job.state == RUNNING:
                    # the write will land *after* this delete — flag the
                    # job so the worker deletes on completion, or the
                    # blob leaks forever (on a RAM backend that is a
                    # real memory leak, not a stray file)
                    job.orphaned = True
                    return
        self.backend.delete(str(key))

    def wait_io(self) -> None:
        """Barrier: wait for all queued stores (paper Algorithm 1 line 15)."""
        self._store_q.join()
        self._load_q.join()

    def calibrate_backend(self, nbytes: int, repeats: int = 2) -> None:
        """Re-measure the whole store path with a synthetic uncontended
        burst.

        The profiling step's writes race jit compilation for CPU, so the
        busy-clock bandwidth they leave behind can understate the device
        severalfold and make the planner underoffload. Call after
        wait_io(). Two measurements:

        * codec+container throughput and size ratio on an incompressible
          payload (the worker encodes before it writes, so a slow codec
          bounds the store path no matter how fast the device is);
        * per-tier device bandwidth via backend.calibrate, which
          exercises every tier of a composite backend.
        """
        if nbytes <= 0:
            return
        import os as _os
        payload = _os.urandom(nbytes)
        t0 = time.perf_counter()
        for _ in range(repeats):
            data = pack_parts([payload], self.codec)
        t_codec = (time.perf_counter() - t0) / repeats
        self._codec_bw = nbytes / t_codec if t_codec > 0 else float("inf")
        # Size ratio from *real* spooled residuals when available: the
        # urandom probe is right for throughput (worst case) but wrong
        # for ratio — activations compress, random bytes don't.
        if self.stats.bytes_offloaded_logical > 0:
            self._codec_ratio = (self.stats.bytes_offloaded
                                 / self.stats.bytes_offloaded_logical)
        else:
            self._codec_ratio = len(data) / nbytes
        self.backend.calibrate(data, repeats)

    def planner_bandwidth(self) -> Union[float, List[TierBandwidth]]:
        """What the adaptive planner should plan against.

        Per-tier *store-path* bandwidths: the measured device rate of
        each tier composed (harmonically — the worker encodes, then
        writes) with the measured codec throughput, in logical residual
        bytes. Tier capacities are converted to logical bytes via the
        codec's size ratio. Falls back to the spool's own end-to-end
        scalar while any tier is still unmeasured."""
        tiers = self.backend.tier_bandwidths()
        if not tiers or any(t.write_bw <= 0 or t.write_bw == float("inf")
                            for t in tiers):
            return self.stats.write_bandwidth
        ratio = getattr(self, "_codec_ratio", 1.0)
        codec_bw = getattr(self, "_codec_bw", float("inf"))
        out = []
        for t in tiers:
            per_byte = ratio / t.write_bw + (1.0 / codec_bw
                                             if codec_bw > 0 else 0.0)
            bw = 1.0 / per_byte
            if self._bw:
                # the simulated-tier throttle (encoded bytes/s) caps
                # every store job regardless of device speed; express
                # it in logical bytes like the rest of the tier
                bw = min(bw, self._bw / max(ratio, 1e-9))
            cap = (None if t.capacity_bytes is None
                   else int(t.capacity_bytes / max(ratio, 1e-9)))
            out.append(TierBandwidth(t.name, bw, cap))
        return out

    def close(self) -> None:
        """Drain queued I/O, stop and JOIN the worker threads, close the
        backend. Idempotent — a second close is a no-op, and returning
        guarantees no worker is still mid-write."""
        if self._closed:
            return
        self._closed = True
        self.wait_io()
        self._stop = True
        for _ in range(self._store_threads):
            self._store_q.put(None)
        for _ in range(self._load_threads):
            self._load_q.put(None)
        for t in self._threads:
            t.join()
        self._threads = []
        self.backend.close()
        if self._owns_pool:
            self.pool.close()

    def data_plane_stats(self) -> Dict[str, Any]:
        """One dict for the whole byte path: backend I/O (incl. host
        copies-per-byte) + aligned-pool reuse. This is where the
        'zero per-job large allocations' claim becomes a number."""
        return {
            "backend": self.backend.stats.as_dict(),
            "pool": self.pool.stats(),
        }

    # --------------------------------------------------------- workers

    def _with_retry(self, op: str, key, fn):
        """Run one backend call with bounded retry/backoff on transient
        failures; every outcome feeds the health monitor."""
        policy = self.retry
        attempt = 1
        while True:
            t0 = time.perf_counter()
            try:
                out = fn()
            except BaseException as e:
                self.health.record_failure(op, e,
                                           time.perf_counter() - t0)
                if (classify_io_error(e) != "transient"
                        or attempt >= policy.max_attempts):
                    raise
                if op == "write":
                    self.stats.store_retries += 1
                else:
                    self.stats.load_retries += 1
                if obs.is_enabled():
                    obs.count("resilience.retry")
                    obs.instant("resilience.retry", cat="resilience",
                                op=op, key=str(key), attempt=attempt,
                                error=repr(e))
                time.sleep(policy.delay(attempt))
                attempt += 1
            else:
                self.health.record_success(op,
                                           time.perf_counter() - t0)
                return out

    def _worker(self, q: "queue.Queue[Optional[_Job]]"):
        while True:
            job = q.get()
            if job is None:
                q.task_done()
                return
            try:
                self._run_job(job)
            except BaseException as e:
                # keep the worker alive and surface the failure at
                # fetch() instead of deadlocking a waiter forever
                job.error = e
                with job.cond:
                    job.state = DONE
                    job.cond.notify_all()
            finally:
                q.task_done()

    def _run_job(self, job: _Job):
        with job.cond:
            if job.state == CANCELED:
                job.cond.notify_all()
                return
            job.state = RUNNING
        t0 = time.perf_counter()
        if job.kind == "store":
            with obs.span("spool.store", cat="spool",
                          key=str(job.key)) as store_sp:
                arrays = [np.asarray(a) for a in job.arrays]
                # vectored store: the serde part list flows through the
                # codec container straight to backend.write_parts — with
                # the raw codec on a vectored backend the payload is
                # never joined or copied on the host at all
                with obs.span("codec.encode", cat="codec",
                              key=str(job.key)):
                    parts = encode_parts(serialize_parts(arrays),
                                         self.codec)
                nbytes = sum(len(p) if not isinstance(p, memoryview)
                             else p.nbytes for p in parts)
                # memoryview parts are re-readable, so a retry re-issues
                # the same vectored write without re-encoding
                self._with_retry(
                    "write", job.key,
                    lambda: self.backend.write_parts(str(job.key),
                                                     parts))
                dt = time.perf_counter() - t0
                if self._bw:
                    min_t = nbytes / self._bw
                    if dt < min_t:
                        time.sleep(min_t - dt)
                        dt = min_t
                store_sp.set(bytes=nbytes)
            self.stats.bytes_offloaded += nbytes
            self.stats.bytes_offloaded_logical += \
                sum(a.nbytes for a in arrays)
            self.stats.store_time += dt
            self.stats.num_stores += 1
            # registry entries must not outlive the buffers they track:
            # release BEFORE freeing, so a recycled address can never
            # hit a stale entry (and a still-live alias keeps its own
            # refcount on the entry)
            with job.cond:
                keys, job.reg_keys = job.reg_keys, ()
            for bkey in keys:
                self.registry.release_key(bkey)
            with job.cond:
                job.arrays = None          # drop the reference -> memory free
                job.state = DONE
                orphaned = job.orphaned
                job.cond.notify_all()
            self.tracker.free((job.key, "s"), tag=f"offloaded:{job.key}")
            if orphaned:
                # Dropped while we were writing. Spool keys are reused
                # across steps, so a NEW lease of this key may already
                # exist — deleting then would destroy its blob (a new
                # lease's write can only happen after its record is
                # inserted under _lock, so checking and deleting under
                # the same lock closes the race).
                with self._lock:
                    if job.key not in self._records:
                        self.backend.delete(str(job.key))
        else:
            key = str(job.key)
            # pooled load: size the blob, readinto a leased aligned
            # buffer, and deserialize zero-copy views over it. The
            # lease lives until the record is dropped (fetch copies on
            # demand when it materializes device arrays).
            lease = None
            with obs.span("spool.load", cat="spool", key=key) as load_sp:
                # RAM-backed stores hand the blob back by reference — a
                # pooled staging copy would only ADD a memcpy there
                nbytes = None if self.backend.zero_copy_read \
                    else self._with_retry(
                        "read", key, lambda: self.backend.size(key))
                if nbytes is not None and nbytes > 0:
                    lease = self.pool.acquire(nbytes)
                    try:
                        # the leased buffer is reused across attempts: a
                        # retried readinto just overwrites it
                        blob = self._with_retry(
                            "read", key,
                            lambda: self.backend.readinto(key, lease.mv))
                    except BaseException:
                        lease.release()
                        raise
                    nread = len(blob)
                else:
                    blob = self._with_retry(
                        "read", key, lambda: self.backend.read(key))
                    nread = len(blob)
                try:
                    with obs.span("codec.decode", cat="codec", key=key):
                        payload, aliases = unpack_aliased(blob)
                        # non-aliasing payloads (codec decodes) own
                        # fresh memory: leave the views writable so
                        # fetch's copy-on-demand doesn't pay a
                        # redundant memcpy
                        arrays = deserialize_leaves(payload, copy=False,
                                                    pinned=aliases)
                except BaseException:
                    if lease is not None:
                        lease.release()
                    raise
                if lease is not None and not aliases:
                    # decoding codecs hand back fresh memory: nothing
                    # borrows the pooled buffer, recycle it immediately
                    # instead of pinning it until drop()
                    lease.release()
                    lease = None
                dt = time.perf_counter() - t0
                if self._bw:
                    min_t = nread / self._bw
                    if dt < min_t:
                        time.sleep(min_t - dt)
                        dt = min_t
                load_sp.set(bytes=nread)
            self.stats.bytes_loaded += nread
            self.stats.load_time += dt
            self.stats.num_loads += 1
            with self._lock:
                rec = self._records.get(job.key)
                if rec is not None:
                    rec["loaded"] = arrays
                    rec["load_lease"] = lease
                elif lease is not None:
                    # record dropped while we were loading: nobody will
                    # ever release this lease through drop()
                    lease.release()
            with job.cond:
                job.state = DONE
                job.cond.notify_all()
