"""ActivationSpool — the tensor cache's I/O engine (paper §3.2–3.3.2).

Two FIFO thread pools (store / load), exactly the paper's structure:

  * offload(key, arrays): enqueue an async store; the spool holds the only
    strong reference to the arrays, so device memory is reclaimed the moment
    the write completes and the reference is dropped (pack-hook semantics).
  * prefetch(key): enqueue an async load (issued by the backward walker one
    module ahead, §3.3.2).
  * fetch(key): blocking acquire for backward. If the store is still queued
    or in flight, the in-memory reference is *forwarded* (§3.3.2) and the
    pending store is cancelled (adaptive-offloading feature 1, §3.3.3).
  * deduplication: arrays whose storage is already tracked (or registered as
    parameters) are recorded as aliases and not written twice (§3.3.1).

The "SSD" here is a real directory written through a real filesystem; an
optional bandwidth_limit simulates a slower tier for the ROK sweeps.
"""
from __future__ import annotations

import os
import pickle
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax

from repro.core.accounting import MemoryTracker
from repro.core.ids import TensorIdRegistry, _buffer_key

# job states
QUEUED, RUNNING, DONE, CANCELED = range(4)

# paper Algorithm 2 line 12: tensors smaller than 2**20 elements stay put
MIN_OFFLOAD_ELEMENTS = 2 ** 20


def _nbytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def _serialize(leaves: Sequence[np.ndarray]) -> bytes:
    metas, blobs = [], []
    for a in leaves:
        a = np.asarray(a)
        metas.append((a.shape, str(a.dtype)))
        blobs.append(a.tobytes())
    return pickle.dumps((metas, blobs), protocol=4)


def _deserialize(data: bytes):
    import ml_dtypes
    metas, blobs = pickle.loads(data)
    out = []
    for (shape, dt), blob in zip(metas, blobs):
        np_dt = np.dtype(getattr(ml_dtypes, dt, dt) if isinstance(dt, str)
                         else dt)
        out.append(np.frombuffer(blob, dtype=np_dt).reshape(shape))
    return out


@dataclass
class SpoolStats:
    bytes_offloaded: int = 0
    bytes_loaded: int = 0
    bytes_forwarded: int = 0
    bytes_deduped: int = 0
    stores_canceled: int = 0
    store_time: float = 0.0
    load_time: float = 0.0
    num_stores: int = 0
    num_loads: int = 0
    # time the *consumer* (backward pass) spent blocked waiting for a
    # load — the paper's "I/O latency exposed in the critical path".
    fetch_wait_time: float = 0.0

    @property
    def write_bandwidth(self) -> float:
        return self.bytes_offloaded / self.store_time \
            if self.store_time else float("inf")


class _Job:
    __slots__ = ("key", "arrays", "state", "cond", "path", "kind")

    def __init__(self, key, arrays, path, kind):
        self.key = key
        self.arrays = arrays
        self.state = QUEUED
        self.cond = threading.Condition()
        self.path = path
        self.kind = kind  # "store" | "load"


class ActivationSpool:
    def __init__(self, directory: str, *, store_threads: int = 4,
                 load_threads: int = 4,
                 bandwidth_limit: Optional[float] = None,
                 tracker: Optional[MemoryTracker] = None,
                 registry: Optional[TensorIdRegistry] = None,
                 min_offload_elements: int = MIN_OFFLOAD_ELEMENTS):
        self.dir = directory
        self.min_offload_elements = min_offload_elements
        os.makedirs(directory, exist_ok=True)
        self.tracker = tracker or MemoryTracker()
        self.registry = registry or TensorIdRegistry()
        self.stats = SpoolStats()
        self._bw = bandwidth_limit
        self._lock = threading.Lock()
        self._records: Dict[Any, Dict] = {}     # key -> record
        self._store_q: "queue.Queue[_Job]" = queue.Queue()
        self._load_q: "queue.Queue[_Job]" = queue.Queue()
        self._stop = False
        self._threads: List[threading.Thread] = []
        for i in range(store_threads):
            t = threading.Thread(target=self._worker,
                                 args=(self._store_q,), daemon=True,
                                 name=f"spool-store-{i}")
            t.start()
            self._threads.append(t)
        for i in range(load_threads):
            t = threading.Thread(target=self._worker,
                                 args=(self._load_q,), daemon=True,
                                 name=f"spool-load-{i}")
            t.start()
            self._threads.append(t)

    # ------------------------------------------------------------- API

    def register_parameters(self, params) -> int:
        return self.registry.register_parameters(params)

    def offload(self, key, tree) -> None:
        """Async-store a pytree of arrays under `key`. Small tensors and
        parameter/duplicate storages stay in memory (recorded, not
        written)."""
        leaves, treedef = jax.tree.flatten(tree)
        keep_idx, spool_idx, acquired = [], [], []
        kept_act_bytes = alias_bytes = 0
        for i, leaf in enumerate(leaves):
            if self.registry.is_parameter(leaf):
                keep_idx.append(i)
                continue
            if leaf.size < self.min_offload_elements:
                keep_idx.append(i)
                kept_act_bytes += leaf.size * leaf.dtype.itemsize
                continue
            tid, dup = self.registry.acquire(leaf)
            acquired.append(_buffer_key(leaf))
            if dup:
                keep_idx.append(i)
                alias_bytes += leaf.size * leaf.dtype.itemsize
            else:
                spool_idx.append(i)
        self.stats.bytes_deduped += alias_bytes

        spooled = [leaves[i] for i in spool_idx]
        nbytes = _nbytes(spooled)
        if kept_act_bytes:
            self.tracker.alloc((key, "k"), kept_act_bytes,
                               tag=f"kept_small:{key}")
        if not spool_idx:               # nothing above the threshold
            with self._lock:
                self._records[key] = {
                    "treedef": treedef,
                    "keep": {i: leaves[i] for i in keep_idx},
                    "spool_idx": [], "n_leaves": len(leaves), "job": None,
                    "nbytes": 0, "loaded": None, "load_job": None,
                    "acquired": acquired,
                }
            return
        self.tracker.alloc((key, "s"), nbytes, tag=f"residual:{key}")
        path = os.path.join(self.dir, f"{key}.act")
        job = _Job(key, spooled, path, "store")
        with self._lock:
            self._records[key] = {
                "treedef": treedef, "keep": {i: leaves[i] for i in keep_idx},
                "spool_idx": spool_idx, "n_leaves": len(leaves),
                "job": job, "nbytes": nbytes, "loaded": None,
                "load_job": None, "acquired": acquired,
            }
        self._store_q.put(job)

    def keep(self, key, tree) -> None:
        """Record a kept-in-memory pytree (adaptive offloading keeps the
        last modules on device, §3.3.3)."""
        leaves, treedef = jax.tree.flatten(tree)
        nbytes = sum(x.size * x.dtype.itemsize for x in leaves
                     if not self.registry.is_parameter(x))
        self.tracker.alloc((key, "k"), nbytes, tag=f"kept:{key}")
        with self._lock:
            self._records[key] = {
                "treedef": treedef, "keep": dict(enumerate(leaves)),
                "spool_idx": [], "n_leaves": len(leaves), "job": None,
                "nbytes": nbytes, "loaded": None, "load_job": None,
                "acquired": [],
            }

    def prefetch(self, key) -> None:
        with self._lock:
            rec = self._records.get(key)
            if rec is None or not rec["spool_idx"]:
                return
            job = rec["job"]
            with job.cond:
                if job.state in (QUEUED, RUNNING):
                    return          # still in memory; forwarding will hit
            if rec["load_job"] is not None or rec["loaded"] is not None:
                return
            lj = _Job(key, None, job.path, "load")
            rec["load_job"] = lj
        self._load_q.put(lj)

    def fetch(self, key):
        """Blocking: return the full pytree for backward."""
        with self._lock:
            rec = self._records.get(key)
            if rec is None:
                raise KeyError(key)
            job = rec["job"]
        spooled = None
        if job is not None and rec["spool_idx"]:
            with job.cond:
                if job.state in (QUEUED, RUNNING):
                    # ---- tensor forwarding (§3.3.2): the store has not
                    # finished; upgrade the in-flight reference. Cancel the
                    # write if it has not started (§3.3.3 feature 1).
                    spooled = job.arrays
                    self.stats.bytes_forwarded += _nbytes(spooled)
                    if job.state == QUEUED:
                        job.state = CANCELED
                        self.stats.stores_canceled += 1
                        # memory stays resident; keep tracker entry
            if spooled is None:
                with self._lock:
                    lj = rec["load_job"]
                if lj is None:
                    self.prefetch(key)
                    with self._lock:
                        lj = rec["load_job"]
                if lj is not None:
                    t_wait = time.perf_counter()
                    with lj.cond:
                        while lj.state not in (DONE, CANCELED):
                            lj.cond.wait()
                    self.stats.fetch_wait_time += (time.perf_counter()
                                                   - t_wait)
                with self._lock:
                    spooled = rec["loaded"]
                self.tracker.alloc((key, "s"), rec["nbytes"],
                                   tag=f"reloaded:{key}")
        leaves = [None] * rec["n_leaves"]
        for i, leaf in rec["keep"].items():
            leaves[i] = leaf
        if rec["spool_idx"]:
            for i, leaf in zip(rec["spool_idx"], spooled):
                leaves[i] = jax.numpy.asarray(leaf) \
                    if isinstance(leaf, np.ndarray) else leaf
        return jax.tree.unflatten(rec["treedef"], leaves)

    def drop(self, key) -> None:
        """Consume a record after backward: free memory + delete the file."""
        with self._lock:
            rec = self._records.pop(key, None)
        if rec is None:
            return
        for bkey in rec["acquired"]:
            self.registry.release_key(bkey)
        self.tracker.free((key, "s"), tag=f"consumed:{key}")
        self.tracker.free((key, "k"), tag=f"consumed:{key}")
        try:
            os.unlink(os.path.join(self.dir, f"{key}.act"))
        except OSError:
            pass

    def wait_io(self) -> None:
        """Barrier: wait for all queued stores (paper Algorithm 1 line 15)."""
        self._store_q.join()
        self._load_q.join()

    def close(self) -> None:
        self.wait_io()
        self._stop = True
        for _ in self._threads:
            self._store_q.put(None)
            self._load_q.put(None)

    # --------------------------------------------------------- workers

    def _worker(self, q: "queue.Queue[Optional[_Job]]"):
        while True:
            job = q.get()
            if job is None:
                q.task_done()
                return
            try:
                self._run_job(job)
            finally:
                q.task_done()

    def _run_job(self, job: _Job):
        with job.cond:
            if job.state == CANCELED:
                job.cond.notify_all()
                return
            job.state = RUNNING
        t0 = time.perf_counter()
        if job.kind == "store":
            arrays = [np.asarray(a) for a in job.arrays]
            data = _serialize(arrays)
            with open(job.path, "wb") as f:
                f.write(data)
            dt = time.perf_counter() - t0
            if self._bw:
                min_t = len(data) / self._bw
                if dt < min_t:
                    time.sleep(min_t - dt)
                    dt = min_t
            self.stats.bytes_offloaded += len(data)
            self.stats.store_time += dt
            self.stats.num_stores += 1
            with job.cond:
                job.arrays = None          # drop the reference -> memory free
                job.state = DONE
                job.cond.notify_all()
            self.tracker.free((job.key, "s"), tag=f"offloaded:{job.key}")
        else:
            with open(job.path, "rb") as f:
                data = f.read()
            arrays = _deserialize(data)
            dt = time.perf_counter() - t0
            if self._bw:
                min_t = len(data) / self._bw
                if dt < min_t:
                    time.sleep(min_t - dt)
                    dt = min_t
            self.stats.bytes_loaded += len(data)
            self.stats.load_time += dt
            self.stats.num_loads += 1
            with self._lock:
                rec = self._records.get(job.key)
                if rec is not None:
                    rec["loaded"] = arrays
            with job.cond:
                job.state = DONE
                job.cond.notify_all()
