"""Stable tensor identifiers and the deduplication registry (paper §3.3.1).

The paper tags each tensor's underlying storage with a first-seen timestamp
because PyTorch's id() is address-based and addresses get recycled after
garbage collection. The JAX analogue: a jax.Array's device buffer pointer is
stable while the buffer is alive but recyclable after it dies, so TensorIds
combines (buffer pointer, shape, dtype) with a monotonically increasing
first-seen sequence number kept in a registry keyed by live buffers.

Parameters are registered up front and excluded from offloading (the
transpose-consistency concern of §3.3.1 does not arise in JAX — a jitted
step re-derives views each call — but shared buffers, e.g. the vision
encoder's K/V reused by every cross-attention layer, hit the dedup path).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Any, Dict, Optional, Set, Tuple

import numpy as np


def _buffer_key(arr) -> Tuple[int, Tuple[int, ...], str]:
    """Identity key of an array's storage (pointer, shape, dtype)."""
    if hasattr(arr, "unsafe_buffer_pointer"):
        try:
            ptr = arr.unsafe_buffer_pointer()
        except Exception:
            ptr = id(arr)
    else:
        a = np.asarray(arr)
        ptr = a.__array_interface__["data"][0]
    return (ptr, tuple(arr.shape), str(arr.dtype))


@dataclass
class TensorRecord:
    tid: int
    nbytes: int
    refcount: int = 1


class TensorIdRegistry:
    """Assigns stable ids; detects duplicates among *live* arrays.

    `acquire(arr)` returns (tid, is_duplicate). The registry holds no
    reference to the array; the caller must `release(tid)` when its use of
    the tensor ends so the key can be recycled safely.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._next = 0
        self._by_key: Dict[Tuple, TensorRecord] = {}
        self._params: Set[Tuple] = set()

    def register_parameters(self, tree) -> int:
        """Exclude every leaf of a params pytree from offloading."""
        import jax
        n = 0
        with self._lock:
            for leaf in jax.tree.leaves(tree):
                self._params.add(_buffer_key(leaf))
                n += 1
        return n

    def is_parameter(self, arr) -> bool:
        with self._lock:
            return _buffer_key(arr) in self._params

    def acquire(self, arr) -> Tuple[int, bool]:
        key = _buffer_key(arr)
        with self._lock:
            rec = self._by_key.get(key)
            if rec is not None:
                rec.refcount += 1
                return rec.tid, True
            tid = self._next
            self._next += 1
            self._by_key[key] = TensorRecord(tid, int(np.prod(arr.shape))
                                             * arr.dtype.itemsize)
            return tid, False

    def release(self, arr) -> None:
        self.release_key(_buffer_key(arr))

    def release_key(self, key: Tuple) -> None:
        with self._lock:
            rec = self._by_key.get(key)
            if rec is None:
                return
            rec.refcount -= 1
            if rec.refcount <= 0:
                del self._by_key[key]

    @property
    def live_count(self) -> int:
        with self._lock:
            return len(self._by_key)
