"""Offload policies: first-class placement objects for the staged engine.

The seed API selected residual placement with a `strategy: str` plus an
`adaptive: bool` flag threaded through `StagedTrainer`. That flag soup is
replaced by `OffloadPolicy` objects — the swappable scheduling seam the
interoperability papers (GreedySnake, 10Cache) argue for: the execution
engine asks the policy two questions and never interprets strings.

    should_offload(stage, profile)   -> spool this stage's residuals?
    on_profile(profiles, bandwidths) -> digest the profiling step
                                        (AdaptivePolicy: compute the plan)

Policies:
  KeepPolicy       residuals stay on device (the ROK "K" axis)
  SpoolPolicy      offload every eligible stage unconditionally ("O")
  RecomputePolicy  layerwise recomputation; only module inputs kept ("R")
  AdaptivePolicy   paper §3.3.3: profile step 0, then offload only the
                   prefix the measured store bandwidth can hide

`resolve_policy` maps the legacy surface (strategy strings, adaptive
flag) onto these objects so seed call shapes keep working.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple, Union

from repro.core.adaptive import (BWD_FACTOR, BandwidthLike, ModuleProfile,
                                 OffloadPlan, TierBandwidth, plan_offload)

#: stage roles whose backward can be recomputed from the module input
RECOMPUTABLE_ROLES = ("layer", "enc_layer")


def _scale_bandwidths(bw: BandwidthLike, scale: float) -> BandwidthLike:
    """Bandwidths as the planner should see them after a health event:
    every tier's write rate scaled by `scale` (0.0 = device gone)."""
    if isinstance(bw, (int, float)):
        return float(bw) * scale
    return [TierBandwidth(t.name, t.write_bw * scale, t.capacity_bytes)
            for t in bw]


def _is_decoder_layer(name: str) -> bool:
    """Staged-engine stage names: decoder layers are 'seg{si}_l{rep}'."""
    return name.startswith("seg") and "_l" in name


@dataclass(frozen=True)
class JitOffloadPlan:
    """A profiled plan translated for the jit engine: per-decoder-layer
    keep/offload choices for the repro.core.hooks spool path, derived
    from the same `on_profile` data that drives the staged engine.

    `spool_stages[i]` is True when decoder layer i's residuals should
    stream through the spool; False keeps them on device (matching the
    staged AdaptivePolicy's keep-set). `activation_policy` is what
    `RunSettings.activation_policy` should be — "spool" while any layer
    offloads, else "keep" (nothing to stream)."""

    spool_stages: Tuple[bool, ...]
    activation_policy: str                     # "spool" | "keep"
    required_bw: float
    write_bw: float
    #: fraction of each layer's profiled bytes the planned shard hands
    #: the spool (1.0 = unsharded; see local_shard_fraction)
    shard_fraction: float = 1.0

    def apply(self, settings) -> "RunSettings":  # noqa: F821
        """The same RunSettings with this plan's placement choices."""
        import dataclasses
        return dataclasses.replace(
            settings,
            activation_policy=self.activation_policy,
            spool_stages=(self.spool_stages
                          if self.activation_policy == "spool" else None))


class OffloadPolicy:
    """Base policy: decides, per stage, where residuals live.

    Subclasses override `should_offload` (and, for profile-driven
    policies, `wants_profile` + `on_profile`). `strategy` is the legacy
    string the policy corresponds to — kept so reports, benchmarks and
    CLI output stay stable across the API redesign.
    """

    strategy = "offload"

    #: engine runs a profiling step (warm re-run + wait_io + calibrate)
    #: while this is True
    wants_profile = False

    plan: Optional[OffloadPlan] = None

    def recomputes(self, role: str) -> bool:
        """True if this stage's backward should re-run forward instead of
        saving residuals."""
        return False

    def should_offload(self, stage: int,
                       profile: Optional[ModuleProfile] = None) -> bool:
        raise NotImplementedError

    def on_profile(self, profiles: Sequence[ModuleProfile],
                   bandwidths: BandwidthLike) -> Optional[OffloadPlan]:
        """Digest the profiling step. Returns the plan (or None when the
        policy is static)."""
        return None

    def __repr__(self):
        return f"{type(self).__name__}()"


class KeepPolicy(OffloadPolicy):
    """All residuals stay in device memory (tracked for the footprint
    curve, never written)."""

    strategy = "keep"

    def should_offload(self, stage, profile=None) -> bool:
        return False


class SpoolPolicy(OffloadPolicy):
    """Unconditional TBA: every eligible stage's residuals go to the
    spool (the non-adaptive `strategy="offload", adaptive=False` form)."""

    strategy = "offload"

    def should_offload(self, stage, profile=None) -> bool:
        return True


class RecomputePolicy(OffloadPolicy):
    """Layerwise full recomputation: layer stages keep only their input
    and re-run forward during backward; non-layer stages keep residuals
    on device."""

    strategy = "recompute"

    def recomputes(self, role: str) -> bool:
        return role in RECOMPUTABLE_ROLES

    def should_offload(self, stage, profile=None) -> bool:
        return False


class AdaptivePolicy(OffloadPolicy):
    """Paper §3.3.3: offload everything during the profiling step, then
    plan the largest offloaded prefix whose transfer deadline the
    measured (per-tier) store bandwidth can hold."""

    strategy = "offload"

    def __init__(self, *, bwd_factor: float = BWD_FACTOR,
                 always_keep_last: bool = True,
                 opt_bytes_per_step: int = 0):
        self.bwd_factor = bwd_factor
        self.always_keep_last = always_keep_last
        # opt-overlap moment traffic sharing the write path (see
        # price_opt_io); 0 = no optimizer I/O competing for bandwidth
        self.opt_bytes_per_step = int(opt_bytes_per_step)
        self.plan = None
        self.profiles: Optional[List[ModuleProfile]] = None
        self.bandwidths: Optional[BandwidthLike] = None
        self.cache_manager = None
        # mid-run re-plans triggered by backend health events
        self.replans = 0
        self.last_health_event = None
        import threading as _threading
        self._replan_lock = _threading.Lock()

    def attach_cache_manager(self, manager) -> None:
        """Connect a `repro.cache.CacheManager` backend: after the
        profiling step, the policy converts its measured step timing
        into the manager's per-class reuse distances, so tier placement
        and the offload plan derive from the same profile."""
        self.cache_manager = manager

    def price_opt_io(self, bytes_per_step: int) -> None:
        """Account for the opt-overlap bridge's moment traffic: the
        bridge stages ~`bytes_per_step` of optimizer state through the
        same write path every step, so the activation deadline test must
        plan against the leftover bandwidth, not the raw tier rate.
        Re-plans immediately when a profile is already in hand."""
        with self._replan_lock:
            self.opt_bytes_per_step = int(bytes_per_step)
            if self.profiles is None or self.bandwidths is None:
                return      # priced at on_profile time instead
            self.plan = plan_offload(
                self.profiles, self._priced(self.bandwidths),
                bwd_factor=self.bwd_factor,
                always_keep_last=self.always_keep_last)
            self.replans += 1

    def _priced(self, bandwidths: BandwidthLike) -> BandwidthLike:
        """`bandwidths` minus the opt-state write rate. The moment
        writer moves opt_bytes_per_step over one step, so it claims
        bytes/t_step of write bandwidth; floor at 1 B/s so a saturated
        tier degrades the plan instead of crashing the divide."""
        if self.opt_bytes_per_step <= 0 or not self.profiles:
            return bandwidths
        t_step = sum(p.fwd_time for p in self.profiles) \
            * (1.0 + self.bwd_factor)
        if t_step <= 0:
            return bandwidths
        rate = self.opt_bytes_per_step / t_step
        if isinstance(bandwidths, (int, float)):
            return max(float(bandwidths) - rate, 1.0)
        return [TierBandwidth(t.name, max(t.write_bw - rate, 1.0),
                              t.capacity_bytes)
                for t in bandwidths]

    def attach_health(self, health) -> None:
        """Subscribe to a `repro.resilience.BackendHealth` monitor: on
        a degrade/failing/recovered transition the policy re-plans
        against the bandwidth the backend can still deliver (failing →
        nothing offloads; stages degrade to on-device residuals, and
        already-offloaded ones ride the engines' recompute fallback).
        Tier demotion inside a managed backend needs no action here —
        the `CacheManager.fallback_to_upper` path already re-homes
        blobs when the SSD tier errors, and its fallback counters ride
        the cache_* metrics block."""
        health.subscribe(self.on_health_event)

    def on_health_event(self, event) -> None:
        """Re-plan mid-run from an I/O-worker thread. Cheap and
        lock-protected: compute a new plan from the retained profile
        with the degraded bandwidth, then swap the plan reference (the
        engine reads it between stages)."""
        from repro import obs
        with self._replan_lock:
            self.last_health_event = event
            if self.profiles is None or self.bandwidths is None:
                return      # no profile yet: nothing to re-plan from
            if event.kind == "failing":
                scale = 0.0  # device gone: stop offloading entirely
            elif event.kind == "degraded":
                scale = 1.0 / max(event.latency_ratio, 1.0)
            else:            # recovered
                scale = 1.0
            self.plan = plan_offload(
                self.profiles,
                self._priced(_scale_bandwidths(self.bandwidths, scale)),
                bwd_factor=self.bwd_factor,
                always_keep_last=self.always_keep_last)
            self.replans += 1
            n_off = sum(self.plan.offload)
        if obs.is_enabled():
            obs.count("resilience.replan")
            obs.instant("resilience.replan", cat="resilience",
                        trigger=event.kind, op=event.op,
                        bw_scale=round(scale, 4),
                        stages_offloaded=n_off,
                        latency_ratio=round(event.latency_ratio, 3))

    @property
    def wants_profile(self) -> bool:
        return self.plan is None

    def should_offload(self, stage, profile=None) -> bool:
        if self.plan is None:
            return True      # profiling step offloads everything it can
        return self.plan.offload[stage]

    def on_profile(self, profiles, bandwidths) -> OffloadPlan:
        self.profiles = list(profiles)
        self.bandwidths = bandwidths
        self.plan = plan_offload(self.profiles, self._priced(bandwidths),
                                 bwd_factor=self.bwd_factor,
                                 always_keep_last=self.always_keep_last)
        if self.cache_manager is not None:
            # Measured reuse distances in seconds, one consistent unit:
            # a residual's mean wait until backward is ~half a step, an
            # optimizer moment waits a full step (step parity), and a
            # parked KV sequence is rescaled to keep its default 3x rank
            # (serving measures its own recency when it runs).
            t_step = sum(p.fwd_time for p in self.profiles) \
                * (1.0 + self.bwd_factor)
            if t_step > 0:
                self.cache_manager.hint_class_distance(
                    "activation", 0.5 * t_step)
                self.cache_manager.hint_class_distance(
                    "opt_state", t_step)
                self.cache_manager.hint_class_distance(
                    "kv_page", 3.0 * t_step)
        return self.plan

    def plan_for_jit(self, *, shard_fraction: float = 1.0) \
            -> JitOffloadPlan:
        """The profiled plan as per-decoder-layer placement for the jit
        engine's hook path — one policy object, profiled once (on either
        engine), drives both step-execution modes.

        `shard_fraction` scales the profiled per-layer byte estimates
        before planning: on an SPMD mesh every shard spools only its
        local residual block (batch-dim sharding over the dp axes), so
        the deadline feasibility test should judge local bytes, not the
        single-device profile's global ones. Use `local_shard_fraction`
        for the fraction a given mesh implies; a smaller fraction can
        only offload MORE layers."""
        if self.plan is None or self.profiles is None:
            raise RuntimeError(
                "plan_for_jit() needs a profiling step first: run one "
                "staged step with this policy (on_profile) before "
                "translating the plan for the jit engine")
        if not 0.0 < shard_fraction <= 1.0:
            raise ValueError(
                f"shard_fraction must be in (0, 1], got {shard_fraction}")
        plan = self.plan
        if shard_fraction != 1.0:
            scaled = [ModuleProfile(p.name,
                                    int(round(p.bytes * shard_fraction)),
                                    p.fwd_time)
                      for p in self.profiles]
            plan = plan_offload(scaled, self._priced(self.bandwidths),
                                bwd_factor=self.bwd_factor,
                                always_keep_last=self.always_keep_last)
        mask = tuple(bool(off)
                     for prof, off in zip(self.profiles, plan.offload)
                     if _is_decoder_layer(prof.name))
        return JitOffloadPlan(
            spool_stages=mask,
            activation_policy="spool" if any(mask) else "keep",
            required_bw=plan.required_bw,
            write_bw=plan.write_bw,
            shard_fraction=shard_fraction)

    def __repr__(self):
        return (f"AdaptivePolicy(bwd_factor={self.bwd_factor}, "
                f"planned={self.plan is not None})")


def local_shard_fraction(mesh, dp_axes=("data",)) -> float:
    """Fraction of a hooked layer's residual bytes ONE shard hands the
    spool under the sharded offload hooks: the leading (batch) dim
    splits over the dp axes, so each shard holds 1/dp_size of a
    batch-major residual (tp slices shrink per-device bytes further but
    also multiply writers, leaving per-host totals unchanged — dp is
    the term that scales a shard's transfer deadline)."""
    if mesh is None:
        return 1.0
    n = 1
    for a in (dp_axes or ()):
        if a in mesh.shape:
            n *= int(mesh.shape[a])
    return 1.0 / max(n, 1)


#: what the legacy strategy strings resolve to
_STRATEGIES = ("keep", "offload", "recompute", "adaptive", "spool")


def resolve_policy(policy: Union[OffloadPolicy, str, None] = None, *,
                   strategy: Optional[str] = None,
                   adaptive: Optional[bool] = None) -> OffloadPolicy:
    """One resolver for every call shape.

    New API: pass an `OffloadPolicy` (or its name: "keep" / "offload" /
    "recompute" / "adaptive" / "spool"). Legacy shim: `strategy=` +
    `adaptive=` keyword pair, with the seed defaults (offload,
    adaptive=True) when everything is None. A bare "offload" keeps the
    seed meaning — adaptive unless `adaptive=False` is passed.
    """
    if policy is not None and (strategy is not None or adaptive is not None):
        raise ValueError("pass either policy= or the legacy "
                         "strategy=/adaptive= pair, not both")
    if isinstance(policy, OffloadPolicy):
        return policy
    name = policy if policy is not None else strategy
    if name is None:
        name = "offload"
    if not isinstance(name, str) or name not in _STRATEGIES:
        raise ValueError(f"unknown offload policy {name!r}; expected an "
                         f"OffloadPolicy or one of {_STRATEGIES}")
    if name == "keep":
        return KeepPolicy()
    if name == "recompute":
        return RecomputePolicy()
    if name == "spool":
        return SpoolPolicy()
    if name == "adaptive":
        return AdaptivePolicy()
    # "offload": seed semantics — adaptive unless explicitly disabled
    return SpoolPolicy() if adaptive is False else AdaptivePolicy()
