"""Exact activation-memory accounting (paper Fig. 7 timelines).

On this CPU container we cannot read an HBM gauge, but we do not need to:
the metric the paper plots is the *activation* footprint, which is fully
determined by which saved-residual tensors are live. The tracker records
every alloc/free with a timestamp, yielding the footprint timeline, its
peak, and the begin-of-backward footprint the paper highlights (45% / 25%
reductions in Fig. 7).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class MemoryEvent:
    t: float
    total: int
    tag: str


class MemoryTracker:
    def __init__(self):
        self._lock = threading.Lock()
        self._live: Dict[Tuple, int] = {}
        self._total = 0
        self._peak = 0
        self.events: List[MemoryEvent] = []
        self._t0 = time.perf_counter()
        self.marks: Dict[str, float] = {}

    def _record(self, tag):
        self.events.append(MemoryEvent(time.perf_counter() - self._t0,
                                       self._total, tag))
        self._peak = max(self._peak, self._total)

    def alloc(self, key, nbytes: int, tag: str = "") -> None:
        with self._lock:
            if key in self._live:
                return
            self._live[key] = nbytes
            self._total += nbytes
            self._record(tag or f"alloc:{key}")

    def free(self, key, tag: str = "") -> None:
        with self._lock:
            nbytes = self._live.pop(key, None)
            if nbytes is None:
                return
            self._total -= nbytes
            self._record(tag or f"free:{key}")

    def mark(self, name: str) -> None:
        """Named timeline marker (e.g. 'backward_begin')."""
        with self._lock:
            self.marks[name] = time.perf_counter() - self._t0

    @property
    def peak(self) -> int:
        with self._lock:
            return self._peak

    @property
    def current(self) -> int:
        with self._lock:
            return self._total

    def footprint_at(self, t: float) -> int:
        """Footprint at timeline time t (step function evaluation)."""
        with self._lock:
            total = 0
            for ev in self.events:
                if ev.t > t:
                    break
                total = ev.total
            return total

    def timeline(self) -> List[Tuple[float, int]]:
        with self._lock:
            return [(e.t, e.total) for e in self.events]

    def reset_peak(self) -> None:
        with self._lock:
            self._peak = self._total
