"""Recompute–Offload–Keep (ROK) curve (paper §4.3, Fig. 11).

Each training run is a point: x = activations memory peak, y = model
throughput. Model throughput is the paper's definition (Megatron [77]):
the *algorithmic* FLOPs of the training step — independent of whether
activations were recomputed — divided by the measured step time.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass
from typing import Dict, List, Optional, Sequence


@dataclass(frozen=True)
class RokPoint:
    strategy: str            # "keep" | "offload" | "recompute"
    batch_size: int
    peak_activation_bytes: int
    step_time_s: float
    model_flops: float       # algorithmic FLOPs per step (6*N*tokens)

    @property
    def throughput_flops_per_s(self) -> float:
        return self.model_flops / self.step_time_s

    def as_dict(self) -> Dict:
        d = asdict(self)
        d["throughput_flops_per_s"] = self.throughput_flops_per_s
        return d


def model_flops_per_step(n_params: int, tokens: int) -> float:
    """6ND — forward (2ND) + backward (4ND), recompute NOT counted
    (model throughput is hardware/software-agnostic, §4.3)."""
    return 6.0 * float(n_params) * float(tokens)


def dominates(a: RokPoint, b: RokPoint) -> bool:
    """a dominates b: no more memory AND no less throughput."""
    return (a.peak_activation_bytes <= b.peak_activation_bytes
            and a.throughput_flops_per_s >= b.throughput_flops_per_s
            and (a.peak_activation_bytes < b.peak_activation_bytes
                 or a.throughput_flops_per_s > b.throughput_flops_per_s))


def pareto_front(points: Sequence[RokPoint]) -> List[RokPoint]:
    front = [p for p in points
             if not any(dominates(q, p) for q in points if q is not p)]
    return sorted(front, key=lambda p: p.peak_activation_bytes)


def save_curve(points: Sequence[RokPoint], path: str) -> None:
    with open(path, "w") as f:
        json.dump([p.as_dict() for p in points], f, indent=1)


def load_curve(path: str) -> List[RokPoint]:
    with open(path) as f:
        raw = json.load(f)
    return [RokPoint(r["strategy"], r["batch_size"],
                     r["peak_activation_bytes"], r["step_time_s"],
                     r["model_flops"]) for r in raw]
