"""Raw host callback primitive for the spool hooks (`repro.core.hostcb`).

`jax.experimental.io_callback`'s runtime impl wraps every operand in a
`jax.device_put(..., cpu_device0)` before invoking the python function.
On a multi-device CPU mesh that is a liveness hazard: the device_put of
a large operand takes jaxlib's *async* copy path, whose completion task
runs on the client's shared worker pool — the same pool the mesh's
collectives and intra-op work saturate. A callback that then forces the
array (`np.asarray`) parks its DEVICE thread on the pending event while
the other devices park at a collective waiting for this device: a
cross-device deadlock that reproduces reliably with 8 forced host
devices on a small container (and is timing-dependent everywhere else).

`raw_io_callback` is a ~60-line primitive that reuses jax's own
callback machinery — the same `_IOEffect` (so jit/scan treat it exactly
like `io_callback`: not DCE'd, allowed in control flow, droppable only
when result-free, which our token threading already prevents) and the
same MANUAL op-sharding under shard_map (one callback per device) — but
lowers through `mlir.emit_python_callback` directly, so the python
function receives the raw numpy VIEWS of the XLA operand buffers, no
jax arrays, no device_put, no events. Nothing in the callback can touch
the jax runtime, so nothing in the callback can deadlock it.

Contract (stricter than io_callback — the device_put was also a copy):

  * operand views are only valid DURING the call — the callback must
    copy anything it keeps (`np.array(x, copy=True)` is a plain memcpy);
  * results must be numpy arrays matching the declared ShapeDtypeStructs;
  * no vmap / differentiation through the primitive (the hooks never do
    either — it lives inside a custom_vjp's fwd/bwd).

Falls back to `jax.experimental.io_callback` when the jax internals it
borrows move (import errors are caught), trading the liveness fix for
compatibility; `RAW_CALLBACK_AVAILABLE` says which one callers got.
"""
from __future__ import annotations

from typing import Any, Callable, Sequence, Tuple

import numpy as np

import jax

RAW_CALLBACK_AVAILABLE = False

try:
    import inspect

    from jax._src import core as _jcore
    from jax._src.callback import _callback_op_sharding as _op_sharding
    from jax._src.callback import _IOEffect
    from jax._src.interpreters import mlir as _mlir

    # Guard against call-signature drift, not just import-time moves:
    # both borrowed internals have changed shape across jax 0.4.x, and
    # a mismatch would otherwise crash at lowering time instead of
    # falling back. (A full smoke lower would need a jax backend, which
    # module import must not initialize.)
    _ep = list(inspect.signature(_mlir.emit_python_callback).parameters)
    if _ep[:6] != ["ctx", "callback", "token", "operands",
                   "operand_avals", "result_avals"] \
            or "has_side_effect" not in _ep or "sharding" not in _ep:
        raise ImportError("emit_python_callback signature drifted")
    if len(inspect.signature(_op_sharding).parameters) != 2:
        raise ImportError("_callback_op_sharding signature drifted")

    raw_callback_p = _jcore.Primitive("repro_raw_host_callback")
    raw_callback_p.multiple_results = True

    @raw_callback_p.def_effectful_abstract_eval
    def _raw_callback_abstract_eval(*avals, callback, result_avals):
        del avals, callback
        return result_avals, {_IOEffect}

    def _raw_callback_lowering(ctx, *args, callback, result_avals):
        del result_avals

        def _wrapped(*flat_args):
            out = callback(*flat_args)
            return (tuple(out) if isinstance(out, (tuple, list))
                    else (out,))

        op_sharding = _op_sharding(ctx.module_context.axis_context, None)
        result, _, _ = _mlir.emit_python_callback(
            ctx, _wrapped, None, list(args), ctx.avals_in, ctx.avals_out,
            has_side_effect=True, sharding=op_sharding)
        return result

    _mlir.register_lowering(raw_callback_p, _raw_callback_lowering)
    RAW_CALLBACK_AVAILABLE = True
except Exception:  # pragma: no cover - future jax moved the internals
    pass


def raw_io_callback(callback: Callable[..., Any], result_shape_dtypes,
                    *args) -> Any:
    """`io_callback` minus the arg device_put (see module docstring).

    `result_shape_dtypes` is a flat sequence (or single) of
    ShapeDtypeStructs; returns a flat tuple (or single array). The
    callback receives numpy views valid only during the call.
    """
    single = hasattr(result_shape_dtypes, "shape")
    sds: Tuple = ((result_shape_dtypes,) if single
                  else tuple(result_shape_dtypes))

    # span per host invocation, named after the hook function — these
    # run on XLA's host-callback threads, so they are what ties the
    # device schedule to spool activity on the trace timeline
    from repro import obs
    span_name = "hostcb." + getattr(callback, "__name__", "cb")

    def traced_callback(*flat_args):
        with obs.span(span_name, cat="hostcb"):
            return callback(*flat_args)

    if not RAW_CALLBACK_AVAILABLE:  # pragma: no cover - fallback path
        from jax.experimental import io_callback
        return io_callback(traced_callback, result_shape_dtypes, *args)
    result_avals = tuple(
        _jcore.ShapedArray(tuple(s.shape), s.dtype) for s in sds)
    out = raw_callback_p.bind(*args, callback=traced_callback,
                              result_avals=result_avals)
    return out[0] if single else tuple(out)
