"""Adaptive offloading planner (paper §3.3.3, Figure 8).

Profile the first training step to collect, per module (here: per scanned
super-layer), the residual bytes and forward compute time, plus the measured
spool write bandwidth. Then pick the *last module to offload* m as the
largest m such that the aggregate transfer deadline holds:

    bytes(m)   = sum_{j<m} store_j + (store_m + load_m)
    deadline(m)= t_fwd_total - t_fwd_end(m)            (rest of forward)
                 + bwd_factor * sum_{j>m} t_fwd_j      (bwd of later modules)
    required_bw(m) = bytes(m) / deadline(m)  <=  write_bandwidth

with the paper's estimate bwd_factor = 2 (backward ~ 2x forward). Modules
after m are kept in GPU memory — they are the first ones needed when the
backward pass begins, so offloading them cannot reduce the peak (offloading
tensors after the peak is not helpful) and only delays memory reclaim.

Tiered storage (repro.io): instead of a single scalar, the planner also
accepts a sequence of `TierBandwidth` entries — the measured write
bandwidth and byte capacity of each storage tier, fastest first (e.g.
host-RAM budget over an SSD array). The feasibility test then compares
against `effective_write_bandwidth`, the byte-weighted aggregate rate of
filling the tiers in order with the candidate plan's traffic: a plan
whose bytes fit the RAM tier is judged at RAM speed; one that spills is
judged at the blended rate.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Union

BWD_FACTOR = 2.0


@dataclass(frozen=True)
class TierBandwidth:
    """One storage tier as the planner sees it: measured write bandwidth
    (bytes/s) and capacity (None = unbounded, e.g. a filesystem)."""
    name: str
    write_bw: float
    capacity_bytes: Optional[int] = None


#: what plan_offload accepts as its bandwidth argument
BandwidthLike = Union[float, Sequence[TierBandwidth]]


def effective_write_bandwidth(tiers: Sequence[TierBandwidth],
                              total_bytes: float) -> float:
    """Aggregate write bandwidth for `total_bytes` filling `tiers` in
    order. Bytes overflowing every finite capacity land on the last
    tier (treated as unbounded — there is always a bottom of the
    hierarchy)."""
    if not tiers:
        return 0.0
    if total_bytes <= 0:
        return tiers[0].write_bw
    remaining = float(total_bytes)
    t = 0.0
    for i, tier in enumerate(tiers):
        last = i == len(tiers) - 1
        cap = (remaining if (last or tier.capacity_bytes is None)
               else min(tier.capacity_bytes, remaining))
        if cap <= 0:
            continue
        if tier.write_bw <= 0:
            return 0.0
        t += cap / tier.write_bw
        remaining -= cap
        if remaining <= 0:
            break
    if t <= 0:
        return float("inf")
    return total_bytes / t


@dataclass(frozen=True)
class ModuleProfile:
    name: str
    bytes: int          # residual bytes this module would offload
    fwd_time: float     # seconds of forward compute


@dataclass(frozen=True)
class OffloadPlan:
    offload: List[bool]          # per module
    required_bw: float           # bytes/s needed for the chosen plan
    write_bw: float              # measured bytes/s
    last_offloaded: int          # index m (-1: nothing offloaded)

    @property
    def num_offloaded(self) -> int:
        return sum(self.offload)


def required_bandwidth(profiles: Sequence[ModuleProfile], m: int,
                       bwd_factor: float = BWD_FACTOR) -> float:
    """Bandwidth needed if modules 0..m (inclusive) are offloaded."""
    if m < 0:
        return 0.0
    bytes_needed = plan_bytes(profiles, m)
    t_fwd_rest = sum(p.fwd_time for p in profiles[m + 1:])
    t_bwd_later = bwd_factor * sum(p.fwd_time for p in profiles[m + 1:])
    # transfers for modules 0..m can also use the time while they execute:
    t_fwd_own = sum(p.fwd_time for p in profiles[1:m + 1])
    deadline = t_fwd_own + t_fwd_rest + t_bwd_later
    if deadline <= 0:
        return float("inf")
    return bytes_needed / deadline


def plan_bytes(profiles: Sequence[ModuleProfile], m: int) -> int:
    """Total transfer bytes if modules 0..m are offloaded (stores for
    0..m plus the reload of module m before its backward)."""
    if m < 0:
        return 0
    return sum(p.bytes for p in profiles[:m]) + 2 * profiles[m].bytes


def _bw_for(write_bw: BandwidthLike, nbytes: float) -> float:
    if isinstance(write_bw, (int, float)):
        return float(write_bw)
    return effective_write_bandwidth(write_bw, nbytes)


def plan_offload(profiles: Sequence[ModuleProfile],
                 write_bw: BandwidthLike,
                 bwd_factor: float = BWD_FACTOR,
                 always_keep_last: bool = True) -> OffloadPlan:
    """Choose the largest feasible last-offloaded module (paper's rule).

    `write_bw` is a scalar bytes/s, or a fastest-first sequence of
    `TierBandwidth` (repro.io tiered backends): each candidate plan is
    judged against the effective bandwidth of its own byte volume."""
    n = len(profiles)
    hi = n - 2 if always_keep_last else n - 1  # last module kept (§3.2 ④)
    best = -1
    for m in range(hi, -2, -1):
        if m < 0:
            break
        avail = _bw_for(write_bw, plan_bytes(profiles, m))
        if required_bandwidth(profiles, m, bwd_factor) <= avail:
            best = m
            break
    offload = [i <= best for i in range(n)]
    return OffloadPlan(
        offload=offload,
        required_bw=required_bandwidth(profiles, best, bwd_factor),
        write_bw=_bw_for(write_bw, plan_bytes(profiles, best)),
        last_offloaded=best,
    )
