"""Adaptive offloading planner (paper §3.3.3, Figure 8).

Profile the first training step to collect, per module (here: per scanned
super-layer), the residual bytes and forward compute time, plus the measured
spool write bandwidth. Then pick the *last module to offload* m as the
largest m such that the aggregate transfer deadline holds:

    bytes(m)   = sum_{j<m} store_j + (store_m + load_m)
    deadline(m)= t_fwd_total - t_fwd_end(m)            (rest of forward)
                 + bwd_factor * sum_{j>m} t_fwd_j      (bwd of later modules)
    required_bw(m) = bytes(m) / deadline(m)  <=  write_bandwidth

with the paper's estimate bwd_factor = 2 (backward ~ 2x forward). Modules
after m are kept in GPU memory — they are the first ones needed when the
backward pass begins, so offloading them cannot reduce the peak (offloading
tensors after the peak is not helpful) and only delays memory reclaim.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

BWD_FACTOR = 2.0


@dataclass(frozen=True)
class ModuleProfile:
    name: str
    bytes: int          # residual bytes this module would offload
    fwd_time: float     # seconds of forward compute


@dataclass(frozen=True)
class OffloadPlan:
    offload: List[bool]          # per module
    required_bw: float           # bytes/s needed for the chosen plan
    write_bw: float              # measured bytes/s
    last_offloaded: int          # index m (-1: nothing offloaded)

    @property
    def num_offloaded(self) -> int:
        return sum(self.offload)


def required_bandwidth(profiles: Sequence[ModuleProfile], m: int,
                       bwd_factor: float = BWD_FACTOR) -> float:
    """Bandwidth needed if modules 0..m (inclusive) are offloaded."""
    if m < 0:
        return 0.0
    bytes_needed = sum(p.bytes for p in profiles[:m]) + 2 * profiles[m].bytes
    t_fwd_rest = sum(p.fwd_time for p in profiles[m + 1:])
    t_bwd_later = bwd_factor * sum(p.fwd_time for p in profiles[m + 1:])
    # transfers for modules 0..m can also use the time while they execute:
    t_fwd_own = sum(p.fwd_time for p in profiles[1:m + 1])
    deadline = t_fwd_own + t_fwd_rest + t_bwd_later
    if deadline <= 0:
        return float("inf")
    return bytes_needed / deadline


def plan_offload(profiles: Sequence[ModuleProfile], write_bw: float,
                 bwd_factor: float = BWD_FACTOR,
                 always_keep_last: bool = True) -> OffloadPlan:
    """Choose the largest feasible last-offloaded module (paper's rule)."""
    n = len(profiles)
    hi = n - 2 if always_keep_last else n - 1  # last module kept (§3.2 ④)
    best = -1
    for m in range(hi, -2, -1):
        if m < 0:
            break
        if required_bandwidth(profiles, m, bwd_factor) <= write_bw:
            best = m
            break
    offload = [i <= best for i in range(n)]
    return OffloadPlan(
        offload=offload,
        required_bw=required_bandwidth(profiles, best, bwd_factor),
        write_bw=write_bw,
        last_offloaded=best,
    )
