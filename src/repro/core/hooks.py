"""Per-layer activation offloading hooks for the jit engine (paper §3.2).

The staged engine hands each module's autograd residuals to the
`ActivationSpool` from ordinary Python between per-stage jit calls. The
jit engine runs the whole training step as ONE XLA program, so the same
pack/unpack-hook dataflow has to cross the program boundary from inside
the trace. This module is that bridge:

  * `spooled_scan_body(fn, bridge)` wraps a segment's scan body in a
    `jax.custom_vjp`. The forward computes the segment's actual autograd
    residuals (the leaves of the `jax.vjp` closure, exactly like
    `core.staged._Stage`), keeps the parameter leaves as ordinary XLA
    residuals, and hands everything else to the spool through a
    `jax.experimental.io_callback` — after which XLA frees the device
    buffers (pack-hook semantics). The backward's io_callback fetches
    them back (blocking, with the spool's tensor forwarding if the store
    is still in flight) and applies the saved vjp.
  * `HookBridge` is the host side: a thread-safe shim that keys spool
    step-leases on the *traced* step counter the callbacks receive, so
    re-entrant offload/fetch calls from XLA host-callback threads land
    in the right transaction. A backward fetch prefetches the previous
    stage first (§3.3.2, one module ahead).

SPMD (multi-device meshes): an io_callback cannot be partitioned by
GSPMD, so on a mesh the hooks wrap the callbacks in a `shard_map` over
the whole mesh — every device invokes its own host callback with only
its LOCAL residual shard (`ShardPlan` picks per-leaf PartitionSpecs:
leading dim over the dp axes, the innermost divisible dim over tp).
Leases become shard-qualified (``jit{step}/s{shard}`` next to the
existing ``_s{stage}`` keys). Mesh axes that shard no leaf of a segment
only replicate data; those replica devices do not store a second copy —
the primary replica records the stage with ``consumers=n_replicas`` and
the bridge counts backward fetches down by that expected shard count
(`HookBridge(dedupe_replicas=False)` restores one store per device).
Callbacks then arrive on N XLA host-callback threads per step instead
of one; the bridge's fetch additionally *waits* for its forward store
callback (bounded by `fetch_timeout`), so no assumption about XLA's
cross-device schedule is baked in. The callbacks go through
`repro.core.hostcb.raw_io_callback` — `io_callback` minus its arg
`device_put`, whose async copy of a large operand can starve against
the mesh's collectives and deadlock the step (see hostcb) — so a host
callback never re-enters the jax runtime: the bridge copies operands
with plain owned memcpys and fetches with `to_device=False`.

Ordering note: the forward callback returns a tiny token that is
threaded through the custom_vjp residuals into the backward callback's
operands. The pairing is therefore enforced by DATA dependence, not by
`ordered=True` effects — scan linearization drops unordered-result-free
effectful calls from the forward pass, and tokens also keep XLA from
reordering a fetch before its store was enqueued.

Grad taps (eager optimizer overlap): with an `opt_sink`, the backward
rule additionally streams each layer's parameter cotangents to
`opt_sink.on_grads(step, stage, leaves)` the moment the layer's vjp has
run — while XLA continues into the next-lower layer's backward. The tap
is fire-and-forget (the sink must never block the callback thread); its
liveness token is folded back into dp by multiplying leaf 0 with a
runtime ``token*0.0 + 1.0`` float gate — bitwise-exact (×1.0) yet not
constant-foldable, so the tap survives DCE. An integer ``token*0`` fold
would be simplified away, and ``+0.0`` would flip ``-0.0`` bits.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import obs
from repro.cache.horizon import reuse_horizon
from repro.core.hostcb import raw_io_callback as io_callback
from repro.core.spool import ActivationSpool, SpoolStepTransaction
from repro.parallel.shmap import (axes_size, canonical_axis_entry,
                                  linear_axis_index, local_shape,
                                  mesh_size, shard_map, spec_axes)

#: stage-index offset for encoder-stream layers, so one step lease can
#: hold both streams without key collisions (decoder layers are 0-based)
ENC_STAGE_BASE = 1 << 20

#: how long a backward fetch waits for its matching forward offload
#: callback before giving up — on a mesh the callbacks arrive on
#: independent XLA host-callback threads, and a replica's backward can
#: in principle be scheduled before the primary's forward callback ran
DEFAULT_FETCH_TIMEOUT_S = 120.0


# ====================================================================
# Shard planning (how residual leaves map onto mesh devices)
# ====================================================================

@dataclass(frozen=True)
class ShardPlan:
    """How one hooked segment's residual leaves split across a mesh.

    `specs[i]` is leaf i's PartitionSpec; `writer_axes` are the mesh
    axes that shard at least one leaf (devices differing only along the
    remaining `replica_axes` hold byte-identical residuals). The shard
    id in spool keys is the linearized index over `writer_axes`; the
    replica id over `replica_axes` selects which duplicate stores."""

    mesh: Any
    specs: Tuple[Any, ...]
    writer_axes: Tuple[str, ...]
    replica_axes: Tuple[str, ...]

    @property
    def n_shards(self) -> int:
        return axes_size(self.mesh, self.writer_axes)

    @property
    def n_replicas(self) -> int:
        return axes_size(self.mesh, self.replica_axes)

    def local_sds(self, global_sds) -> Tuple[jax.ShapeDtypeStruct, ...]:
        return tuple(
            jax.ShapeDtypeStruct(local_shape(s.shape, spec, self.mesh),
                                 s.dtype)
            for s, spec in zip(global_sds, self.specs))


def plan_shards(mesh, dp_axes, tp_axis, leaf_sds) -> ShardPlan:
    """Pick a PartitionSpec per residual leaf: leading dim over the dp
    axes (batch-major residuals dominate), the innermost other divisible
    dim over tp. Indivisible leaves replicate — their bytes are stored
    once per *writer* group, not once per device."""
    dp_axes = tuple(a for a in (dp_axes or ())
                    if a in mesh.shape and mesh.shape[a] > 1)
    if tp_axis is not None and (tp_axis not in mesh.shape
                                or mesh.shape[tp_axis] <= 1):
        tp_axis = None
    dp_size = axes_size(mesh, dp_axes)
    specs = []
    for s in leaf_sds:
        parts: List[Any] = [None] * len(s.shape)
        if dp_axes and s.shape and s.shape[0] > 0 \
                and s.shape[0] % dp_size == 0:
            parts[0] = canonical_axis_entry(dp_axes)
        if tp_axis is not None:
            tp = mesh.shape[tp_axis]
            for d in range(len(s.shape) - 1, -1, -1):
                if parts[d] is None and s.shape[d] > 0 \
                        and s.shape[d] % tp == 0:
                    parts[d] = tp_axis
                    break
        specs.append(P(*parts))
    used = set()
    for spec in specs:
        used.update(spec_axes(spec))
    writer = tuple(a for a in mesh.axis_names if a in used)
    replica = tuple(a for a in mesh.axis_names if a not in used)
    return ShardPlan(mesh=mesh, specs=tuple(specs),
                     writer_axes=writer, replica_axes=replica)


class HookBridge:
    """Host-side endpoint of the jit engine's activation-offload hooks.

    One bridge per training session. Callbacks arrive on XLA's
    host-callback threads with (step, stage[, shard]) scalars; the
    bridge opens one transactional spool lease per step and shard
    (key ``jit{step}`` on one device, ``jit{step}/s{shard}`` per mesh
    shard — mirroring the staged engine's ``mb{mb}``) and closes each
    lease when the backward pass has consumed every stage it recorded.

    Shard accounting: when residuals are replicated across part of the
    mesh and `dedupe_replicas` is on, only the primary replica stores a
    stage — recorded with ``consumers=n_replicas`` — and every
    replica's backward fetch counts the stage down; the LAST fetch
    drops it. `stats_by_shard()` exposes per-shard offload/fetch/byte
    counters whose totals sum exactly to the bridge-wide traffic.
    """

    def __init__(self, spool: ActivationSpool, *, key_prefix: str = "jit",
                 dedupe_replicas: bool = True,
                 fetch_timeout: float = DEFAULT_FETCH_TIMEOUT_S,
                 fetch_fallback: bool = False):
        self.spool = spool
        self.dedupe_replicas = dedupe_replicas
        self.fetch_timeout = fetch_timeout
        self.fetch_fallback = fetch_fallback
        self._prefix = key_prefix
        self._lock = threading.RLock()
        self._cv = threading.Condition(self._lock)
        self._txs: Dict[str, SpoolStepTransaction] = {}
        self._shard_stats: Dict[Any, Dict[str, int]] = {}

    @property
    def stats(self):
        return self.spool.stats

    def stats_by_shard(self) -> Dict[Any, Dict[str, int]]:
        """Per-shard callback traffic: offloads / fetches /
        replica_skips counts and logical bytes in each direction. The
        key is the shard id (None on a single device)."""
        with self._lock:
            return {k: dict(v) for k, v in self._shard_stats.items()}

    def _note(self, shard, field: str, n: int = 1) -> None:
        with self._lock:
            rec = self._shard_stats.setdefault(shard, {
                "offloads": 0, "fetches": 0, "replica_skips": 0,
                "degraded_fetches": 0, "bytes_in": 0, "bytes_out": 0})
            rec[field] += n

    def _step_id(self, step: int, shard) -> str:
        base = f"{self._prefix}{step}"
        return base if shard is None else f"{base}/s{shard}"

    def _tx(self, step_id: str) -> SpoolStepTransaction:
        with self._lock:
            tx = self._txs.get(step_id)
            if tx is None:
                tx = self.spool.step(step_id)
                self._txs[step_id] = tx
            return tx

    # ---------------------------------------------------- callback API

    def offload(self, step: int, stage: int, arrays: List[Any], *,
                shard=None, consumers: int = 1) -> None:
        """Forward hook: async-store one segment's residual leaves
        under the (step, shard) lease. `consumers` is how many backward
        fetches this stage expects (one per replica shard).

        The leaves are COPIED here: raw_io_callback hands the hooks
        numpy views of XLA's operand buffers that die when the callback
        returns, and the spool's store worker runs after that. A plain
        owned memcpy also never touches the jax runtime — a device
        thread must not block on jax's async machinery mid-step."""
        with obs.span("hook.offload", cat="hook", step=step, stage=stage,
                      shard=shard) as sp:
            arrays = [np.array(a, copy=True) for a in arrays]
            tx = self._tx(self._step_id(step, shard))
            tx.offload(stage, arrays, consumers=consumers)
            nbytes = int(sum(a.nbytes for a in arrays))
            sp.set(bytes=nbytes)
        self._note(shard, "offloads")
        self._note(shard, "bytes_in", nbytes)
        with self._cv:
            self._cv.notify_all()

    def sharded_offload(self, step: int, stage: int, arrays: List[Any],
                        *, shard: int, replica: int,
                        n_replicas: int) -> None:
        """Mesh entry point: with replica dedupe the primary replica
        stores once for its whole replica group; without it every
        device stores its own copy under a replica-qualified shard."""
        if self.dedupe_replicas and n_replicas > 1:
            if replica == 0:
                self.offload(step, stage, arrays, shard=shard,
                             consumers=n_replicas)
            else:
                self._note(shard, "replica_skips")
                obs.instant("hook.replica_skip", cat="hook", step=step,
                            stage=stage, shard=shard, replica=replica)
        else:
            self.offload(step, stage, arrays,
                         shard=shard * n_replicas + replica)

    def fetch(self, step: int, stage: int, *,
              shard=None) -> List[np.ndarray]:
        """Backward hook: blocking fetch of one segment's residuals,
        prefetching the previous stage first (one module ahead). Counts
        the stage's consumers down; the last fetch drops it, and the
        (step, shard) lease closes when its last live stage is
        consumed. Waits (bounded) for the forward offload callback —
        on a mesh the store and fetch arrive on different host-callback
        threads and their cross-device order is not guaranteed."""
        step_id = self._step_id(step, shard)
        # only a sharded fetch may legitimately beat its store callback
        # (they run on different device threads); on one device the
        # token data-dependence already ordered them, so a missing
        # lease there is a bug — fail fast instead of timing out
        wait = self.fetch_timeout if shard is not None else 0.0
        deadline = time.monotonic() + wait
        with obs.span("hook.fetch", cat="hook", step=step, stage=stage,
                      shard=shard) as fsp:
            with obs.span("hook.wait_store", cat="hook", step=step,
                          stage=stage, shard=shard):
                with self._cv:
                    while True:
                        tx = self._txs.get(step_id)
                        if tx is not None and tx.has_stage(stage):
                            break
                        left = deadline - time.monotonic()
                        if left <= 0:
                            raise KeyError(
                                f"no live spool record for step "
                                f"{step_id!r} stage {stage} after "
                                f"{wait:.0f}s — was the forward offload "
                                f"callback dropped?")
                        self._cv.wait(timeout=min(left, 1.0))
            # one module ahead (§3.3.2): the reuse horizon over the
            # remaining backward stages
            for s in reuse_horizon(range(stage - 1, -1, -1)):
                tx.prefetch(s)
            # to_device=False: the callback returns host arrays straight
            # to XLA — converting through jnp would device_put on the
            # callback thread, the exact jax-runtime dependence
            # raw_io_callback exists to avoid
            out = tx.consume(stage, to_device=False)
            arrays = [np.asarray(a) for a in out]
            nbytes = int(sum(a.nbytes for a in arrays))
            fsp.set(bytes=nbytes)
        self._note(shard, "fetches")
        self._note(shard, "bytes_out", nbytes)
        with self._lock:
            if not tx.live_stages and self._txs.get(step_id) is tx:
                del self._txs[step_id]
                tx.close()
        return arrays

    def sharded_fetch(self, step: int, stage: int, *, shard: int,
                      replica: int, n_replicas: int) -> List[np.ndarray]:
        if self.dedupe_replicas and n_replicas > 1:
            return self.fetch(step, stage, shard=shard)
        return self.fetch(step, stage,
                          shard=shard * n_replicas + replica)

    def fetch_or_fallback(self, step: int, stage: int, shapes,
                          *, shard=None) -> Tuple[np.ndarray, ...]:
        """Degraded-mode fetch: like `fetch` but a load failure returns
        ``(0, *zeros)`` instead of raising, so the XLA program can branch
        to recompute (`spooled_scan_body`'s lax.cond). On success returns
        ``(1, *arrays)``. The branch decision is runtime data — the hook
        trace always contains BOTH the fetch and the recompute path, and
        this flag picks one per (step, stage) at execution time."""
        try:
            arrays = self.fetch(step, stage, shard=shard)
            return (np.int32(1), *arrays)
        except (RuntimeError, OSError, KeyError) as e:
            self.spool.stats.fetch_fallbacks += 1
            self._note(shard, "degraded_fetches")
            obs.count("resilience.fetch_fallback")
            obs.instant("resilience.fetch_fallback", cat="resilience",
                        step=step, stage=stage, shard=shard,
                        error=repr(e))
            self._abort_stage(step, stage, shard)
            zeros = tuple(np.zeros(s.shape, s.dtype) for s in shapes)
            return (np.int32(0), *zeros)

    def _abort_stage(self, step: int, stage: int, shard=None) -> None:
        """Drop a stage whose fetch failed so the (step, shard) lease can
        still close — the blob may be gone, `drop` tolerates that."""
        step_id = self._step_id(step, shard)
        with self._lock:
            tx = self._txs.get(step_id)
            if tx is None:
                return
            try:
                tx.drop(stage)
            except Exception:
                pass
            if not tx.live_stages and self._txs.get(step_id) is tx:
                del self._txs[step_id]
                tx.close()

    def close(self) -> None:
        """Drop any leftover leases (a step aborted mid-backward)."""
        with self._lock:
            txs, self._txs = list(self._txs.values()), {}
        for tx in txs:
            tx.close()


def _tap_grads(dp, step, stage, sink, mesh=None):
    """Stream one layer's parameter cotangents to ``sink.on_grads``
    from inside the backward trace without changing dp's value.

    Single device: one raw_io_callback with the dp leaves as operands
    (the sink copies what it keeps). On a mesh the tap runs under a
    shard_map with replicated in_specs — GSPMD materializes the
    logically-correct (post-reduction) gradients before the body — and
    only the device with linear index 0 hands them to the sink; the
    token is psum'd so every device's schedule orders the tap
    (offload_body precedent). The returned dp folds the token in via
    the ×1.0 gate described in the module docstring."""
    leaves, treedef = jax.tree.flatten(dp)
    if not leaves:
        return dp
    if mesh is None or mesh_size(mesh) <= 1:
        def grad_tap_cb(step_, stage_, *arrays):
            sink.on_grads(int(step_), int(stage_),
                          [np.array(a, copy=True) for a in arrays])
            return np.int32(0)

        tok = io_callback(grad_tap_cb,
                          jax.ShapeDtypeStruct((), jnp.int32),
                          step, stage, *leaves)
        gate = tok.astype(jnp.float32) * 0.0 + 1.0
    else:
        axis_names = tuple(mesh.axis_names)

        def grad_tap_cb(step_, stage_, dev_, *arrays):
            if int(np.asarray(dev_).reshape(())) == 0:
                sink.on_grads(int(step_), int(stage_),
                              [np.array(a, copy=True) for a in arrays])
            return np.zeros((1,), np.int32)

        def tap_body(step_, stage_, *leaves_):
            dev_ = linear_axis_index(mesh, axis_names)
            tok = io_callback(grad_tap_cb,
                              jax.ShapeDtypeStruct((1,), jnp.int32),
                              step_, stage_, dev_, *leaves_)
            return jax.lax.psum(tok, axis_names)

        token_spec = P(canonical_axis_entry(axis_names))
        tok = shard_map(tap_body, mesh=mesh,
                        in_specs=(P(), P(), *([P()] * len(leaves))),
                        out_specs=token_spec,
                        check_vma=False)(step, stage, *leaves)
        gate = jnp.sum(tok.astype(jnp.float32)) * 0.0 + 1.0
    leaves = [leaves[0] * gate.astype(leaves[0].dtype)] + leaves[1:]
    return jax.tree.unflatten(treedef, leaves)


def tapped_scan_body(fn: Callable, opt_sink, *, mesh=None) -> Callable:
    """Tap-only wrapper for segments whose residuals stay in device
    memory (``host_offload="opt_state"`` with opt overlap): the forward
    saves the ordinary vjp residuals as XLA residuals — no spool I/O —
    and the backward streams each layer's parameter grads to
    `opt_sink` the moment its vjp has run. Same
    ``wrapped(p, x, step, stage)`` signature as `spooled_scan_body`."""
    cell: Dict[str, Any] = {}

    @jax.custom_vjp
    def wrapped(p, x, step, stage):
        return fn(p, x)

    def fwd(p, x, step, stage):
        out, vjp = jax.vjp(fn, p, x)
        leaves, treedef = jax.tree.flatten(vjp)
        cell["treedef"] = treedef
        return out, (tuple(leaves), step, stage)

    def bwd(res, g):
        leaves, step, stage = res
        vjp = jax.tree.unflatten(cell["treedef"], list(leaves))
        dp, dx = vjp(g)
        dp = _tap_grads(dp, step, stage, opt_sink, mesh)
        return dp, dx, jnp.zeros_like(step), jnp.zeros_like(stage)

    wrapped.defvjp(fwd, bwd)
    return wrapped


def spooled_scan_body(fn: Callable, bridge: HookBridge, *,
                      mesh=None, dp_axes=(), tp_axis=None,
                      opt_sink=None) -> Callable:
    """Wrap ``fn(p_layer, x) -> out`` (a segment's per-layer body) so its
    residuals stream through the bridge's spool.

    Returns ``wrapped(p_layer, x, step, stage) -> out`` where `step` and
    `stage` are traced float32 scalars (float so the custom_vjp
    cotangents are ordinary zeros; values are exact integers). The
    undifferentiated primal path calls `fn` directly — serving and eval
    never touch the spool.

    With a multi-device `mesh`, the callbacks run under a shard_map so
    each device hands the bridge only its local residual shard (see the
    module docstring); `dp_axes`/`tp_axis` seed the per-leaf sharding
    choice exactly like `RunSettings`. With an `opt_sink`, the backward
    additionally taps the layer's parameter grads (see `_tap_grads`).
    """
    # populated at trace time by fwd, read by bwd (same trace); the
    # pattern and the param-leaf identity test match core.staged._Stage
    cell: Dict[str, Any] = {}
    sharded = mesh is not None and mesh_size(mesh) > 1
    # Degraded mode (single device only): the bwd callback returns an
    # ok-flag and the trace carries BOTH the fetch and a recompute path
    # through a lax.cond, with (p, x) saved as extra residuals. Under a
    # mesh the recompute branch would put collectives inside cond
    # branches — not supported, so sharded runs keep fetch-or-raise.
    fallback = bridge.fetch_fallback and not sharded

    @jax.custom_vjp
    def wrapped(p, x, step, stage):
        return fn(p, x)

    def fwd(p, x, step, stage):
        out, vjp = jax.vjp(fn, p, x)
        leaves, treedef = jax.tree.flatten(vjp)
        pids = {id(t) for t in jax.tree.leaves(p)}
        param_idx = tuple(i for i, l in enumerate(leaves) if id(l) in pids)
        resid_idx = tuple(i for i in range(len(leaves))
                          if i not in param_idx)
        cell["treedef"] = treedef
        cell["param_idx"] = param_idx
        cell["resid_idx"] = resid_idx
        cell["n_leaves"] = len(leaves)
        cell["resid_shapes"] = tuple(
            jax.ShapeDtypeStruct(leaves[i].shape, leaves[i].dtype)
            for i in resid_idx)
        kept = tuple(leaves[i] for i in param_idx)
        if not resid_idx:            # segment saved only parameter leaves
            return out, (kept, step, stage, jnp.zeros((), jnp.int32))

        resid = tuple(leaves[i] for i in resid_idx)
        if not sharded:
            def offload_cb(step_, stage_, *arrays):
                bridge.offload(int(step_), int(stage_), list(arrays))
                return np.int32(0)

            token = io_callback(offload_cb,
                                jax.ShapeDtypeStruct((), jnp.int32),
                                step, stage, *resid)
            if fallback:
                # The recompute branch re-differentiates the segment in
                # bwd, where fn's closed-over tracers (positions, masks)
                # would leak into the staged-out jaxpr as invalid
                # consts. Hoist them into explicit residuals and save
                # the closure-free converted function instead.
                # jax.closure_convert is not enough: it only hoists
                # perturbable (float) consts, and e.g. int32 positions
                # still leak.
                conv_fn, hoisted = _hoist_all_consts(fn, p, x)
                cell["conv_fn"] = conv_fn
                return out, (kept, step, stage, token,
                             (p, x, hoisted))
            return out, (kept, step, stage, token)

        plan = plan_shards(mesh, dp_axes, tp_axis, cell["resid_shapes"])
        cell["plan"] = plan
        n_replicas = plan.n_replicas

        def offload_cb(step_, stage_, shard_, replica_, *arrays):
            bridge.sharded_offload(int(step_), int(stage_), list(arrays),
                                   shard=int(shard_),
                                   replica=int(replica_),
                                   n_replicas=n_replicas)
            return np.zeros((1,), np.int32)

        dedupe = bridge.dedupe_replicas and n_replicas > 1

        def offload_body(step_, stage_, *local_leaves):
            shard_ = linear_axis_index(mesh, plan.writer_axes)
            replica_ = linear_axis_index(mesh, plan.replica_axes)
            tok = io_callback(offload_cb,
                              jax.ShapeDtypeStruct((1,), jnp.int32),
                              step_, stage_, shard_, replica_,
                              *local_leaves)
            if dedupe:
                # With replica dedupe only the primary replica's
                # callback stores; a replica's backward fetch then
                # BLOCKS (host side) on the primary's store having run.
                # XLA's scheduler cannot see that cross-device callback
                # dependence and may legally park the primary at a
                # later collective first — a deadlock. The psum makes
                # the dependence explicit: every device's token now
                # data-depends on every replica's (so in particular the
                # primary's) store callback having executed.
                tok = jax.lax.psum(tok, plan.replica_axes)
            return tok

        # one (1,)-token per device, reassembled over the whole mesh so
        # the backward shard_map can hand each device its own token back
        token_spec = P(canonical_axis_entry(mesh.axis_names))
        token = shard_map(offload_body, mesh=mesh,
                          in_specs=(P(), P(), *plan.specs),
                          out_specs=token_spec,
                          check_vma=False)(step, stage, *resid)
        return out, (kept, step, stage, token)

    def bwd(res, g):
        saved_in = None
        if fallback and len(res) == 5:
            kept, step, stage, token, saved_in = res
        else:
            kept, step, stage, token = res
        leaves: List[Any] = [None] * cell["n_leaves"]
        for i, l in zip(cell["param_idx"], kept):
            leaves[i] = l
        ok = None
        if cell["resid_idx"]:
            if not sharded:
                if fallback:
                    def fetch_cb(step_, stage_, _token):
                        return bridge.fetch_or_fallback(
                            int(step_), int(stage_),
                            cell["resid_shapes"])

                    got = io_callback(
                        fetch_cb,
                        (jax.ShapeDtypeStruct((), jnp.int32),
                         *cell["resid_shapes"]),
                        step, stage, token)
                    ok, fetched = got[0], got[1:]
                else:
                    def fetch_cb(step_, stage_, _token):
                        return tuple(bridge.fetch(int(step_),
                                                  int(stage_)))

                    fetched = io_callback(fetch_cb, cell["resid_shapes"],
                                          step, stage, token)
            else:
                plan = cell["plan"]
                local_sds = plan.local_sds(cell["resid_shapes"])
                n_replicas = plan.n_replicas

                def fetch_cb(step_, stage_, shard_, replica_, _token):
                    return tuple(bridge.sharded_fetch(
                        int(step_), int(stage_), shard=int(shard_),
                        replica=int(replica_), n_replicas=n_replicas))

                def fetch_body(step_, stage_, token_):
                    shard_ = linear_axis_index(mesh, plan.writer_axes)
                    replica_ = linear_axis_index(mesh, plan.replica_axes)
                    return io_callback(fetch_cb, local_sds, step_, stage_,
                                       shard_, replica_, token_)

                token_spec = P(canonical_axis_entry(mesh.axis_names))
                fetched = shard_map(fetch_body, mesh=mesh,
                                    in_specs=(P(), P(), token_spec),
                                    out_specs=plan.specs,
                                    check_vma=False)(step, stage, token)
            for i, l in zip(cell["resid_idx"], fetched):
                leaves[i] = l
        if ok is not None and saved_in is not None:
            p_saved, x_saved, hoisted = saved_in

            def use_fetched(g_):
                vjp = jax.tree.unflatten(cell["treedef"], leaves)
                return vjp(g_)

            def use_recompute(g_):
                # re-runs the segment forward from the saved inputs and
                # differentiates it — the zeros the failed fetch
                # returned are never read on this branch
                outs = jax.vjp(cell["conv_fn"], p_saved, x_saved,
                               *hoisted)[1](g_)
                return outs[0], outs[1]

            dp, dx = jax.lax.cond(ok > 0, use_fetched, use_recompute, g)
        else:
            vjp = jax.tree.unflatten(cell["treedef"], leaves)
            dp, dx = vjp(g)
        if opt_sink is not None:
            dp = _tap_grads(dp, step, stage, opt_sink,
                            mesh if sharded else None)
        return dp, dx, jnp.zeros_like(step), jnp.zeros_like(stage)

    wrapped.defvjp(fwd, bwd)
    return wrapped


def _hoist_all_consts(fn: Callable, *example_args):
    """Closure-convert `fn`, hoisting EVERY tracer const — unlike
    jax.closure_convert, which only hoists perturbable (float) ones.

    Returns ``(conv_fn, hoisted)`` where ``conv_fn(*example_args,
    *hoisted)`` equals ``fn(*example_args)`` but closes over no tracers,
    so it can be re-traced inside a custom_vjp bwd rule (the degraded
    recompute branch) without leaking the enclosing trace."""
    flat_in, in_tree = jax.tree.flatten(example_args)
    store: Dict[str, Any] = {}

    def flat_fn(*fl):
        out = fn(*jax.tree.unflatten(in_tree, fl))
        out_flat, store["out_tree"] = jax.tree.flatten(out)
        return out_flat

    closed = jax.make_jaxpr(flat_fn)(*flat_in)
    consts = list(closed.consts)
    tracer_idx = tuple(i for i, c in enumerate(consts)
                       if isinstance(c, jax.core.Tracer))
    hoisted = tuple(consts[i] for i in tracer_idx)
    n_args = len(example_args)

    def conv_fn(*args):
        trees, hs = args[:n_args], args[n_args:]
        cs = list(consts)
        for i, h in zip(tracer_idx, hs):
            cs[i] = h
        fl = jax.tree.flatten(trees)[0]
        out_flat = jax.core.eval_jaxpr(closed.jaxpr, cs, *fl)
        return jax.tree.unflatten(store["out_tree"], out_flat)

    return conv_fn, hoisted


def run_splits(mask: List[bool]) -> List[tuple]:
    """Split a per-layer offload mask into contiguous (start, end,
    offload) runs — a scanned super-layer can only be hooked whole, so
    mixed plans split the stack into a few shorter scans."""
    runs = []
    start = 0
    for i in range(1, len(mask) + 1):
        if i == len(mask) or mask[i] != mask[start]:
            runs.append((start, i, mask[start]))
            start = i
    return runs
