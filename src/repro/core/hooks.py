"""Per-layer activation offloading hooks for the jit engine (paper §3.2).

The staged engine hands each module's autograd residuals to the
`ActivationSpool` from ordinary Python between per-stage jit calls. The
jit engine runs the whole training step as ONE XLA program, so the same
pack/unpack-hook dataflow has to cross the program boundary from inside
the trace. This module is that bridge:

  * `spooled_scan_body(fn, bridge)` wraps a segment's scan body in a
    `jax.custom_vjp`. The forward computes the segment's actual autograd
    residuals (the leaves of the `jax.vjp` closure, exactly like
    `core.staged._Stage`), keeps the parameter leaves as ordinary XLA
    residuals, and hands everything else to the spool through a
    `jax.experimental.io_callback` — after which XLA frees the device
    buffers (pack-hook semantics). The backward's io_callback fetches
    them back (blocking, with the spool's tensor forwarding if the store
    is still in flight) and applies the saved vjp.
  * `HookBridge` is the host side: a thread-safe shim that keys spool
    step-leases on the *traced* step counter the callbacks receive, so
    re-entrant offload/fetch calls from XLA host-callback threads land
    in the right transaction. A backward fetch prefetches the previous
    stage first (§3.3.2, one module ahead).

Ordering note: the forward callback returns a tiny token that is
threaded through the custom_vjp residuals into the backward callback's
operands. The pairing is therefore enforced by DATA dependence, not by
`ordered=True` effects — scan linearization drops unordered-result-free
effectful calls from the forward pass, and tokens also keep XLA from
reordering a fetch before its store was enqueued.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import io_callback

from repro.core.spool import ActivationSpool, SpoolStepTransaction

#: stage-index offset for encoder-stream layers, so one step lease can
#: hold both streams without key collisions (decoder layers are 0-based)
ENC_STAGE_BASE = 1 << 20


class HookBridge:
    """Host-side endpoint of the jit engine's activation-offload hooks.

    One bridge per training session. Callbacks arrive on XLA's
    host-callback threads with (step, stage) scalars; the bridge opens
    one transactional spool lease per step (key ``jit{step}``, mirroring
    the staged engine's ``mb{mb}``) and closes it when the backward pass
    has consumed every recorded stage.
    """

    def __init__(self, spool: ActivationSpool, *, key_prefix: str = "jit"):
        self.spool = spool
        self._prefix = key_prefix
        self._lock = threading.RLock()
        self._txs: Dict[int, SpoolStepTransaction] = {}

    @property
    def stats(self):
        return self.spool.stats

    def _tx(self, step: int) -> SpoolStepTransaction:
        with self._lock:
            tx = self._txs.get(step)
            if tx is None:
                tx = self.spool.step(f"{self._prefix}{step}")
                self._txs[step] = tx
            return tx

    # ---------------------------------------------------- callback API

    def offload(self, step: int, stage: int, arrays: List[Any]) -> None:
        """Forward hook: async-store one segment's residual leaves."""
        self._tx(step).offload(stage, list(arrays))

    def fetch(self, step: int, stage: int) -> List[np.ndarray]:
        """Backward hook: blocking fetch of one segment's residuals,
        prefetching the previous stage first (one module ahead). Closes
        the step's lease when its last live stage is consumed."""
        with self._lock:
            tx = self._txs.get(step)
        if tx is None:
            raise KeyError(f"no live spool lease for jit step {step}")
        tx.prefetch(stage - 1)
        out = tx.fetch(stage)
        arrays = [np.asarray(a) for a in out]
        tx.drop(stage)
        with self._lock:
            if not tx.live_stages and self._txs.get(step) is tx:
                del self._txs[step]
                tx.close()
        return arrays

    def close(self) -> None:
        """Drop any leftover leases (a step aborted mid-backward)."""
        with self._lock:
            txs, self._txs = list(self._txs.values()), {}
        for tx in txs:
            tx.close()


def spooled_scan_body(fn: Callable, bridge: HookBridge) -> Callable:
    """Wrap ``fn(p_layer, x) -> out`` (a segment's per-layer body) so its
    residuals stream through the bridge's spool.

    Returns ``wrapped(p_layer, x, step, stage) -> out`` where `step` and
    `stage` are traced float32 scalars (float so the custom_vjp
    cotangents are ordinary zeros; values are exact integers). The
    undifferentiated primal path calls `fn` directly — serving and eval
    never touch the spool.
    """
    # populated at trace time by fwd, read by bwd (same trace); the
    # pattern and the param-leaf identity test match core.staged._Stage
    cell: Dict[str, Any] = {}

    @jax.custom_vjp
    def wrapped(p, x, step, stage):
        return fn(p, x)

    def fwd(p, x, step, stage):
        out, vjp = jax.vjp(fn, p, x)
        leaves, treedef = jax.tree.flatten(vjp)
        pids = {id(t) for t in jax.tree.leaves(p)}
        param_idx = tuple(i for i, l in enumerate(leaves) if id(l) in pids)
        resid_idx = tuple(i for i in range(len(leaves))
                          if i not in param_idx)
        cell["treedef"] = treedef
        cell["param_idx"] = param_idx
        cell["resid_idx"] = resid_idx
        cell["n_leaves"] = len(leaves)
        cell["resid_shapes"] = tuple(
            jax.ShapeDtypeStruct(leaves[i].shape, leaves[i].dtype)
            for i in resid_idx)
        kept = tuple(leaves[i] for i in param_idx)
        if not resid_idx:            # segment saved only parameter leaves
            return out, (kept, step, stage, jnp.zeros((), jnp.int32))

        def offload_cb(step_, stage_, *arrays):
            bridge.offload(int(step_), int(stage_), list(arrays))
            return np.int32(0)

        token = io_callback(offload_cb, jax.ShapeDtypeStruct((), jnp.int32),
                            step, stage,
                            *(leaves[i] for i in resid_idx))
        return out, (kept, step, stage, token)

    def bwd(res, g):
        kept, step, stage, token = res
        leaves: List[Any] = [None] * cell["n_leaves"]
        for i, l in zip(cell["param_idx"], kept):
            leaves[i] = l
        if cell["resid_idx"]:
            def fetch_cb(step_, stage_, _token):
                return tuple(bridge.fetch(int(step_), int(stage_)))

            fetched = io_callback(fetch_cb, cell["resid_shapes"],
                                  step, stage, token)
            for i, l in zip(cell["resid_idx"], fetched):
                leaves[i] = l
        vjp = jax.tree.unflatten(cell["treedef"], leaves)
        dp, dx = vjp(g)
        return dp, dx, jnp.zeros_like(step), jnp.zeros_like(stage)

    wrapped.defvjp(fwd, bwd)
    return wrapped


def run_splits(mask: List[bool]) -> List[tuple]:
    """Split a per-layer offload mask into contiguous (start, end,
    offload) runs — a scanned super-layer can only be hooked whole, so
    mixed plans split the stack into a few shorter scans."""
    runs = []
    start = 0
    for i in range(1, len(mask) + 1):
        if i == len(mask) or mask[i] != mask[start]:
            runs.append((start, i, mask[start]))
            start = i
    return runs
