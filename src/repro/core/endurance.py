"""SSD write amount, bandwidth, and lifespan modeling (paper §3.4, Fig. 9,
Table 4) — the llm-analysis extension, rebuilt on exact residual counting.

Two layers:

1. `residual_bytes_per_layer(cfg, batch, seq)` — the *exact* activation
   bytes one transformer layer saves for backward, obtained by flattening
   the jax.vjp closure of the block under eval_shape (no allocation).
   This is the quantity TBA offloads; the paper's Table 4 validates its
   analytic estimate against the measured offload amount — ours is exact
   by construction, and tests cross-check it against the spool's measured
   bytes (tests/test_endurance.py).

2. `project(system)` — the Fig. 9 projection: forward time from the
   max(compute, memory) pipeline model, t_step = 3 x t_fwd, required PCIe
   write bandwidth = offloaded bytes / (t_step / 2), SSD lifespan =
   endurance_bytes * t_step / bytes_per_step.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import (RunSettings, apply_block,
                                      build_segments, init_block)

# paper §3.3.2 / Algorithm 2 line 12: tensors < 2^20 elements stay on GPU
MIN_OFFLOAD_ELEMENTS = 2 ** 20


def _block_residual_specs(cfg: ModelConfig, batch: int, seq: int,
                          settings: Optional[RunSettings] = None):
    # Count under FlashAttention semantics (attn saves only q, k, v — the
    # kernels' custom_vjp) to match the paper's FA-2 substrate (§4.1):
    # the XLA chunked path would additionally count its per-chunk score
    # residuals, which FA never materialises.
    settings = settings or RunSettings(attn_impl="pallas_interpret",
                                       attn_chunk=1024,
                                       param_dtype=cfg.dtype)
    seg = build_segments(cfg)[-1]          # the repeated (majority) block

    def f(params, x):
        aux: Dict = {}
        positions = jnp.arange(x.shape[1]) if cfg.use_rope else None
        for i, bdef in enumerate(seg.blocks):
            x, _ = apply_block(bdef, params[f"b{i}"], x, cfg, settings,
                               positions=positions, aux=aux)
        return x

    def shapes(params, x):
        _, vjp = jax.vjp(f, params, x)
        return tuple(jax.tree.leaves(vjp))

    key = jax.random.key(0)
    p_sds = jax.eval_shape(
        lambda k: {f"b{i}": init_block(k, b, cfg,
                                       jnp.dtype(cfg.dtype).type)
                   for i, b in enumerate(seg.blocks)}, key)
    x_sds = jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                 jnp.dtype(cfg.dtype))
    res = jax.eval_shape(shapes, p_sds, x_sds)
    p_shapes = {(tuple(l.shape), str(l.dtype))
                for l in jax.tree.leaves(p_sds)}
    return res, p_shapes, len(seg.blocks)


def residual_bytes_per_layer(cfg: ModelConfig, batch: int, seq: int, *,
                             offloadable_only: bool = True) -> int:
    """Activation bytes per (single) layer saved for backward.

    offloadable_only applies the paper's >= 2^20-element filter and
    excludes parameter-shaped leaves (§3.3.1 parameter exclusion)."""
    res, p_shapes, n_blocks = _block_residual_specs(cfg, batch, seq)
    total = 0
    for leaf in res:
        sig = (tuple(leaf.shape), str(leaf.dtype))
        if sig in p_shapes:
            continue                       # parameter (excluded, §3.3.1)
        if offloadable_only and leaf.size < MIN_OFFLOAD_ELEMENTS:
            continue
        total += leaf.size * leaf.dtype.itemsize
    return total // n_blocks if n_blocks > 1 else total


def analytic_bytes_per_token_per_layer(cfg: ModelConfig, *,
                                       tp: int = 1) -> float:
    """llm-analysis-style analytic count of activation bytes per token per
    layer under FlashAttention + tensor parallelism `tp` (the estimator
    the paper extends in §3.4; validated against its Table 4).

    Saved per attention sublayer: block input x (h), norm output (h),
    q/k/v ((Hq+2Hkv)*hd / tp), attention output o (Hq*hd / tp).
    Per MLP sublayer: x (h), norm output (h), hidden pre-activation
    (F/tp), activation output (F/tp), plus the gate branch for GLU MLPs.
    SSM/RG-LRU blocks: projections and scan output at their inner width.
    """
    h = cfg.d_model
    e = jnp.dtype(cfg.dtype).itemsize
    elems = 0.0
    kind = cfg.layer_kind(0) if cfg.family != "moe" else "attn"
    if cfg.family == "ssm":
        d_inner = cfg.ssm_expand * h
        # z/x projections (2*d_inner), conv out (d_inner + 2N), scan out
        elems += 2 * d_inner + (d_inner + 2 * cfg.ssm_state_dim) + d_inner
        elems += 2 * h                     # x + gated-norm input
        return elems * e
    # attention (or rg-lru) sublayer
    if cfg.hybrid_pattern:
        # average over the pattern
        n_attn = sum(1 for k in cfg.hybrid_pattern if k == "attn")
        n_rg = len(cfg.hybrid_pattern) - n_attn
        W = cfg.rglru_width or h
        rg_elems = 2 * h + (3 * W + 2 * W) / tp   # gate,in,conv + gates
        hd = cfg.resolved_head_dim
        at_elems = 2 * h + ((cfg.num_heads + 2 * cfg.num_kv_heads) * hd
                            + cfg.num_heads * hd) / tp
        elems += (n_attn * at_elems + n_rg * rg_elems) \
            / len(cfg.hybrid_pattern)
    else:
        hd = cfg.resolved_head_dim
        elems += 2 * h + ((cfg.num_heads + 2 * cfg.num_kv_heads) * hd
                          + cfg.num_heads * hd) / tp
    # mlp sublayer
    if cfg.moe_num_experts:
        # top-k expert FFs touch each token (dropless view)
        F = cfg.d_ff * cfg.moe_top_k
    else:
        F = cfg.d_ff
    if F:
        n_branches = 3 if cfg.mlp_glu else 2
        elems += 2 * h + n_branches * F / tp
    return elems * e


def offloaded_bytes_per_step(cfg: ModelConfig, batch: int, seq: int, *,
                             tp: int = 1) -> int:
    """Whole-model offload traffic per training step per TP shard
    (Table 4 model estimate; the paper measures one of two TP=2 GPUs)."""
    per_tok_layer = analytic_bytes_per_token_per_layer(cfg, tp=tp)
    return int(per_tok_layer * batch * seq * cfg.num_layers)


# ------------------------------------------------------------- Fig. 9

@dataclass(frozen=True)
class GpuSpec:
    name: str = "A100-PCIe"
    peak_flops: float = 312e12        # fp16
    hbm_bw: float = 1.9e12            # bytes/s (A100-40GB PCIe ~1.55-2.0)


@dataclass(frozen=True)
class SsdSpec:
    """4x Solidigm D7-P5810 1.6TB per GPU (paper §3.4)."""
    name: str = "4x D7-P5810"
    endurance_pbw: float = 146.0 * 4  # PB writes across the 4 drives
    jesd_waf: float = 2.5             # sequential writes vs JESD rating
    our_waf: float = 1.0


@dataclass(frozen=True)
class SystemConfig:
    """One Fig. 9 x-axis entry (Megatron-LM table [77])."""
    label: str
    n_params: float
    n_gpus: int
    hidden: int
    layers: int
    seq_len: int
    global_batch: int                 # sequences
    achieved_flops_per_gpu: float     # measured model FLOP/s per GPU [77]
    zero3: bool = False


# Megatron-LM's published scaling table (Narayanan et al. '21), the
# source the paper cites for Fig. 9's system configurations.
MEGATRON_SYSTEMS: List[SystemConfig] = [
    SystemConfig("22B Megatron", 22e9, 64, 6144, 48, 2048, 1536, 149e12),
    SystemConfig("175B Megatron", 175e9, 384, 12288, 96, 2048, 1536,
                 153e12),
    SystemConfig("530B Megatron", 530e9, 1120, 20480, 105, 2048, 2520,
                 159e12),
    SystemConfig("1T Megatron", 1008e9, 3072, 25600, 128, 2048, 3072,
                 163e12),
    SystemConfig("20B ZeRO3", 20e9, 64, 6144, 44, 2048, 1024, 120e12,
                 zero3=True),
    SystemConfig("100B ZeRO3", 100e9, 384, 10240, 80, 2048, 1024, 110e12,
                 zero3=True),
]


@dataclass
class Projection:
    label: str
    t_step_s: float
    act_bytes_per_gpu: float
    pcie_write_gb_s: float
    lifespan_years: float
    max_act_bytes_per_gpu: float


def _act_bytes_per_token_per_layer(hidden: int, dtype_bytes: int = 2,
                                   multiplier: float = 10.6) -> float:
    """Analytic fallback for Fig.9's GPT geometry: ~10.6*h elements per
    token per layer survive for backward under FlashAttention (validated
    against residual_bytes_per_layer on the paper's BERT geometry)."""
    return multiplier * hidden * dtype_bytes


def project(sys: SystemConfig, gpu: GpuSpec = GpuSpec(),
            ssd: SsdSpec = SsdSpec()) -> Projection:
    tokens = sys.global_batch * sys.seq_len
    # model FLOPs per step (6ND); step time from achieved per-GPU rate
    flops = 6.0 * sys.n_params * tokens
    t_step = flops / (sys.achieved_flops_per_gpu * sys.n_gpus)

    act_per_token_layer = _act_bytes_per_token_per_layer(sys.hidden)
    act_total = act_per_token_layer * sys.layers * tokens
    act_per_gpu = act_total / sys.n_gpus

    # §3.4: write window is half the step (adaptive offloading defers the
    # tail of the writes into early backward)
    pcie_write = act_per_gpu / (t_step / 2.0)

    endurance_bytes = (ssd.endurance_pbw * 1e15
                       * ssd.jesd_waf / ssd.our_waf)
    lifespan_s = endurance_bytes * t_step / act_per_gpu
    years = lifespan_s / (365.25 * 24 * 3600)

    # max activations a step could offload: two layers resident, rest on
    # SSD, bounded by SSD capacity per GPU (4 x 1.6 TB)
    max_act = min(4 * 1.6e12, act_per_gpu * 8)
    return Projection(sys.label, t_step, act_per_gpu, pcie_write / 1e9,
                      years, max_act)


def project_all() -> List[Projection]:
    return [project(s) for s in MEGATRON_SYSTEMS]


# ------------------------------------------------- per-device wear (repro.io)

@dataclass(frozen=True)
class DeviceWear:
    """Measured write load and projected lifespan of one SSD in a
    striped array (repro.io.StripedBackend per-device accounting)."""
    device: str
    bytes_written: int
    share: float                  # fraction of the array's total writes
    write_gb_s: float             # sustained rate over the measured window
    lifespan_years: float


def project_device_lifespans(per_device_bytes: Sequence[int],
                             elapsed_s: float, *,
                             ssd: SsdSpec = SsdSpec(),
                             devices_in_spec: int = 4,
                             labels: Optional[Sequence[str]] = None) \
        -> List[DeviceWear]:
    """Fig. 9's lifespan projection, per physical drive.

    The striped backend counts bytes per stripe directory; each
    directory stands in for one SSD, so dividing the spec's array
    endurance by `devices_in_spec` gives the per-drive budget. Lifespan
    is endurance over the *measured sustained write rate* of that drive
    — a skewed stripe layout shows up directly as one drive aging
    faster than the array average."""
    if elapsed_s <= 0:
        raise ValueError("elapsed_s must be positive")
    endurance_per_dev = (ssd.endurance_pbw * 1e15 / devices_in_spec
                         * ssd.jesd_waf / ssd.our_waf)
    total = sum(per_device_bytes)
    out = []
    for i, nbytes in enumerate(per_device_bytes):
        label = labels[i] if labels else f"dev{i}"
        rate = nbytes / elapsed_s
        life_s = endurance_per_dev / rate if rate > 0 else float("inf")
        out.append(DeviceWear(
            device=label, bytes_written=int(nbytes),
            share=(nbytes / total if total else 0.0),
            write_gb_s=rate / 1e9,
            lifespan_years=life_s / (365.25 * 24 * 3600)))
    return out
