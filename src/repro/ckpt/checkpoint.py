"""Async, atomic, reshardable checkpointing (tensorstore-free).

Layout per step:
    <dir>/step_<N>.tmp/...      (written)
    <dir>/step_<N>/             (atomic rename on commit)
        manifest.json           treedef, shapes, dtypes, user metadata
        arrays.npz              flattened leaves keyed by path

Design points required at cluster scale:
  * atomic commit — a crash mid-write never leaves a half checkpoint that
    restore could pick up (restore only reads committed dirs);
  * async save — serialization happens on a background thread off the
    training loop's critical path; `wait()` joins before the next save;
  * elastic reshard-on-load — arrays are stored as *logical* (global)
    values; restore takes an optional tree of NamedShardings for the
    current mesh, so a 512-chip checkpoint restores onto 256 chips (or a
    differently shaped mesh) without conversion tools;
  * keep_last GC — old committed steps are pruned after a new commit.

bf16 leaves round-trip via ml_dtypes (numpy-native in this environment).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import warnings
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out.append((key, leaf))
    return out


def save_checkpoint(directory: str, step: int, tree, *,
                    metadata: Optional[Dict] = None) -> str:
    """Synchronous atomic save. Returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    items = _flatten_with_paths(tree)
    arrays = {}
    manifest = {"step": step, "metadata": metadata or {}, "leaves": {}}
    for key, leaf in items:
        arr = np.asarray(leaf)
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
        skey = key.replace("/", "|")   # zip-safe npz member names
        # bf16 stored raw via view to u16
        if arr.dtype.name == "bfloat16":
            arrays[skey] = arr.view(np.uint16)
            manifest["leaves"][key]["dtype"] = "bfloat16"
        else:
            arrays[skey] = arr
    # Crash consistency: every payload byte is fsynced before the
    # manifest is written, the manifest is written LAST (its validity
    # marks the checkpoint complete), and the rename that publishes the
    # directory is made durable by fsyncing the parent. A crash at any
    # point leaves either the old committed step or a .tmp/partial dir
    # that `checkpoint_is_valid` rejects — never a half checkpoint that
    # restore could pick up.
    with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(tmp)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)          # atomic commit
    _fsync_dir(directory)
    return final


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    except OSError:
        pass                        # some filesystems reject dir fsync
    finally:
        os.close(fd)


def checkpoint_is_valid(path: str) -> bool:
    """True iff the committed checkpoint dir at `path` is complete: the
    manifest parses and the npz opens with every manifest leaf present.
    A truncated npz (crash or torn copy) fails the zip central-directory
    check; a missing/garbled manifest fails the parse."""
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as data:
            names = set(data.files)
        need = {k.replace("/", "|") for k in manifest["leaves"]}
        return need <= names
    except Exception:
        return False


def latest_step(directory: str) -> Optional[int]:
    """Newest step whose checkpoint is COMPLETE. Partial or corrupt
    dirs (torn copy, crash before this module fsynced the manifest) are
    skipped with a warning, falling back to the next older valid step —
    resume prefers losing a few steps to loading garbage."""
    if not os.path.isdir(directory):
        return None
    steps = sorted((int(d.split("_")[1]) for d in os.listdir(directory)
                    if d.startswith("step_") and not d.endswith(".tmp")),
                   reverse=True)
    for s in steps:
        path = os.path.join(directory, f"step_{s:08d}")
        if checkpoint_is_valid(path):
            return s
        warnings.warn(f"skipping partial/corrupt checkpoint {path}")
    return None


def restore_checkpoint(directory: str, tree_like, *, step: Optional[int]
                       = None, shardings=None):
    """Restore into the structure of `tree_like` (arrays or SDS).

    shardings: optional pytree of jax.sharding.Sharding matching
    tree_like — arrays are device_put with them (elastic reshard)."""
    if step is None:
        step = latest_step(directory)    # skips corrupt checkpoints
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    if os.path.isdir(path) and not checkpoint_is_valid(path):
        # an EXPLICITLY requested step that is broken is an error, not
        # something to silently substitute
        raise ValueError(f"checkpoint {path} is partial or corrupt")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    import ml_dtypes
    by_key = {}
    for key, meta in manifest["leaves"].items():
        arr = data[key.replace("/", "|")]
        if meta["dtype"] == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        by_key[key] = arr

    items = _flatten_with_paths(tree_like)
    leaves = []
    for key, like in items:
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = by_key[key]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(
                f"shape mismatch for {key!r}: ckpt {arr.shape} vs "
                f"model {like.shape}")
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(tree_like)
    restored = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings)
    else:
        restored = jax.tree.map(jax.numpy.asarray, restored)
    return restored, manifest


class CheckpointManager:
    """Async save + GC + restore with a stable directory layout."""

    def __init__(self, directory: str, *, keep_last: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep_last = keep_last
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save

    def save(self, step: int, tree, metadata: Optional[Dict] = None):
        # snapshot to host memory synchronously (cheap); serialize async
        host_tree = jax.tree.map(np.asarray, tree)
        self.wait()
        if not self.async_save:
            save_checkpoint(self.dir, step, host_tree, metadata=metadata)
            self._gc()
            return
        self._thread = threading.Thread(
            target=self._save_worker, args=(step, host_tree, metadata),
            daemon=True)
        self._thread.start()

    def _save_worker(self, step, tree, metadata):
        try:
            save_checkpoint(self.dir, step, tree, metadata=metadata)
            self._gc()
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep_last] if self.keep_last else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore

    def latest_step(self) -> Optional[int]:
        return latest_step(self.dir)

    def restore(self, tree_like, *, step=None, shardings=None):
        self.wait()
        return restore_checkpoint(self.dir, tree_like, step=step,
                                  shardings=shardings)


# ------------------------------------------------- train-state helpers

def save_train_state(ckpt: "CheckpointManager", step: int, params, opt_state,
                     loader=None, *, final: bool = False) -> None:
    """One canonical layout for a training checkpoint (params + optimizer
    state + data cursor) — shared by TrainLoop and TrainSession so the
    two drivers cannot drift apart."""
    meta = {"data": loader.state_dict()
            if hasattr(loader, "state_dict") else {},
            "final": final}
    ckpt.save(step, {"params": params, "opt_state": opt_state},
              metadata=meta)
    if final:
        ckpt.wait()


def restore_train_state(ckpt: "CheckpointManager", params, opt_state,
                        loader=None, *, shardings=None):
    """Restore the latest committed train-state checkpoint (the inverse
    of `save_train_state`). `params`/`opt_state` provide the target tree
    structure; `shardings` reshards onto the current mesh. Returns
    (step, params, opt_state) or None when no checkpoint exists."""
    step = ckpt.latest_step()
    if step is None:
        return None
    like = {"params": params, "opt_state": opt_state}
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype)
        if hasattr(x, "shape") else x, like)
    restored, manifest = ckpt.restore(like, step=step,
                                      shardings=shardings)
    if hasattr(loader, "load_state_dict") and \
            manifest["metadata"].get("data"):
        loader.load_state_dict(manifest["metadata"]["data"])
    return step, restored["params"], restored["opt_state"]
