"""repro.resilience — ride through a dying SSD.

The paper's endurance analysis (§VII) makes SSD wear-out a *planned*
event on long pretraining runs, so the data plane has to treat device
degradation as a normal operating mode, not an exception. This package
is the fault-riding layer:

  RetryPolicy    — bounded exponential backoff for transient I/O errors
                   (classified by repro.io.backend.classify_io_error);
                   the spool's store/load workers wrap every backend
                   call in it.
  BackendHealth  — per-backend health monitor: consecutive-failure and
                   latency-degradation tracking, with state-transition
                   events ("degraded" / "failing" / "recovered") pushed
                   to subscribers. AdaptivePolicy subscribes and
                   re-plans mid-run when the backend sours.
  ChaosHarness   — test/ops driver that scripts faults against a live
                   backend stack (kill a stripe device, flaky writes,
                   raising reads, ENOSPC) and aggregates the injected
                   counters the chaos tests assert on.

The degradation ladder, end to end: healthy offload → retry/backoff →
stripe rebalancing away from the sick device → tier fallback (managed
backend) → recompute-from-kept-inputs when a residual is truly lost.
"""
from repro.resilience.chaos import ChaosHarness, unwrap_chain
from repro.resilience.health import BackendHealth, HealthEvent
from repro.resilience.retry import RetryPolicy

__all__ = [
    "BackendHealth",
    "ChaosHarness",
    "HealthEvent",
    "RetryPolicy",
    "unwrap_chain",
]
