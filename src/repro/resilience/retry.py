"""Bounded exponential backoff for transient backend I/O errors."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class RetryPolicy:
    """How many times to re-issue a failed backend call, and how long to
    wait between attempts.

    ``max_attempts`` counts the first try: 3 means one call plus up to
    two retries. Backoff is ``backoff_s * factor**(attempt-1)`` capped
    at ``backoff_max_s`` — deterministic (no jitter) so fault-injection
    tests can assert exact attempt counts.
    """

    max_attempts: int = 3
    backoff_s: float = 0.01
    backoff_factor: float = 2.0
    backoff_max_s: float = 0.25

    def delay(self, attempt: int) -> float:
        """Seconds to sleep before retry number ``attempt`` (1-based)."""
        if attempt < 1:
            return 0.0
        d = self.backoff_s * (self.backoff_factor ** (attempt - 1))
        return min(d, self.backoff_max_s)

    def validate(self) -> None:
        assert self.max_attempts >= 1, "need at least one attempt"
        assert self.backoff_s >= 0.0 and self.backoff_max_s >= 0.0
        assert self.backoff_factor >= 1.0
