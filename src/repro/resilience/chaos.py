"""ChaosHarness — script faults against a live backend stack.

The chaos tests (and the CI chaos job) need to make a real, running
training session experience a dying SSD: a stripe device that starts
hard-failing mid-run, a flaky controller that drops a fraction of
writes, reads that raise, a filesystem that fills up. The primitives
live in `FaultInjectingBackend` (arming) and `StripedBackend` (per-
device error seams); this harness finds them inside an arbitrarily
nested backend chain and exposes scenario-level verbs on top.
"""
from __future__ import annotations

import errno
from typing import Dict, Iterator, Optional

from repro import obs


def unwrap_chain(backend) -> Iterator[object]:
    """Yield ``backend`` and every backend reachable through the
    standard wrapper attributes (``inner``, ``upper``, ``lower``)."""
    seen = set()
    stack = [backend]
    while stack:
        b = stack.pop()
        if b is None or id(b) in seen:
            continue
        seen.add(id(b))
        yield b
        for attr in ("inner", "upper", "lower"):
            nxt = getattr(b, attr, None)
            if nxt is not None and hasattr(nxt, "kind"):
                stack.append(nxt)


class ChaosHarness:
    """Scenario driver over a backend chain.

    >>> harness = ChaosHarness(spool.backend)
    >>> harness.kill_device(1)          # stripe device 1 is gone
    >>> harness.flaky_writes(0.3, seed=7)
    >>> harness.report()["rebalanced_chunks"]
    """

    def __init__(self, backend) -> None:
        self.backend = backend
        self.fault = None
        self.striped = None
        for b in unwrap_chain(backend):
            kind = getattr(b, "kind", "")
            if kind == "fault" and self.fault is None:
                self.fault = b
            if kind == "striped" and self.striped is None:
                self.striped = b

    # ------------------------------------------------------ scenarios
    def kill_device(self, dev: int,
                    exc: Optional[BaseException] = None) -> None:
        """Hard-fail stripe device ``dev``: every chunk write *and*
        read on it raises, as if the NVMe dropped off the bus."""
        assert self.striped is not None, "no striped backend in chain"
        exc = exc or OSError(errno.EIO, f"chaos: device {dev} died")
        self.striped.set_device_error(dev, exc)
        if obs.is_enabled():
            obs.instant("chaos.kill_device", cat="resilience", dev=dev)

    def heal_device(self, dev: int) -> None:
        assert self.striped is not None, "no striped backend in chain"
        self.striped.clear_device_error(dev)
        if obs.is_enabled():
            obs.instant("chaos.heal_device", cat="resilience", dev=dev)

    def flaky_writes(self, rate: float, seed: int = 0,
                     exc: Optional[BaseException] = None) -> None:
        """Each write through the fault wrapper fails with probability
        ``rate`` (seeded RNG → reproducible chaos)."""
        assert self.fault is not None, "no fault backend in chain"
        self.fault.arm_intermittent(rate, seed=seed, exc=exc)

    def raising_reads(self, n: int, *, key_substr: Optional[str] = None,
                      exc: Optional[BaseException] = None) -> None:
        assert self.fault is not None, "no fault backend in chain"
        self.fault.arm_read_failures(n, exc=exc, key_substr=key_substr)

    def enospc(self, after_bytes: int) -> None:
        """The device reports ENOSPC once ``after_bytes`` more bytes
        have been written through the fault wrapper."""
        assert self.fault is not None, "no fault backend in chain"
        self.fault.arm_enospc(after_bytes)

    # ------------------------------------------------------ reporting
    def report(self) -> Dict[str, int]:
        """Aggregate injected-fault and recovery counters across the
        chain — what the chaos tests assert 'each path fired'."""
        out: Dict[str, int] = {}
        if self.fault is not None:
            out.update(self.fault.injected)
        if self.striped is not None:
            out["rebalanced_chunks"] = self.striped.rebalanced_chunks
            out["chunk_write_failures"] = (
                self.striped.chunk_write_failures)
            out["devices_down"] = sum(self.striped.devices_down())
        return out
