"""Per-backend health monitor: consecutive failures + latency drift.

The spool's retry wrapper feeds every backend call outcome into a
`BackendHealth` instance. The monitor keeps per-op (write/read)
counters and a latency EWMA, derives a three-state status, and pushes
`HealthEvent`s to subscribers on every state *transition*:

  healthy  — normal operation
  degraded — op latency EWMA exceeds ``degrade_latency_ratio`` times
             the baseline established over the first ``min_samples``
             successful calls (a slowly dying SSD looks exactly like
             this: no errors yet, bandwidth collapsing)
  failing  — ``fail_threshold`` consecutive failures on an op (the
             device is effectively gone)

AdaptivePolicy subscribes and re-plans on "degraded"/"failing"; obs
gauges mirror the state so the per-step metrics show the transition.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

from repro import obs

HEALTHY, DEGRADED, FAILING = "healthy", "degraded", "failing"
_STATUS_CODE = {HEALTHY: 0, DEGRADED: 1, FAILING: 2}


@dataclass(frozen=True)
class HealthEvent:
    """One state transition of a monitored backend."""

    kind: str                  # "degraded" | "failing" | "recovered"
    backend: str               # backend kind string, e.g. "striped"
    op: str                    # "write" | "read"
    consecutive_failures: int
    latency_ratio: float       # current EWMA / baseline (1.0 = nominal)
    error: Optional[str] = None


@dataclass
class _OpState:
    consec_failures: int = 0
    failures: int = 0
    successes: int = 0
    baseline_s: Optional[float] = None   # mean of first min_samples
    baseline_n: int = 0
    baseline_sum: float = 0.0
    ewma_s: Optional[float] = None
    status: str = HEALTHY


class BackendHealth:
    """Thread-safe health tracker for one storage backend."""

    def __init__(self, backend: str = "?", *, fail_threshold: int = 3,
                 degrade_latency_ratio: float = 4.0,
                 ema_alpha: float = 0.25, min_samples: int = 8) -> None:
        assert fail_threshold >= 1
        assert degrade_latency_ratio > 1.0
        self.backend = backend
        self.fail_threshold = fail_threshold
        self.degrade_latency_ratio = degrade_latency_ratio
        self.ema_alpha = ema_alpha
        self.min_samples = min_samples
        self._lock = threading.Lock()
        self._ops: Dict[str, _OpState] = {}
        self._subs: List[Callable[[HealthEvent], None]] = []
        self.events: List[HealthEvent] = []

    # ------------------------------------------------------ subscribe
    def subscribe(self, cb: Callable[[HealthEvent], None]) -> None:
        """Register ``cb`` to be called (outside the monitor lock, on
        the recording thread) for every state transition."""
        with self._lock:
            self._subs.append(cb)

    # ------------------------------------------------------ recording
    def record_success(self, op: str, seconds: float) -> None:
        ev = None
        with self._lock:
            st = self._ops.setdefault(op, _OpState())
            st.successes += 1
            st.consec_failures = 0
            if st.baseline_s is None:
                st.baseline_n += 1
                st.baseline_sum += seconds
                if st.baseline_n >= self.min_samples:
                    st.baseline_s = max(st.baseline_sum / st.baseline_n,
                                        1e-9)
            a = self.ema_alpha
            st.ewma_s = (seconds if st.ewma_s is None
                         else (1 - a) * st.ewma_s + a * seconds)
            ratio = self._ratio(st)
            if st.status == FAILING:
                st.status = (DEGRADED if self._is_degraded(st)
                             else HEALTHY)
                ev = self._event("recovered", op, st, ratio)
            elif st.status == HEALTHY and self._is_degraded(st):
                st.status = DEGRADED
                ev = self._event("degraded", op, st, ratio)
            elif st.status == DEGRADED and not self._is_degraded(st):
                st.status = HEALTHY
                ev = self._event("recovered", op, st, ratio)
        self._emit(ev)

    def record_failure(self, op: str, exc: BaseException,
                       seconds: float = 0.0) -> None:
        ev = None
        with self._lock:
            st = self._ops.setdefault(op, _OpState())
            st.failures += 1
            st.consec_failures += 1
            if (st.consec_failures >= self.fail_threshold
                    and st.status != FAILING):
                st.status = FAILING
                ev = self._event(FAILING, op, st, self._ratio(st),
                                 error=repr(exc))
        self._emit(ev)

    # ------------------------------------------------------ inspection
    @property
    def status(self) -> str:
        """Worst status across ops."""
        with self._lock:
            worst = HEALTHY
            for st in self._ops.values():
                if _STATUS_CODE[st.status] > _STATUS_CODE[worst]:
                    worst = st.status
            return worst

    def latency_ratio(self, op: str = "write") -> float:
        with self._lock:
            st = self._ops.get(op)
            return self._ratio(st) if st else 1.0

    def snapshot(self) -> Dict[str, object]:
        """Flat dict for metrics emission (resilience_ block)."""
        with self._lock:
            out: Dict[str, object] = {
                "health": _STATUS_CODE[self._worst_locked()],
                "health_events": len(self.events),
            }
            for op, st in self._ops.items():
                out[f"{op}_failures"] = st.failures
                out[f"{op}_consec_failures"] = st.consec_failures
                out[f"{op}_latency_ratio"] = round(self._ratio(st), 3)
            return out

    # ------------------------------------------------------ internals
    def _worst_locked(self) -> str:
        worst = HEALTHY
        for st in self._ops.values():
            if _STATUS_CODE[st.status] > _STATUS_CODE[worst]:
                worst = st.status
        return worst

    def _ratio(self, st: _OpState) -> float:
        if st.baseline_s is None or st.ewma_s is None:
            return 1.0
        return st.ewma_s / st.baseline_s

    def _is_degraded(self, st: _OpState) -> bool:
        return self._ratio(st) > self.degrade_latency_ratio

    def _event(self, kind: str, op: str, st: _OpState, ratio: float,
               error: Optional[str] = None) -> HealthEvent:
        ev = HealthEvent(kind=kind, backend=self.backend, op=op,
                         consecutive_failures=st.consec_failures,
                         latency_ratio=ratio, error=error)
        self.events.append(ev)
        return ev

    def _emit(self, ev: Optional[HealthEvent]) -> None:
        if ev is None:
            return
        if obs.is_enabled():
            obs.instant(f"resilience.{ev.kind}", cat="resilience",
                        backend=ev.backend, op=ev.op,
                        consec=ev.consecutive_failures,
                        latency_ratio=round(ev.latency_ratio, 3),
                        error=ev.error or "")
            obs.gauge("resilience.health",
                      _STATUS_CODE[self.status], backend=ev.backend)
        with self._lock:
            subs = list(self._subs)
        for cb in subs:
            try:
                cb(ev)
            except Exception:
                pass  # a broken subscriber must not kill an I/O worker
