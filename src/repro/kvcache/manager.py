"""KV-cache managers: the paged, spool-backed device cache and the
dense baseline (repro.kvcache).

`PagedKVCache` decouples logical sequence length from device residency:
K/V lives in fixed-size pages in a shared device pool, each sequence
owns a page table, and a parked (preempted/idle) sequence's pages are
*evicted through the activation spool* — the same bufpool + aio/fs +
byteplane data plane training activations ride, reused unchanged for
bf16 KV pages. Every sequence holds one spool lease
(`spool.lease(f"kv{rid}")`); pages are lease stages keyed by logical
page index, so releasing a retired sequence drops every blob it ever
spooled, on success and on error alike (the transactional-lease
contract from training, reused for serving).

`DenseKVCache` is the classic layout — one dense cache row per slot —
behind the same interface, so the continuous-batching scheduler runs
against either and the benchmark can A/B them at equal device budget.
Both decode through jitted steps with donated cache arguments, and both
use *identical* attention extents (`KVCacheConfig.padded_seq_len`), so
paged and dense logits are bitwise-equal on the same request trace.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs
from repro.kvcache import adapters
from repro.kvcache.pages import KVCacheConfig, PageAllocator
from repro.models.api import ModelApi
from repro.models.transformer import RunSettings

__all__ = ["PagedKVCache", "DenseKVCache", "KVStats"]


@dataclass
class KVStats:
    """Counters the serve report and the bench surface."""
    pages_allocated: int = 0
    page_faults: int = 0            # decode-growth allocs (pos crossed a page)
    pages_evicted: int = 0
    pages_restored: int = 0
    bytes_evicted: int = 0
    bytes_restored: int = 0
    evictions: int = 0              # sequence park events
    restores: int = 0               # sequence un-park events
    prefills: int = 0
    hot_binds: int = 0              # slot refills that needed no spool I/O

    def as_dict(self) -> Dict[str, int]:
        import dataclasses as _dc
        return _dc.asdict(self)


def _align_up(n: int, m: int) -> int:
    return -(-n // m) * m


class _ManagerBase:
    """Shared slot bookkeeping: per-slot position / last-token arrays
    and the prompt-bucketing rule (kept identical between paged and
    dense so both run the very same prefill forward)."""

    def __init__(self, api: ModelApi, params, settings: RunSettings,
                 kvcfg: KVCacheConfig, n_slots: int):
        self.api = api
        self.cfg = api.cfg
        self.params = params
        self.settings = settings
        self.kvcfg = kvcfg.validate()
        self.n_slots = n_slots
        self.P = kvcfg.page_tokens
        self.S = kvcfg.padded_seq_len
        self.max_pages = kvcfg.max_pages
        self.exact_prefill = adapters.needs_exact_prefill(
            api.segments, self.S)
        self.pos = np.zeros((n_slots,), np.int32)
        self.last_tok = np.zeros((n_slots,), np.int32)
        self.stats = KVStats()
        self._start_fns: Dict[int, Any] = {}

    def bind_token(self, seq, token: int) -> None:
        """Stage the first sampled token (from prefill logits) as the
        slot's next decode input — no position bump: the token's K/V is
        written by the decode step that consumes it."""
        seq.last_tok = token
        self.last_tok[seq.slot] = token

    def bucket_for(self, plen: int) -> int:
        """Prefill length for a prompt: page-aligned right padding when
        every sequence state is paged (pad K/V is masked), the exact
        length when ring/recurrent state would integrate pad tokens."""
        return plen if self.exact_prefill else _align_up(plen, self.P)

    def _pad_prompt(self, prompt: np.ndarray, bucket: int) -> jnp.ndarray:
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :len(prompt)] = prompt
        return jnp.asarray(toks)


# ======================================================================
# Paged manager
# ======================================================================

class PagedKVCache(_ManagerBase):
    kind = "paged"
    can_evict = True

    def __init__(self, api: ModelApi, params, settings: RunSettings,
                 kvcfg: KVCacheConfig, n_slots: int, spool):
        super().__init__(api, params, settings, kvcfg, n_slots)
        if spool is None:
            raise ValueError("PagedKVCache needs a spool for eviction")
        self.spool = spool
        # Under a cache-manager backend, parked KV pages are a declared
        # tensor class (lease keys `kv{rid}_*`): they compete with
        # activations and opt_state for the bounded host-RAM tier on
        # reuse distance (decode recency via the refill horizon's
        # prefetch hints) instead of through a private heuristic.
        cm = getattr(spool, "cache_manager", None)
        if cm is not None:
            cm.register_class("kv_page", prefix="kv")
        self.n_pool_pages = kvcfg.resolve_pool_pages(n_slots)
        self.alloc = PageAllocator(self.n_pool_pages)
        self.paged_ids = adapters.paged_block_ids(api.segments, self.S)
        if not any(self.paged_ids):
            raise ValueError(
                f"{self.cfg.name}: no pageable (full-attention) cache "
                "entries — a paged pool would hold nothing")
        self.pools = adapters.build_pools(
            api.segments, self.cfg, self.n_pool_pages, self.P, self.S,
            kvcfg.dtype)
        self.resident = adapters.build_resident(
            api.segments, self.cfg, n_slots, self.S, kvcfg.dtype)
        self.page_bytes = adapters.page_nbytes(self.pools)
        self.tables = np.zeros((n_slots, self.max_pages), np.int32)
        self._decode_fn = jax.jit(
            lambda params, pools, resident, tables, tokens, pos:
                api.decode_step_paged(params, pools, resident, tables,
                                      {"tokens": tokens}, pos, settings),
            donate_argnums=(1, 2))
        self._scatter_fns: Dict[Any, Any] = {}
        self._res_write_fns: Dict[Any, Any] = {}

    @property
    def device_bytes(self) -> int:
        return (adapters.tree_nbytes(self.pools)
                + adapters.tree_nbytes(self.resident))

    # ------------------------------------------------------- decode

    def decode(self) -> np.ndarray:
        """One decode step for every slot; returns (B, V) f32 logits.
        Idle slots decode a dummy token into the null page."""
        logits, self.pools, self.resident = self._decode_fn(
            self.params, self.pools, self.resident,
            jnp.asarray(self.tables), jnp.asarray(self.last_tok[:, None]),
            jnp.asarray(self.pos))
        return np.asarray(logits[:, 0])

    def advance(self, seq, token: int) -> None:
        """Record the sampled token; the slot writes it next step."""
        seq.pos += 1
        seq.last_tok = token
        self.pos[seq.slot] = seq.pos
        self.last_tok[seq.slot] = token

    def fault_in(self, seq) -> None:
        """Make sure the page holding position seq.pos exists before
        the decode step writes into it."""
        needed = seq.pos // self.P + 1
        if needed <= len(seq.pages):
            return
        grow = needed - len(seq.pages)
        ids = self.alloc.alloc(grow)
        for k, pid in enumerate(ids):
            self.tables[seq.slot, len(seq.pages) + k] = pid
        seq.pages.extend(ids)
        self.stats.pages_allocated += grow
        self.stats.page_faults += grow
        obs.instant("kv.alloc", cat="kv", seq=seq.rid, pages=grow,
                    fault=True)
        obs.gauge("kv.pages_in_use", self.alloc.in_use)

    # ------------------------------------------------------- lifecycle

    def start(self, seq, slot: int) -> np.ndarray:
        """Prefill a new sequence into pages bound to `slot`; returns
        the (V,) logits row at the last prompt position."""
        plen = len(seq.prompt)
        bucket = self.bucket_for(plen)
        n_pages = max(1, -(-bucket // self.P))
        ids = self.alloc.alloc(n_pages)
        seq.tx = self.spool.lease(f"kv{seq.rid}")
        with obs.span("kv.prefill", cat="kv", seq=seq.rid,
                      tokens=plen, pages=n_pages):
            row, self.pools, self.resident = self._start_fn(bucket)(
                self.params, self._pad_prompt(seq.prompt, bucket),
                self.pools, self.resident, jnp.asarray(ids, jnp.int32),
                jnp.asarray(slot, jnp.int32),
                jnp.asarray(plen - 1, jnp.int32))
            row = np.asarray(row)
        seq.pages = list(ids)
        seq.slot = slot
        seq.pos = plen
        self.tables[slot] = 0
        self.tables[slot, :n_pages] = ids
        self.pos[slot] = plen
        self.stats.pages_allocated += n_pages
        self.stats.prefills += 1
        obs.instant("kv.alloc", cat="kv", seq=seq.rid, pages=n_pages)
        obs.gauge("kv.pages_in_use", self.alloc.in_use)
        return row

    def evict(self, seq) -> None:
        """Park a slot-resident sequence: stream its pages (and any
        resident recurrent/ring state) to the spool, free the device
        pages, unbind the slot. The spool writes are async — decode of
        the other slots keeps running while the pages drain."""
        assert seq.slot is not None and seq.pages is not None
        n = len(seq.pages)
        with obs.span("kv.evict", cat="kv", seq=seq.rid, pages=n):
            ids = jnp.asarray(seq.pages)
            host: List = []
            for seg_i, entry in enumerate(self.pools):
                for bid, kv in entry.items():
                    host.append((f"{seg_i}.{bid}", {
                        "k": np.asarray(kv["k"][:, ids]),
                        "v": np.asarray(kv["v"][:, ids])}))
            nbytes = 0
            for j in range(n):
                blob = {name: {"k": kv["k"][:, j], "v": kv["v"][:, j]}
                        for name, kv in host}
                nbytes += sum(a.nbytes for a in jax.tree.leaves(blob))
                seq.tx.offload(j, blob)
            st = {}
            for seg_i, entry in enumerate(self.resident):
                for bid, tree in entry.items():
                    st[f"{seg_i}.{bid}"] = jax.tree.map(
                        lambda a: np.asarray(a[:, seq.slot]), tree)
            if st:
                nbytes += sum(a.nbytes for a in jax.tree.leaves(st))
                seq.tx.offload("st", st)
        self.alloc.free(seq.pages)
        self._unbind(seq)
        seq.n_pages = n
        seq.pages = None
        self.stats.pages_evicted += n
        self.stats.bytes_evicted += nbytes
        self.stats.evictions += 1
        obs.instant("kv.evicted", cat="kv", seq=seq.rid, pages=n,
                    bytes=nbytes)
        obs.gauge("kv.pages_in_use", self.alloc.in_use)

    def prefetch(self, seq) -> None:
        """Hint async loads for a parked sequence's pages — issued when
        it enters the refill horizon, so the blobs stream back from the
        spool while other slots keep decoding."""
        if seq.pages is not None or seq.tx is None:
            return
        for j in range(seq.n_pages):
            seq.tx.prefetch(j)
        if seq.tx.has_stage("st"):
            seq.tx.prefetch("st")
        obs.instant("kv.prefetch", cat="kv", seq=seq.rid,
                    pages=seq.n_pages)

    def restore(self, seq, slot: int) -> None:
        """Un-park a sequence into `slot`: fetch its pages from the
        spool (prefetch hits make this a forwarding, not a read) and
        scatter them into freshly allocated device pages."""
        assert seq.pages is None
        n = seq.n_pages
        with obs.span("kv.restore", cat="kv", seq=seq.rid, pages=n):
            ids = self.alloc.alloc(n)
            nbytes = 0
            for j, pid in enumerate(ids):
                blob = seq.tx.consume(j, to_device=False)
                nbytes += sum(a.nbytes for a in jax.tree.leaves(blob))
                pid_ = jnp.asarray(pid, jnp.int32)
                for seg_i, entry in enumerate(self.pools):
                    for bid in entry:
                        page = blob[f"{seg_i}.{bid}"]
                        entry[bid] = self._scatter(seg_i, bid)(
                            entry[bid], pid_,
                            {"k": jnp.asarray(page["k"]),
                             "v": jnp.asarray(page["v"])})
            if seq.tx.has_stage("st"):
                st = seq.tx.consume("st", to_device=False)
                nbytes += sum(a.nbytes for a in jax.tree.leaves(st))
                slot_ = jnp.asarray(slot, jnp.int32)
                for seg_i, entry in enumerate(self.resident):
                    for bid in entry:
                        rows = jax.tree.map(jnp.asarray,
                                            st[f"{seg_i}.{bid}"])
                        entry[bid] = self._res_write(seg_i, bid)(
                            entry[bid], slot_, rows)
        seq.pages = ids
        seq.slot = slot
        self.tables[slot] = 0
        self.tables[slot, :n] = ids
        self.pos[slot] = seq.pos
        self.last_tok[slot] = seq.last_tok
        self.stats.pages_allocated += n
        self.stats.pages_restored += n
        self.stats.bytes_restored += nbytes
        self.stats.restores += 1
        obs.instant("kv.restored", cat="kv", seq=seq.rid, pages=n,
                    bytes=nbytes)
        obs.gauge("kv.pages_in_use", self.alloc.in_use)

    def release(self, seq) -> None:
        """Retire a sequence: free device pages if resident, drop every
        spooled blob via the lease's close (leak-proof by contract)."""
        if seq.pages is not None:
            self.alloc.free(seq.pages)
            if seq.slot is not None:
                self._unbind(seq)
            seq.pages = None
        if seq.tx is not None:
            seq.tx.close()
            seq.tx = None
        obs.gauge("kv.pages_in_use", self.alloc.in_use)

    def _unbind(self, seq) -> None:
        self.tables[seq.slot] = 0
        self.pos[seq.slot] = 0
        self.last_tok[seq.slot] = 0
        seq.slot = None

    # ------------------------------------------------------- jit cache

    def _start_fn(self, bucket: int):
        fn = self._start_fns.get(bucket)
        if fn is not None:
            return fn
        P, S = self.P, self.S
        n_pages = max(1, -(-bucket // P))
        pad = n_pages * P - bucket
        api, settings = self.api, self.settings

        def start(params, toks, pools, resident, ids, slot, lpos):
            logits, caches, _ = api.forward(
                params, {"tokens": toks}, settings, emit_cache=True,
                cache_len=S)
            new_pools, new_res = [], []
            for seg_i, entry in enumerate(pools):
                ne = {}
                for bid, kv in entry.items():
                    ce = caches[seg_i][bid]

                    def pages_of(a):
                        a = a[:, 0, :bucket]
                        if pad:
                            a = jnp.pad(a, [(0, 0), (0, pad),
                                            (0, 0), (0, 0)])
                        return a.reshape(a.shape[0], n_pages, P,
                                         *a.shape[2:])

                    ne[bid] = {
                        "k": kv["k"].at[:, ids].set(pages_of(ce["k"])),
                        "v": kv["v"].at[:, ids].set(pages_of(ce["v"])),
                    }
                new_pools.append(ne)
            for seg_i, entry in enumerate(resident):
                ne = {}
                for bid, tree in entry.items():
                    ce = caches[seg_i][bid]
                    ne[bid] = jax.tree.map(
                        lambda r, x: r.at[:, slot].set(x[:, 0]),
                        tree, ce)
                new_res.append(ne)
            return logits[0, lpos], new_pools, new_res

        fn = jax.jit(start, donate_argnums=(2, 3))
        self._start_fns[bucket] = fn
        return fn

    def _scatter(self, seg_i: int, bid: str):
        key = (seg_i, bid)
        fn = self._scatter_fns.get(key)
        if fn is None:
            fn = jax.jit(
                lambda kv, pid, page: {
                    "k": kv["k"].at[:, pid].set(page["k"]),
                    "v": kv["v"].at[:, pid].set(page["v"])},
                donate_argnums=(0,))
            self._scatter_fns[key] = fn
        return fn

    def _res_write(self, seg_i: int, bid: str):
        key = (seg_i, bid)
        fn = self._res_write_fns.get(key)
        if fn is None:
            fn = jax.jit(
                lambda tree, slot, rows: jax.tree.map(
                    lambda r, x: r.at[:, slot].set(x), tree, rows),
                donate_argnums=(0,))
            self._res_write_fns[key] = fn
        return fn


# ======================================================================
# Dense baseline
# ======================================================================

class DenseKVCache(_ManagerBase):
    """The classic dense layout: every slot owns full-length cache rows
    (`padded_seq_len`, matching the paged attention extent bitwise).
    No eviction — a live sequence pins its slot until retirement, so
    concurrency is capped at the slot count. This is the baseline the
    bench holds at equal device bytes."""

    kind = "dense"
    can_evict = False

    def __init__(self, api: ModelApi, params, settings: RunSettings,
                 kvcfg: KVCacheConfig, n_slots: int, spool=None):
        super().__init__(api, params, settings, kvcfg, n_slots)
        empty = [set() for _ in api.segments]
        self.caches = adapters.build_resident(
            api.segments, self.cfg, n_slots, self.S, kvcfg.dtype,
            paged=empty)
        self._decode_fn = jax.jit(
            lambda params, caches, tokens, pos:
                api.decode_step(params, caches, {"tokens": tokens}, pos,
                                settings),
            donate_argnums=(1,))

    @property
    def device_bytes(self) -> int:
        return adapters.tree_nbytes(self.caches)

    def decode(self) -> np.ndarray:
        logits, self.caches = self._decode_fn(
            self.params, self.caches,
            jnp.asarray(self.last_tok[:, None]), jnp.asarray(self.pos))
        return np.asarray(logits[:, 0])

    def advance(self, seq, token: int) -> None:
        seq.pos += 1
        seq.last_tok = token
        self.pos[seq.slot] = seq.pos
        self.last_tok[seq.slot] = token

    def fault_in(self, seq) -> None:   # dense rows never fault
        pass

    def start(self, seq, slot: int) -> np.ndarray:
        plen = len(seq.prompt)
        bucket = self.bucket_for(plen)
        with obs.span("kv.prefill", cat="kv", seq=seq.rid, tokens=plen):
            row, self.caches = self._start_fn(bucket)(
                self.params, self._pad_prompt(seq.prompt, bucket),
                self.caches, jnp.asarray(slot, jnp.int32),
                jnp.asarray(plen - 1, jnp.int32))
            row = np.asarray(row)
        seq.slot = slot
        seq.pos = plen
        self.pos[slot] = plen
        self.stats.prefills += 1
        return row

    def evict(self, seq) -> None:
        raise RuntimeError("dense KV cache cannot evict — sequences pin "
                           "their slot until retirement")

    def prefetch(self, seq) -> None:
        pass

    def restore(self, seq, slot: int) -> None:
        raise RuntimeError("dense KV cache has nothing to restore")

    def release(self, seq) -> None:
        if seq.slot is not None:
            self.pos[seq.slot] = 0
            self.last_tok[seq.slot] = 0
            seq.slot = None

    def _start_fn(self, bucket: int):
        fn = self._start_fns.get(bucket)
        if fn is not None:
            return fn
        api, settings, S = self.api, self.settings, self.S

        def start(params, toks, caches, slot, lpos):
            logits, pre, _ = api.forward(
                params, {"tokens": toks}, settings, emit_cache=True,
                cache_len=S)
            new = []
            for seg_i, entry in enumerate(caches):
                ne = {}
                for bid, tree in entry.items():
                    ne[bid] = jax.tree.map(
                        lambda r, x: r.at[:, slot].set(x[:, 0]),
                        tree, pre[seg_i][bid])
                new.append(ne)
            return logits[0, lpos], new

        fn = jax.jit(start, donate_argnums=(2,))
        self._start_fns[bucket] = fn
        return fn
