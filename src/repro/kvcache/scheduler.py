"""Continuous-batching serve scheduler over a KV-cache manager
(repro.kvcache).

The server owns B decode *slots* and a queue of requests. Unlike the
old batch-at-a-time driver (decode every member of a batch to
completion, then admit the next batch), slots turn over individually:
the moment a sequence retires, its slot refills from the resume queue
(parked sequences first — their pages are already prefetching from the
spool) or from the new queue, while the other slots keep decoding.

With a paged cache and a scheduling *quantum*, the server also
time-slices: a sequence that has decoded `quantum` tokens since it was
bound gets preempted — its pages evicted through the spool — whenever
other work is waiting. Live (mid-generation) sequences then exceed the
slot count; device residency is the slot working set, and the spool
holds the rest. The dense manager cannot evict, so its concurrency is
structurally capped at B — that is the baseline the benchmark compares
against at equal device bytes.

Everything here is deterministic on purpose (FIFO queues, ascending
slot refill, LIFO page recycling in the allocator): the same request
trace yields the same schedule log, the same token ids, and — paged or
dense — bitwise-identical logits.

Accounting fixes over the old driver, kept as invariants by tests:
  * the first sampled token of a request (from prefill logits) is
    counted in `generated_tokens` like every other token;
  * idle slots never count toward decode tokens (`decode_slot_tokens`
    only sums slots with a live sequence), so tok/s is not inflated by
    padding rows.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro import obs
from repro.cache.horizon import reuse_horizon

__all__ = ["Request", "Sequence", "Server", "ServeReport"]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (plen,) int32
    max_new: int


class Sequence:
    """One in-flight request plus the state the KV manager hangs off
    it (slot binding, page list, spool lease)."""

    def __init__(self, req: Request, t_submit: float):
        self.rid = req.rid
        self.prompt = np.asarray(req.prompt, np.int32)
        self.max_new = req.max_new
        self.tokens: List[int] = []
        self.pos = 0                 # next KV write position
        self.last_tok = 0
        self.slot: Optional[int] = None
        self.pages: Optional[List[int]] = None   # device pages (paged)
        self.n_pages = 0             # page count while parked
        self.tx = None               # spool lease (paged)
        self.q_used = 0              # decode tokens since last bind
        self.preemptions = 0
        self.t_submit = t_submit
        self.t_first: Optional[float] = None
        self.token_times: List[float] = []
        self.logits: Optional[List[np.ndarray]] = None

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.max_new


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


@dataclass
class ServeReport:
    requests: int = 0
    n_slots: int = 0
    decode_steps: int = 0
    prompt_tokens: int = 0          # true prompt tokens, no padding
    generated_tokens: int = 0       # every sampled token, incl. first
    decode_slot_tokens: int = 0     # decode-step tokens on live slots
    decode_time_s: float = 0.0
    wall_time_s: float = 0.0
    decode_tok_s: float = 0.0
    gen_tok_s: float = 0.0
    slot_occupancy: float = 0.0     # live-slot fraction of decode grid
    peak_live: int = 0
    mean_live: float = 0.0
    preemptions: int = 0
    ttft_p50_ms: float = 0.0
    ttft_p99_ms: float = 0.0
    itl_p50_ms: float = 0.0         # inter-token latency
    itl_p95_ms: float = 0.0
    itl_p99_ms: float = 0.0
    cache_kind: str = ""
    device_bytes: int = 0
    kv: Dict[str, int] = field(default_factory=dict)

    def as_dict(self) -> Dict[str, Any]:
        import dataclasses as _dc
        return _dc.asdict(self)


class Server:
    """Continuous-batching decode loop over a KV-cache manager.

    cache:          PagedKVCache or DenseKVCache (manager.py).
    eos_id:         retire a sequence early on this token (None: run to
                    max_new).
    record_logits:  keep every sampled-from logits row per sequence
                    (numpy, f32) — the paged-vs-dense parity tests
                    compare these bitwise.
    """

    def __init__(self, cache, *, eos_id: Optional[int] = None,
                 record_logits: bool = False,
                 sample: Optional[Callable[[np.ndarray], int]] = None,
                 time_fn: Callable[[], float] = time.perf_counter):
        self.cache = cache
        self.kvcfg = cache.kvcfg
        self.n_slots = cache.n_slots
        self.eos_id = eos_id
        self.record_logits = record_logits
        self.sample = sample or (lambda row: int(np.argmax(row)))
        self.time = time_fn
        self.new_q: deque = deque()
        self.resume_q: deque = deque()
        self.slots: List[Optional[Sequence]] = [None] * self.n_slots
        self.finished: List[Sequence] = []
        self.schedule_log: List = []     # (step, event, rid, slot)
        self._next_rid = 0
        self.decode_steps = 0
        self.decode_slot_tokens = 0
        self._live_sum = 0
        self._peak_live = 0
        self._decode_time = 0.0

    # ------------------------------------------------------- intake

    def submit(self, prompt, max_new: int) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) == 0:
            raise ValueError("empty prompt")
        if len(prompt) + max_new > self.kvcfg.max_seq_len:
            raise ValueError(
                f"prompt({len(prompt)}) + max_new({max_new}) exceeds "
                f"max_seq_len={self.kvcfg.max_seq_len}")
        rid = self._next_rid
        self._next_rid += 1
        seq = Sequence(Request(rid, prompt, max_new), self.time())
        self.new_q.append(seq)
        return rid

    # ------------------------------------------------------- helpers

    @property
    def live(self) -> int:
        """Sequences mid-generation: bound to a slot or parked."""
        return (sum(1 for s in self.slots if s is not None)
                + len(self.resume_q))

    def _log(self, event: str, seq: Sequence, slot) -> None:
        self.schedule_log.append((self.decode_steps, event, seq.rid,
                                  slot))
        obs.instant(f"serve.{event}", cat="serve", rid=seq.rid,
                    slot=slot, step=self.decode_steps)

    def _emit_token(self, seq: Sequence, row: np.ndarray) -> int:
        tok = self.sample(row)
        now = self.time()
        if seq.t_first is None:
            seq.t_first = now
        seq.token_times.append(now)
        seq.tokens.append(tok)
        if self.record_logits:
            if seq.logits is None:
                seq.logits = []
            seq.logits.append(np.asarray(row, np.float32))
        return tok

    def _admit_ok(self) -> bool:
        cap = self.kvcfg.max_live
        return not cap or self.live < cap

    def _refill(self) -> None:
        """Admission order: new requests first (up to `max_live`), then
        parked sequences round-robin. New-first is what grows live
        concurrency past the slot count — a preempted sequence waits
        behind fresh admissions, its pages prefetching meanwhile, and
        the quantum guarantees everyone keeps making progress."""
        for slot in range(self.n_slots):
            if self.slots[slot] is not None:
                continue
            if self.new_q and self._admit_ok():
                seq = self.new_q.popleft()
                row = self.cache.start(seq, slot)
                seq.q_used = 0
                self.slots[slot] = seq
                self._log("start", seq, slot)
                tok = self._emit_token(seq, row)
                self.cache.bind_token(seq, tok)
                if self._finish_if_done(seq, slot, tok):
                    continue
            elif self.resume_q:
                seq = self.resume_q.popleft()
                self.cache.restore(seq, slot)
                seq.q_used = 0
                self.slots[slot] = seq
                self._log("resume", seq, slot)

    def _finish_if_done(self, seq: Sequence, slot: int,
                        tok: int) -> bool:
        if seq.done or (self.eos_id is not None and tok == self.eos_id):
            self.cache.release(seq)
            self.slots[slot] = None
            self.finished.append(seq)
            self._log("retire", seq, slot)
            return True
        return False

    # ------------------------------------------------------- main loop

    def step(self) -> None:
        """One scheduler iteration: refill, prefetch, fault-in, decode,
        sample, retire/preempt."""
        self._refill()
        # the refill horizon: sequences about to re-enter decode, in
        # resume order — the same prefix the cache manager consumes as
        # its kv_page reuse hint
        for seq in reuse_horizon(self.resume_q,
                                 depth=self.kvcfg.prefetch_depth):
            self.cache.prefetch(seq)
        active = [(i, s) for i, s in enumerate(self.slots)
                  if s is not None]
        if not active:
            return
        for _, seq in active:
            self.cache.fault_in(seq)
        live = self.live
        self._live_sum += live
        self._peak_live = max(self._peak_live, live)
        obs.gauge("serve.live", live)
        t0 = self.time()
        with obs.span("serve.decode", cat="serve",
                      step=self.decode_steps, active=len(active),
                      live=live):
            logits = self.cache.decode()
        self._decode_time += self.time() - t0
        self.decode_steps += 1
        self.decode_slot_tokens += len(active)
        quantum = self.kvcfg.quantum
        for slot, seq in active:
            tok = self._emit_token(seq, logits[slot])
            self.cache.advance(seq, tok)
            seq.q_used += 1
            if self._finish_if_done(seq, slot, tok):
                continue
            if (quantum and self.cache.can_evict
                    and seq.q_used >= quantum
                    and (self.new_q or self.resume_q)):
                self.cache.evict(seq)
                seq.preemptions += 1
                self.slots[slot] = None
                self.resume_q.append(seq)
                self._log("preempt", seq, slot)

    def run(self) -> ServeReport:
        """Drain every queue and slot; explicit termination — the loop
        ends exactly when no sequence is waiting, parked, or bound."""
        t0 = self.time()
        with obs.span("serve.run", cat="serve",
                      requests=len(self.new_q)):
            while self.new_q or self.resume_q or any(
                    s is not None for s in self.slots):
                self.step()
        wall = self.time() - t0
        return self._report(wall)

    # ------------------------------------------------------- report

    def _report(self, wall: float) -> ServeReport:
        seqs = self.finished
        gen = sum(len(s.tokens) for s in seqs)
        ttft = [(s.t_first - s.t_submit) * 1e3 for s in seqs
                if s.t_first is not None]
        itl = [(b - a) * 1e3 for s in seqs
               for a, b in zip(s.token_times, s.token_times[1:])]
        grid = self.decode_steps * self.n_slots
        r = ServeReport(
            requests=len(seqs),
            n_slots=self.n_slots,
            decode_steps=self.decode_steps,
            prompt_tokens=sum(len(s.prompt) for s in seqs),
            generated_tokens=gen,
            decode_slot_tokens=self.decode_slot_tokens,
            decode_time_s=self._decode_time,
            wall_time_s=wall,
            decode_tok_s=(self.decode_slot_tokens / self._decode_time
                          if self._decode_time else 0.0),
            gen_tok_s=gen / wall if wall else 0.0,
            slot_occupancy=(self.decode_slot_tokens / grid
                            if grid else 0.0),
            peak_live=self._peak_live,
            mean_live=(self._live_sum / self.decode_steps
                       if self.decode_steps else 0.0),
            preemptions=sum(s.preemptions for s in seqs),
            ttft_p50_ms=_pct(ttft, 50), ttft_p99_ms=_pct(ttft, 99),
            itl_p50_ms=_pct(itl, 50), itl_p95_ms=_pct(itl, 95),
            itl_p99_ms=_pct(itl, 99),
            cache_kind=self.cache.kind,
            device_bytes=self.cache.device_bytes,
            kv=self.cache.stats.as_dict(),
        )
        return r
