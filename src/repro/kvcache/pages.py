"""Paged KV-cache primitives: page geometry and the device page
allocator (repro.kvcache).

A *page* holds `page_tokens` consecutive tokens of one sequence's K/V
across every pageable layer (the pool arrays carry the layer dimension,
so one page id addresses the same page slot in every layer's pool —
allocating a page allocates it for the whole layer stack at once, the
blob the spool sees on eviction).

Physical page 0 is the reserved *null page*: idle decode slots (and
table entries past a sequence's allocated length) point at it, so the
jitted decode step never needs a batch-size-dependent branch — inactive
rows scribble their dummy token into page 0 and nobody ever attends to
it (a live sequence's table never contains 0).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List

__all__ = ["KVCacheConfig", "PageAllocator", "PagePoolExhausted"]


class PagePoolExhausted(RuntimeError):
    """The device page pool has no free pages left.

    Raised on a page fault (an actively-decoding slot crossing a page
    boundary) that cannot be satisfied. With the default sizing
    (`pool_pages = n_slots * max_pages + 1`) this cannot happen; it
    can when `pool_pages` is set tighter than the worst case."""


@dataclass(frozen=True)
class KVCacheConfig:
    """Knobs of the paged KV-cache subsystem.

    page_tokens:    tokens per KV page (per layer). Smaller pages waste
                    less pool on short prompts but mean more spool
                    records per eviction.
    pool_pages:     device page-pool size, *including* the reserved
                    null page. 0 -> sized to the worst case,
                    n_slots * max_pages + 1, so active slots can never
                    fault against an exhausted pool.
    max_seq_len:    logical sequence-length cap (prompt + generation).
                    Rounded up to a page multiple; this is also the
                    dense baseline's per-slot cache length, so paged
                    and dense decode see identically-shaped attention.
    prefetch_depth: how many next-up parked sequences get their pages
                    prefetched from the spool while other slots keep
                    decoding (the ISSUE's prefetch-on-slot-refill).
    quantum:        decode tokens a sequence may run before the
                    scheduler preempts it for waiting work (0 = run to
                    retirement; preemption is what turns spare spool
                    capacity into extra live sequences).
    max_live:       admission cap on concurrently live (mid-generation)
                    sequences. 0 = unbounded for the paged cache;
                    the dense cache is always capped at its slot count.
    dtype:          KV pool dtype (the spool's byteplane codec applies
                    to bf16 pages unchanged).
    """
    page_tokens: int = 16
    pool_pages: int = 0
    max_seq_len: int = 256
    prefetch_depth: int = 2
    quantum: int = 0
    max_live: int = 0
    dtype: str = "bfloat16"

    @property
    def max_pages(self) -> int:
        return -(-self.max_seq_len // self.page_tokens)

    @property
    def padded_seq_len(self) -> int:
        """max_seq_len rounded up to a whole number of pages — the
        gathered attention extent, and the dense baseline's cache
        length (kept equal for bitwise parity)."""
        return self.max_pages * self.page_tokens

    def resolve_pool_pages(self, n_slots: int) -> int:
        if self.pool_pages:
            return self.pool_pages
        return n_slots * self.max_pages + 1

    def validate(self) -> "KVCacheConfig":
        assert self.page_tokens > 0, self.page_tokens
        assert self.max_seq_len >= self.page_tokens, \
            (self.max_seq_len, self.page_tokens)
        assert self.prefetch_depth >= 0
        assert self.quantum >= 0
        assert self.max_live >= 0
        if self.pool_pages:
            assert self.pool_pages >= 2, "need >= 1 page beyond the null"
        return self


class PageAllocator:
    """Free-list allocator over physical page ids [1, n_pages).

    Deterministic: freed pages are recycled LIFO, fresh pages are
    handed out in ascending id order — the same request trace always
    produces the same physical placement (the scheduler-determinism
    tests rely on this)."""

    def __init__(self, n_pages: int):
        assert n_pages >= 2, "pool needs the null page plus one"
        self.n_pages = n_pages
        # pop() yields ascending ids for a fresh pool
        self._free: List[int] = list(range(n_pages - 1, 0, -1))
        self.allocated = 0          # lifetime allocs
        self.freed = 0
        self.high_water = 0

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return (self.n_pages - 1) - len(self._free)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise PagePoolExhausted(
                f"need {n} pages, {len(self._free)} free of "
                f"{self.n_pages - 1} (raise pool_pages or lower "
                f"max_live/quantum pressure)")
        out = [self._free.pop() for _ in range(n)]
        self.allocated += n
        self.high_water = max(self.high_water, self.in_use)
        return out

    def free(self, ids: List[int]) -> None:
        for pid in ids:
            assert 0 < pid < self.n_pages, pid
            self._free.append(pid)
        self.freed += len(ids)
