"""Cache-layout adapters between the model's decode caches and the
paged KV pool (repro.kvcache).

A model's decode cache is heterogeneous (models/transformer.py): dense
full-attention K/V grows with the sequence and is *pageable*; a
sliding-window layer's ring cache is a bounded buffer whose slot
layout depends on absolute position; rglru/ssm carry O(1) recurrent
state; cross-attention K/V is a fixed encoder projection. This module
decides, per block, which side of the split a cache entry lands on:

  paged    — full-attention K/V (window 0, or a window at least as
             long as the padded cache — masking makes it full), carved
             into fixed-size pages in a shared device pool;
  resident — everything else, kept as per-slot dense stacks exactly
             like the classic decode cache. Resident entries ride
             evictions as one per-sequence state blob, so a parked
             recurrent or windowed sequence restores bit-exactly too.

It also owns the right-padding rule: bucketing a prompt up to a page
multiple is exact only when every sequence-dependent cache entry is
paged (causal masking hides the pad K/V). Ring slots and recurrent
states integrate pad tokens into their state, so any arch carrying
them prefills at the exact prompt length instead (one jit
specialization per distinct prompt length rather than per bucket).
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dtype_of
from repro.models.transformer import SegmentDef, init_block_cache

__all__ = [
    "is_pageable", "paged_block_ids", "needs_exact_prefill",
    "build_pools", "build_resident", "page_nbytes", "tree_nbytes",
]


def is_pageable(bdef, padded_seq_len: int) -> bool:
    """Full-attention K/V pages; a window >= the padded cache length is
    full attention in disguise (the mask never bites)."""
    return bdef.mixer == "attn" and (
        not bdef.window or bdef.window >= padded_seq_len)


def paged_block_ids(segments: Tuple[SegmentDef, ...],
                    padded_seq_len: int) -> List[set]:
    """Per-segment set of block ids ("b0", ...) whose cache is paged."""
    return [{f"b{i}" for i, b in enumerate(seg.blocks)
             if is_pageable(b, padded_seq_len)}
            for seg in segments]


def needs_exact_prefill(segments: Tuple[SegmentDef, ...],
                        padded_seq_len: int) -> bool:
    """True when right-padding the prompt to a page bucket would leak
    pad tokens into sequence state (ring caches, recurrent state)."""
    for seg in segments:
        for b in seg.blocks:
            if b.mixer in ("rglru", "ssm"):
                return True
            if b.mixer == "attn" and not is_pageable(b, padded_seq_len):
                return True
    return False


def build_pools(segments: Tuple[SegmentDef, ...], cfg: ModelConfig,
                n_pages: int, page_tokens: int, padded_seq_len: int,
                dtype) -> List[Dict]:
    """Device page pools: per segment, {bid: {"k","v"}} with shape
    (n_repeat, n_pages, page_tokens, Hkv, head_dim). Page 0 is the
    null page (pages.py)."""
    dtype = dtype_of(dtype) if isinstance(dtype, str) else dtype
    hd = cfg.resolved_head_dim
    pools: List[Dict] = []
    for seg, ids in zip(segments,
                        paged_block_ids(segments, padded_seq_len)):
        entry = {}
        for i, bdef in enumerate(seg.blocks):
            bid = f"b{i}"
            if bid not in ids:
                continue
            shape = (seg.n_repeat, n_pages, page_tokens,
                     cfg.num_kv_heads, hd)
            entry[bid] = {"k": jnp.zeros(shape, dtype),
                          "v": jnp.zeros(shape, dtype)}
        pools.append(entry)
    return pools


def build_resident(segments: Tuple[SegmentDef, ...], cfg: ModelConfig,
                   n_slots: int, padded_seq_len: int, dtype,
                   paged: List[set] = None) -> List[Dict]:
    """Per-slot dense stacks for the non-paged blocks: per segment,
    {bid: cache_entry} with leading dim n_repeat — the exact layout
    api.decode_step scans, just filtered down to the resident blocks.
    Pass `paged` explicitly to override the split (the dense baseline
    passes empty sets to keep every block resident)."""
    dtype = dtype_of(dtype) if isinstance(dtype, str) else dtype
    resident: List[Dict] = []
    if paged is None:
        paged = paged_block_ids(segments, padded_seq_len)
    for seg, ids in zip(segments, paged):
        entry = {}
        for i, bdef in enumerate(seg.blocks):
            bid = f"b{i}"
            if bid in ids:
                continue
            one = init_block_cache(bdef, cfg, n_slots, padded_seq_len,
                                   dtype)
            entry[bid] = jax.tree.map(
                lambda a: jnp.zeros((seg.n_repeat,) + a.shape, a.dtype),
                one)
        resident.append(entry)
    return resident


def tree_nbytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def page_nbytes(pools: List[Dict]) -> int:
    """Bytes one physical page occupies across every layer's pool."""
    total = 0
    for entry in pools:
        for kv in entry.values():
            for arr in (kv["k"], kv["v"]):
                n_repeat, _, P, H, D = arr.shape
                total += n_repeat * P * H * D * arr.dtype.itemsize
    return total
