"""Paged KV-cache subsystem for serving at scale (repro.kvcache).

Decouples logical sequence length from device residency: K/V lives in
fixed-size pages in shared device pools, each sequence owns a page
table, and cold sequences (preempted or idle) evict their pages through
the activation spool — the same bufpool + aio/fs + byteplane data plane
the trainer streams activations through, reused for serving. Pages of
a sequence entering the refill horizon are prefetched back under the
other slots' decode compute, the SSDTrain overlap argument applied to
inference.

    pages.py      page geometry, KVCacheConfig, the page allocator
    adapters.py   paged/resident split of heterogeneous decode caches
    manager.py    PagedKVCache (spool-backed) and DenseKVCache baseline
    scheduler.py  continuous-batching Server with quantum preemption

`build_manager` is the one-call entry the serve launcher and the bench
use: model api + params + a KVCacheConfig in, a ready manager out.
"""
from __future__ import annotations

from repro.kvcache.manager import DenseKVCache, KVStats, PagedKVCache
from repro.kvcache.pages import (KVCacheConfig, PageAllocator,
                                 PagePoolExhausted)
from repro.kvcache.scheduler import Request, Sequence, Server, ServeReport

__all__ = [
    "KVCacheConfig", "PageAllocator", "PagePoolExhausted",
    "PagedKVCache", "DenseKVCache", "KVStats",
    "Server", "ServeReport", "Request", "Sequence",
    "build_manager",
]


def build_manager(kind: str, api, params, settings, kvcfg: KVCacheConfig,
                  n_slots: int, spool=None):
    """Construct a KV-cache manager: kind in {"paged", "dense"}."""
    if kind == "paged":
        return PagedKVCache(api, params, settings, kvcfg, n_slots, spool)
    if kind == "dense":
        return DenseKVCache(api, params, settings, kvcfg, n_slots)
    raise ValueError(f"unknown KV cache kind {kind!r}")
