"""RG-LRU gated linear recurrence kernel (Pallas, TPU target).

    h_t = exp(log_a_t) * h_{t-1} + x_t

Grid (B, W_blocks, n_chunks); the chunk axis is sequential with the hidden
state h (blk_w,) f32 carried in VMEM scratch. Within a chunk the recurrence
runs as a fori_loop over time steps on the VPU — the recurrence is
elementwise over the width dim, so each step is a (blk_w,)-wide FMA; the
chunking exists to keep the working set in VMEM and to overlap the HBM
streams of log_a / x with compute. (A log-space prefix-scan variant trades
VPU steps for exp/cumsum passes but loses precision when log_a ~ -20 at
init; the sequential form is exact. The chunk loop, not the step loop, is
the HBM-bandwidth determinant.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def _rglru_kernel(a_ref, x_ref, y_ref, h_ref, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)                  # (chunk, blk_w)
    x = x_ref[0].astype(jnp.float32)

    def step(t, carry):
        h = carry
        h = jnp.exp(a[t]) * h + x[t]
        y_ref[0, t] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h


@functools.partial(jax.jit, static_argnames=("chunk", "blk_w", "interpret"))
def rglru_scan_fwd(log_a, x, *, chunk: int = 256, blk_w: int = 512,
                   interpret: bool = False):
    """log_a, x: (B, S, W) f32 -> h: (B, S, W) f32."""
    B, S, W = x.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    blk_w = min(blk_w, W)
    while W % blk_w:
        blk_w //= 2
    n_c = S // chunk
    n_w = W // blk_w

    kernel = functools.partial(_rglru_kernel, chunk=chunk)
    grid = (B, n_w, n_c)
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, blk_w), lambda b, w, c: (b, c, w)),
            pl.BlockSpec((1, chunk, blk_w), lambda b, w, c: (b, c, w)),
        ],
        out_specs=pl.BlockSpec((1, chunk, blk_w), lambda b, w, c: (b, c, w)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((blk_w,), jnp.float32)],
        interpret=interpret,
    )(log_a, x)
    return y
