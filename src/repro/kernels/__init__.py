"""Pallas TPU kernels for the perf-critical compute layers.

The paper itself contributes no kernels (its substrate uses
FlashAttention-2); these cover the hot loops of the assigned architectures:

  flash_attention.py  fused GQA online-softmax attention (FA-2 on TPU)
  ssd_scan.py         Mamba-2 state-space-duality chunked scan
  rglru_scan.py       RG-LRU gated linear recurrence

ops.py exposes the jit + custom_vjp wrappers; ref.py holds the pure-jnp
oracles every kernel is allclose-tested against (interpret=True on CPU).
"""
