"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are deliberately the *simplest correct* formulations (full score
matrix, exact sequential recurrences) — independent of both the kernels and
the production chunked paths in models/, so each of the three
implementations (kernel, production XLA path, oracle) cross-checks the
other two.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30


def attention_reference(q, k, v, *, causal: bool = True, window: int = 0,
                        logit_cap: float = 0.0):
    """Direct softmax attention. q: (B,Sq,Hq,D); k,v: (B,Skv,Hkv,D)."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    kx = jnp.repeat(k, G, axis=2).astype(jnp.float32)  # (B,Skv,Hq,D)
    vx = jnp.repeat(v, G, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kx)
    s = s / jnp.sqrt(jnp.float32(D))
    if logit_cap:
        s = jnp.tanh(s / logit_cap) * logit_cap
    iq = jnp.arange(Sq)[:, None]
    jk = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= jk <= iq
    if window:
        mask &= jk > iq - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vx)
    return o.astype(q.dtype)


def ssd_reference(xh, dA_log, B_s, C_s):
    """Exact sequential SSD recurrence (no chunking).

    xh: (B,S,H,P) f32; dA_log: (B,S,H); B_s, C_s: (B,S,N).
    state_t = exp(dA_log_t) * state_{t-1} + B_t (x) xh_t
    y_t     = C_t . state_t
    Returns (y (B,S,H,P) f32, final state (B,H,P,N) f32)."""
    B, S, H, P = xh.shape
    N = B_s.shape[-1]

    def step(state, inp):
        x_t, a_t, b_t, c_t = inp
        state = (state * jnp.exp(a_t)[:, :, None, None]
                 + jnp.einsum("bn,bhp->bhpn", b_t, x_t))
        y_t = jnp.einsum("bn,bhpn->bhp", c_t, state)
        return state, y_t

    state0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (xh.swapaxes(0, 1).astype(jnp.float32),
          dA_log.swapaxes(0, 1).astype(jnp.float32),
          B_s.swapaxes(0, 1).astype(jnp.float32),
          C_s.swapaxes(0, 1).astype(jnp.float32))
    state, ys = jax.lax.scan(step, state0, xs)
    return ys.swapaxes(0, 1), state


def rglru_reference(log_a, x):
    """Exact sequential h_t = exp(log_a_t) h_{t-1} + x_t over axis 1."""
    def step(h, inp):
        a_t, x_t = inp
        h = jnp.exp(a_t) * h + x_t
        return h, h

    h0 = jnp.zeros(x.shape[:1] + x.shape[2:], jnp.float32)
    _, hs = jax.lax.scan(
        step, h0, (log_a.swapaxes(0, 1).astype(jnp.float32),
                   x.swapaxes(0, 1).astype(jnp.float32)))
    return hs.swapaxes(0, 1)
