"""Mamba-2 SSD (state-space duality) chunked scan kernel (Pallas, TPU).

Grid (B, H, n_chunks); the chunk axis is the innermost sequential dimension
so the inter-chunk state recurrence lives in a VMEM scratch carry of shape
(P, N) f32 per (batch, head) program. Within a chunk the SSD decomposition
runs on the MXU:

    y_intra = (C B^T * exp(La_i - La_j) * causal) @ x          (Q x Q dots)
    y_inter = exp(La) * (C @ state^T)
    state'  = exp(La_last) * state + (x * exp(La_last - La))^T @ B

chunk=128 aligns the quadratic tile with the MXU. Validated against the
pure-jnp oracle (ref.ssd_reference / models.mamba2.ssd_chunked) in
interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, st_ref, state_ref, *,
                chunk: int):
    ci = pl.program_id(2)
    n_c = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0].astype(jnp.float32)            # (Q, P)
    a = a_ref[0, :, 0].astype(jnp.float32)            # (Q,)
    b = b_ref[0].astype(jnp.float32)                  # (Q, N)
    c = c_ref[0].astype(jnp.float32)                  # (Q, N)

    la = jnp.cumsum(a)                                # (Q,)
    # --- intra-chunk quadratic term ---
    g = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, Q)
    dd = la[:, None] - la[None, :]                    # (Q, Q) La_i - La_j
    iq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    m = jnp.where(iq >= jq, jnp.exp(dd), 0.0)
    y = jax.lax.dot_general(g * m, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, P)

    # --- inter-chunk contribution from the carried state ---
    state = state_ref[...]                            # (P, N)
    y += jnp.exp(la)[:, None] * jax.lax.dot_general(
        c, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)           # (Q, P)

    # --- state update ---
    decay_chunk = jnp.exp(la[-1])
    w = jnp.exp(la[-1] - la)[:, None] * x             # (Q, P)
    s_new = jax.lax.dot_general(w, b, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (P,N)
    state_ref[...] = state * decay_chunk + s_new

    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_c - 1)
    def _emit_state():
        st_ref[0, 0] = state_ref[...].astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_fwd(xh, dA_log, B_s, C_s, *, chunk: int = 128,
                 interpret: bool = False):
    """xh: (B, S, H, P) inputs pre-scaled by dt; dA_log: (B, S, H);
    B_s, C_s: (B, S, N). Returns (y (B, S, H, P) f32, state (B,H,P,N) f32).
    S must be divisible by chunk (callers pad)."""
    B, S, H, P = xh.shape
    N = B_s.shape[-1]
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    n_c = S // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    grid = (B, H, n_c)
    y, state = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xh, dA_log, B_s, C_s)
    return y, state
