"""Fused GQA flash attention forward kernel (Pallas, TPU target).

TPU adaptation of FlashAttention-2 (the paper's evaluation substrate, §4.1):
online-softmax tiling with the KV axis as the innermost sequential grid
dimension, carry (m, l, acc) in VMEM scratch, and MXU-aligned (128, 128)
score tiles. GQA is expressed in the index maps: query-head program b reads
kv head b // group_size, so KV tiles are fetched once per group from HBM.

Supports: causal masking, sliding window, logit softcap (gemma2), any
Hq % Hkv == 0. Validated against models.attention.attend_chunked (the pure
jnp oracle in kernels/ref.py) in interpret mode on CPU.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU compiler params are optional under interpret mode
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

NEG_INF = -1.0e30
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window: int, logit_cap: float,
                 blk_q: int, blk_k: int, n_kv: int, kv_len: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (blk_q, D)
    k = k_ref[0].astype(jnp.float32)                  # (blk_k, D)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if logit_cap:
        s = jnp.tanh(s / logit_cap) * logit_cap

    rows = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32,
                                                 (blk_q, blk_k), 0)
    cols = kj * blk_k + jax.lax.broadcasted_iota(jnp.int32,
                                                 (blk_q, blk_k), 1)
    mask = cols < kv_len                  # padded keys are invalid
    if causal:
        mask &= cols <= rows
    if window:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kj == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "logit_cap", "blk_q",
                              "blk_k", "interpret"))
def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        logit_cap: float = 0.0,
                        blk_q: int = DEFAULT_BLOCK_Q,
                        blk_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = False):
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) -> (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)

    # (B*H, S, D) layout; pad sequence to block multiples
    qt = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, D)
    kt = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)
    vt = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)
    blk_q = min(blk_q, max(Sq, 8))
    blk_k = min(blk_k, max(Skv, 8))
    qt, sq0 = _pad_to(qt, 1, blk_q)
    kt, sk0 = _pad_to(kt, 1, blk_k)
    vt, _ = _pad_to(vt, 1, blk_k)
    n_q = qt.shape[1] // blk_q
    n_kv = kt.shape[1] // blk_k

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        logit_cap=logit_cap, blk_q=blk_q, blk_k=blk_k, n_kv=n_kv,
        kv_len=sk0)

    grid = (B * Hq, n_q, n_kv)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_k, D),
                         lambda b, i, j, G=G, Hq=Hq, Hkv=Hkv:
                         ((b // Hq) * Hkv + (b % Hq) // G, j, 0)),
            pl.BlockSpec((1, blk_k, D),
                         lambda b, i, j, G=G, Hq=Hq, Hkv=Hkv:
                         ((b // Hq) * Hkv + (b % Hq) // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :sq0].reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3)
    return out
