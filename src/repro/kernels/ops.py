"""Jitted public wrappers for the Pallas kernels.

Forward runs the fused Pallas kernel; backward is a custom_vjp against the
mathematically identical pure-JAX formulation (recompute-based, the same
residual policy FlashAttention-2 uses: save nothing but inputs, rebuild the
tiles in the backward pass). On TPU the backward would be its own kernel
pair (dq and dkv sweeps); the recompute-vjp here is bit-compatible with
that and keeps the oracle authoritative for gradients.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.rglru_scan import rglru_scan_fwd
from repro.kernels.ssd_scan import ssd_scan_fwd


# ------------------------------------------------------------ attention

@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6))
def _flash_attention(q, k, v, causal, window, logit_cap, interpret):
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               logit_cap=logit_cap, interpret=interpret)


def _fa_fwd(q, k, v, causal, window, logit_cap, interpret):
    out = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              logit_cap=logit_cap, interpret=interpret)
    return out, (q, k, v)


def _fa_bwd(causal, window, logit_cap, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: ref.attention_reference(
            q, k, v, causal=causal, window=window, logit_cap=logit_cap),
        q, k, v)
    return vjp(g)


_flash_attention.defvjp(_fa_fwd, _fa_bwd)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    logit_cap: float = 0.0, interpret: bool = False):
    return _flash_attention(q, k, v, causal, window, logit_cap, interpret)


# ------------------------------------------------------------ SSD scan

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _ssd_scan(xh, dA_log, B_s, C_s, chunk, interpret):
    return ssd_scan_fwd(xh, dA_log, B_s, C_s, chunk=chunk,
                        interpret=interpret)


def _ssd_fwd(xh, dA_log, B_s, C_s, chunk, interpret):
    out = ssd_scan_fwd(xh, dA_log, B_s, C_s, chunk=chunk,
                       interpret=interpret)
    return out, (xh, dA_log, B_s, C_s)


def _ssd_bwd(chunk, interpret, res, g):
    xh, dA_log, B_s, C_s = res
    _, vjp = jax.vjp(
        lambda *a: ref.ssd_reference(*a), xh, dA_log, B_s, C_s)
    return vjp(g)


_ssd_scan.defvjp(_ssd_fwd, _ssd_bwd)


def ssd_scan(xh, dA_log, B_s, C_s, *, chunk: int = 128,
             interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    return _ssd_scan(xh, dA_log, B_s, C_s, chunk, interpret)


# ------------------------------------------------------------ RG-LRU scan

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rglru_scan(log_a, x, interpret):
    return rglru_scan_fwd(log_a, x, interpret=interpret)


def _rg_fwd(log_a, x, interpret):
    return rglru_scan_fwd(log_a, x, interpret=interpret), (log_a, x)


def _rg_bwd(interpret, res, g):
    log_a, x = res
    _, vjp = jax.vjp(lambda a, b: ref.rglru_reference(a, b), log_a, x)
    return vjp(g)


_rglru_scan.defvjp(_rg_fwd, _rg_bwd)


def rglru_scan(log_a, x, *, interpret: bool = False):
    return _rglru_scan(log_a, x, interpret)
