"""Chrome/Perfetto trace-event export + schema validation (`repro.obs`).

`write_chrome_trace` turns a `Tracer`'s rings into the trace-event JSON
format (the "JSON Array Format" with object envelope) that
chrome://tracing and https://ui.perfetto.dev load directly. Three track
groups (pids), so one run reads as three synchronized timelines:

  pid 0  host threads    — every event on its physical thread (spool
                           store/load workers, XLA host-callback
                           threads, the engine's main thread)
  pid 1  shards          — hook/spool events that carry a `shard` arg,
                           re-binned per mesh shard
  pid 2  storage tiers   — backend I/O events re-binned per backend
                           kind (fs / striped / mem / tiered / aio /
                           fault), so a tiered store's RAM-vs-SSD split
                           is a visible lane change

`validate_trace` checks a trace object (or file) against the schema the
exporter promises — CI runs it on every `--trace` artifact so a
malformed trace fails the build, not the engineer who opens it a week
later.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Union

from repro.obs.tracer import Tracer

PID_THREADS = 0
PID_SHARDS = 1
PID_TIERS = 2

_PROCESS_NAMES = {
    PID_THREADS: "repro host threads",
    PID_SHARDS: "mesh shards",
    PID_TIERS: "storage tiers",
}

#: phases the exporter emits / the validator accepts
VALID_PHASES = ("X", "i", "M", "C")


def _meta(pid: int, tid: int, what: str, name: str) -> Dict[str, Any]:
    return {"name": what, "ph": "M", "pid": pid, "tid": tid,
            "ts": 0, "args": {"name": name}}


def trace_events(tracer: Tracer) -> List[Dict[str, Any]]:
    """Flatten the tracer's rings into trace-event dicts (ts/dur in
    microseconds relative to the tracer's epoch)."""
    t0 = tracer.t0_ns
    events: List[Dict[str, Any]] = []
    events.append(_meta(PID_THREADS, 0, "process_name",
                        _PROCESS_NAMES[PID_THREADS]))
    shard_tids: Dict[Any, int] = {}
    tier_tids: Dict[str, int] = {}

    for ring in tracer.rings():
        events.append(_meta(PID_THREADS, ring.ring_id, "thread_name",
                            ring.thread_name))
        for name, cat, ts_ns, dur_ns, args in ring.snapshot():
            base = {
                "name": name,
                "cat": cat or "default",
                "pid": PID_THREADS,
                "tid": ring.ring_id,
                "ts": (ts_ns - t0) / 1e3,
            }
            if dur_ns >= 0:
                base["ph"] = "X"
                base["dur"] = dur_ns / 1e3
            else:
                base["ph"] = "i"
                base["s"] = "t"
            if args:
                base["args"] = args
            events.append(base)

            # shard lane: any event that names its mesh shard
            shard = (args or {}).get("shard")
            if shard is not None:
                tid = shard_tids.setdefault(shard, len(shard_tids))
                events.append({**base, "pid": PID_SHARDS, "tid": tid})
            # tier lane: backend I/O events name their backend kind
            kind = (args or {}).get("kind")
            if kind is not None and name.startswith("io."):
                tid = tier_tids.setdefault(kind, len(tier_tids))
                events.append({**base, "pid": PID_TIERS, "tid": tid})

    if shard_tids:
        events.append(_meta(PID_SHARDS, 0, "process_name",
                            _PROCESS_NAMES[PID_SHARDS]))
        for shard, tid in shard_tids.items():
            events.append(_meta(PID_SHARDS, tid, "thread_name",
                                f"shard {shard}"))
    if tier_tids:
        events.append(_meta(PID_TIERS, 0, "process_name",
                            _PROCESS_NAMES[PID_TIERS]))
        for kind, tid in tier_tids.items():
            events.append(_meta(PID_TIERS, tid, "thread_name",
                                f"tier {kind}"))

    # counters become one "C" sample at export time (rates over the run;
    # the per-step series lives in the metrics JSONL, not the trace)
    counters = tracer.counters()
    if counters:
        events.append({"name": "counters", "ph": "C", "pid": PID_THREADS,
                       "tid": 0, "ts": 0,
                       "args": {k: v for k, v in sorted(counters.items())}})
    return events


def write_chrome_trace(path: str, tracer: Tracer,
                       extra: Optional[Dict[str, Any]] = None) -> str:
    """Write the Perfetto-loadable JSON envelope; returns `path`."""
    doc = {
        "traceEvents": trace_events(tracer),
        "displayTimeUnit": "ms",
        "otherData": {
            "tool": "repro.obs",
            "dropped_events": tracer.dropped(),
            "total_events": tracer.total_events(),
            "open_spans": tracer.open_spans(),
            **(extra or {}),
        },
    }
    with open(path, "w") as f:
        json.dump(doc, f)
    return path


# ----------------------------------------------------------- validation

def validate_trace(trace: Union[str, Dict[str, Any]],
                   expect_cats: tuple = ()) -> List[str]:
    """Validate a trace document (or a path to one) against the
    trace-event schema. Returns a list of human-readable problems —
    empty means valid. `expect_cats` additionally requires at least one
    non-metadata event in each named category (CI asserts the offload
    path actually got instrumented, not just that JSON parsed)."""
    if isinstance(trace, str):
        try:
            with open(trace) as f:
                trace = json.load(f)
        except (OSError, ValueError) as e:
            return [f"unreadable trace: {e}"]
    errors: List[str] = []
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        return ["top level must be an object with a 'traceEvents' list"]
    events = trace["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be a list"]
    seen_cats: set = set()
    for i, ev in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        for field in ("name", "ph", "pid", "tid", "ts"):
            if field not in ev:
                errors.append(f"{where}: missing {field!r}")
        ph = ev.get("ph")
        if ph not in VALID_PHASES:
            errors.append(f"{where}: bad phase {ph!r}")
        if ph == "X":
            if not isinstance(ev.get("dur"), (int, float)) \
                    or ev["dur"] < 0:
                errors.append(f"{where}: complete event needs dur >= 0")
        if ph == "M" and "name" not in ev.get("args", {}):
            errors.append(f"{where}: metadata event needs args.name")
        if not isinstance(ev.get("ts", 0), (int, float)) \
                or ev.get("ts", 0) < 0:
            errors.append(f"{where}: ts must be a non-negative number")
        if ph in ("X", "i"):
            for c in str(ev.get("cat", "")).split(","):
                if c:
                    seen_cats.add(c)
        if len(errors) > 50:
            errors.append("... (truncated)")
            break
    for cat in expect_cats:
        if cat not in seen_cats:
            errors.append(f"no events in expected category {cat!r} "
                          f"(saw: {sorted(seen_cats)})")
    return errors
