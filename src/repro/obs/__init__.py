"""repro.obs — overlap-proving trace and telemetry subsystem.

Always-compiled-in instrumentation for the activation-offload path:
a lock-light per-thread ring tracer (`repro.obs.tracer`), a
Chrome/Perfetto exporter + validator (`repro.obs.export`), and the
overlap analyzer that turns a trace window into I/O-hidden fraction and
stall attribution (`repro.obs.overlap`).

Call sites use the module-level helpers (`span`/`instant`/`count`/
`gauge`), which are a None-check no-op until `enable()` installs a
tracer — usually via `TrainSession(trace=...)` or `--trace`.
"""
from repro.obs.tracer import (
    DEFAULT_RING_SIZE,
    Tracer,
    count,
    disable,
    enable,
    gauge,
    get_tracer,
    instant,
    is_enabled,
    span,
)
from repro.obs.export import trace_events, validate_trace, write_chrome_trace
from repro.obs.overlap import analyze, predicted_vs_measured

__all__ = [
    "DEFAULT_RING_SIZE",
    "Tracer",
    "analyze",
    "count",
    "disable",
    "enable",
    "gauge",
    "get_tracer",
    "instant",
    "is_enabled",
    "predicted_vs_measured",
    "span",
    "trace_events",
    "validate_trace",
    "write_chrome_trace",
]
