"""CLI: validate a trace-event JSON file against the exporter schema.

    python -m repro.obs.validate out.json [--expect spool io codec engine]

Exit 0 when the trace parses, every event satisfies the trace-event
schema, and each `--expect` category has at least one event — the CI
smoke job runs this on the `--trace` artifact so a schema regression
fails the build.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.export import validate_trace


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Validate a repro.obs Chrome trace-event JSON file")
    ap.add_argument("trace", help="path to the trace JSON")
    ap.add_argument("--expect", nargs="*", default=[],
                    help="categories that must contain >=1 event")
    args = ap.parse_args(argv)

    errors = validate_trace(args.trace, expect_cats=tuple(args.expect))
    if errors:
        for e in errors:
            print(f"INVALID: {e}", file=sys.stderr)
        return 1

    with open(args.trace) as f:
        doc = json.load(f)
    n = len(doc.get("traceEvents", []))
    other = doc.get("otherData", {})
    print(f"OK: {args.trace}: {n} events, "
          f"dropped={other.get('dropped_events', '?')}, "
          f"open_spans={other.get('open_spans', '?')}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
