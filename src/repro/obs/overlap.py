"""Overlap analyzer: turn a trace window into the paper's claim.

SSDTrain's pitch is that activation I/O is *hidden* — the SSD traffic
happens while the accelerator computes, so the training loop never
waits. `analyze()` computes that as numbers from a window of trace
events (typically one step, fed from `Tracer.snapshot_new`):

  io_busy_s        union of backend I/O span time (writes + reads)
  exposed_wait_s   union of `spool.fetch_wait` spans — the time a
                   consumer was actually blocked on the spool
  io_hidden_frac   1 - exposed/io_busy, clamped to [0, 1] — the
                   fraction of I/O that compute paid for

plus stall attribution: each exposed fetch-wait interval is intersected
with the same-key backend read and codec decode spans, splitting the
wait into "waiting for the disk", "waiting for the decoder", and the
remainder "waiting in queue" (job not yet scheduled on a load worker).

Counters (from `Tracer.counters()` deltas) contribute prefetch
hit/late/ghost rates. Everything lands in `StepReport.to_metrics()` as
`obs_*` fields, and `predicted_vs_measured` closes the loop against the
dryrun planner's roofline.

Optimizer-state I/O (spool keys prefixed "opt", written by the
opt-overlap bridge) is attributed separately: those spans are excluded
from the activation metrics above and land in `opt_io_busy_s` /
`opt_exposed_wait_s` / `opt_hidden_frac` instead, where "exposed" is
only the time the *training thread* was blocked (`engine.opt_join`
waiting on the side worker, or the serial path's `engine.opt_fetch` /
`engine.opt_stage`) — the side worker blocking on its own disk reads is
the hidden case, not a stall. `opt_hidden_frac` charges a thread block
only for its intersection with opt I/O activity (`opt_exposed_io_s`):
a join that is really riding out the worker's update kernels is compute
exposure, reported via `opt_update_s` and the join span, not I/O the
overlap failed to hide.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.obs.tracer import TraceEvent

Interval = Tuple[int, int]      # (start_ns, end_ns]

#: span names produced by the instrumentation layer (single source of
#: truth so the analyzer and the call sites cannot drift apart)
IO_SPANS = ("io.write", "io.read")
DECODE_SPAN = "codec.decode"
ENCODE_SPAN = "codec.encode"
FETCH_WAIT_SPAN = "spool.fetch_wait"
STORE_SPAN = "spool.store"
LOAD_SPAN = "spool.load"
#: opt-overlap worker spans (side thread, hidden by construction) and
#: the training-thread spans that expose opt-state I/O when it is NOT
#: hidden (join = overlapped path, fetch/stage = serial path)
OPT_WORKER_SPANS = ("opt.fetch", "opt.stage")
OPT_EXPOSED_SPANS = ("engine.opt_join", "engine.opt_fetch",
                     "engine.opt_stage")
OPT_UPDATE_SPAN = "engine.opt_update"
#: spool keys carrying optimizer moments (OptBridge lease ids)
OPT_KEY_PREFIX = "opt"


def _union(intervals: Iterable[Interval]) -> List[Interval]:
    """Merge overlapping intervals; returns a sorted disjoint list."""
    ivs = sorted(i for i in intervals if i[1] > i[0])
    out: List[Interval] = []
    for lo, hi in ivs:
        if out and lo <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], hi))
        else:
            out.append((lo, hi))
    return out


def _total(intervals: Iterable[Interval]) -> int:
    return sum(hi - lo for lo, hi in _union(intervals))


def _intersect(a: Sequence[Interval], b: Sequence[Interval]) -> int:
    """Total overlap (ns) between two disjoint sorted interval lists."""
    total = 0
    i = j = 0
    a = _union(a)
    b = _union(b)
    while i < len(a) and j < len(b):
        lo = max(a[i][0], b[j][0])
        hi = min(a[i][1], b[j][1])
        if hi > lo:
            total += hi - lo
        if a[i][1] < b[j][1]:
            i += 1
        else:
            j += 1
    return total


def _spans(events: Iterable[TraceEvent], names: Tuple[str, ...]
           ) -> List[TraceEvent]:
    return [ev for ev in events if ev[0] in names and ev[3] >= 0]


def _is_opt(ev: TraceEvent) -> bool:
    """True for spans keyed to an optimizer-moment spool lease."""
    key = ev[4].get("key")
    return isinstance(key, str) and key.startswith(OPT_KEY_PREFIX)


def _iv(ev: TraceEvent) -> Interval:
    return (ev[2], ev[2] + ev[3])


def analyze(events: Sequence[TraceEvent],
            counters: Optional[Dict[str, float]] = None
            ) -> Dict[str, Any]:
    """Analyze one window of trace events (see module docstring).

    `counters` is a delta of `Tracer.counters()` over the same window;
    prefetch rates are 0 when absent. All durations come back in
    seconds, fractions in [0, 1]."""
    keyed = _spans(events, IO_SPANS + (FETCH_WAIT_SPAN, STORE_SPAN,
                                       LOAD_SPAN))
    opt_keyed = [ev for ev in keyed if _is_opt(ev)]
    act = [ev for ev in keyed if not _is_opt(ev)]

    io = _spans(act, IO_SPANS)
    waits = _spans(act, (FETCH_WAIT_SPAN,))
    decodes = [ev for ev in _spans(events, (DECODE_SPAN,))
               if not _is_opt(ev)]
    encodes = [ev for ev in _spans(events, (ENCODE_SPAN,))
               if not _is_opt(ev)]
    stores = _spans(act, (STORE_SPAN,))
    loads = _spans(act, (LOAD_SPAN,))

    # opt-state I/O attribution: busy is everything the moment leases
    # kept the datapath doing (worker-side waits included — they are
    # hidden work, not stalls); exposed is training-thread time only.
    # Like the activation stall attribution below, the hidden fraction
    # charges a thread block only for the part spent over actual opt
    # I/O activity — a join riding out the side worker's jitted update
    # kernels is compute exposure (visible as engine.opt_update /
    # engine.opt_join spans), not I/O the overlap failed to hide
    opt_busy = opt_keyed + _spans(events, OPT_WORKER_SPANS)
    opt_exposed = _spans(events, OPT_EXPOSED_SPANS)
    opt_updates = _spans(events, (OPT_UPDATE_SPAN,))
    opt_busy_iv = _union(map(_iv, opt_busy))
    opt_exposed_iv = _union(map(_iv, opt_exposed))
    opt_busy_ns = _total(opt_busy_iv)
    opt_exposed_ns = _total(opt_exposed_iv)
    opt_exposed_io_ns = _intersect(opt_exposed_iv, opt_busy_iv)
    if opt_busy_ns > 0:
        opt_hidden = 1.0 - opt_exposed_io_ns / opt_busy_ns
    else:
        opt_hidden = 1.0 if opt_exposed_ns == 0 else 0.0

    io_busy_ns = _total(map(_iv, io))
    exposed_ns = _total(map(_iv, waits))

    # stall attribution: for each exposed wait, how much of it was the
    # same key's disk read vs. decode; the rest was queueing
    reads_by_key: Dict[Any, List[Interval]] = {}
    for ev in io:
        if ev[0] == "io.read":
            reads_by_key.setdefault(ev[4].get("key"), []).append(_iv(ev))
    dec_by_key: Dict[Any, List[Interval]] = {}
    for ev in decodes:
        dec_by_key.setdefault(ev[4].get("key"), []).append(_iv(ev))

    stall_read_ns = 0
    stall_decode_ns = 0
    for ev in waits:
        key = ev[4].get("key")
        w = [_iv(ev)]
        stall_read_ns += _intersect(w, reads_by_key.get(key, []))
        stall_decode_ns += _intersect(w, dec_by_key.get(key, []))
    stall_queue_ns = max(0, exposed_ns - stall_read_ns - stall_decode_ns)

    if io_busy_ns > 0:
        hidden = 1.0 - min(exposed_ns, io_busy_ns) / io_busy_ns
    else:
        hidden = 1.0 if exposed_ns == 0 else 0.0

    c = counters or {}
    issued = c.get("prefetch.issued", 0)
    res = {
        "io_busy_s": io_busy_ns / 1e9,
        "exposed_wait_s": exposed_ns / 1e9,
        "io_hidden_frac": hidden,
        "stall_read_s": stall_read_ns / 1e9,
        "stall_decode_s": stall_decode_ns / 1e9,
        "stall_queue_s": stall_queue_ns / 1e9,
        "encode_s": _total(map(_iv, encodes)) / 1e9,
        "decode_s": _total(map(_iv, decodes)) / 1e9,
        "store_s": _total(map(_iv, stores)) / 1e9,
        "load_s": _total(map(_iv, loads)) / 1e9,
        "opt_io_busy_s": opt_busy_ns / 1e9,
        "opt_exposed_wait_s": opt_exposed_ns / 1e9,
        "opt_exposed_io_s": opt_exposed_io_ns / 1e9,
        "opt_hidden_frac": opt_hidden,
        "opt_update_s": _total(map(_iv, opt_updates)) / 1e9,
        "prefetch_issued": int(issued),
        "prefetch_hit": int(c.get("prefetch.hit", 0)),
        "prefetch_late": int(c.get("prefetch.late", 0)),
        "prefetch_ghost": int(c.get("prefetch.ghost", 0)),
    }
    res["prefetch_hit_rate"] = (
        res["prefetch_hit"] / issued if issued else 0.0)
    return res


def predicted_vs_measured(predicted: Dict[str, Any],
                          measured: Dict[str, Any]) -> Dict[str, Any]:
    """Compare a dryrun `predicted_overlap` block against a measured
    `analyze()` result — the TierBandwidth calibration check. Returns
    the paired numbers plus the hidden-fraction error."""
    p_hidden = float(predicted.get("io_hidden_frac", 0.0))
    m_hidden = float(measured.get("io_hidden_frac", 0.0))
    out = {
        "predicted_io_s": float(predicted.get("t_io_s", 0.0)),
        "measured_io_s": float(measured.get("io_busy_s", 0.0)),
        "predicted_hidden_frac": p_hidden,
        "measured_hidden_frac": m_hidden,
        "hidden_frac_error": m_hidden - p_hidden,
    }
    # opt-state lane: present only when the prediction priced it (the
    # dryrun's eager-update timeline) so legacy pairings stay unchanged
    if "t_opt_io_s" in predicted:
        po = float(predicted.get("opt_hidden_frac", 0.0))
        mo = float(measured.get("opt_hidden_frac", 0.0))
        out.update({
            "predicted_opt_io_s": float(predicted["t_opt_io_s"]),
            "measured_opt_io_s": float(
                measured.get("opt_io_busy_s", 0.0)),
            "predicted_opt_hidden_frac": po,
            "measured_opt_hidden_frac": mo,
            "opt_hidden_frac_error": mo - po,
        })
    return out
