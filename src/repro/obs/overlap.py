"""Overlap analyzer: turn a trace window into the paper's claim.

SSDTrain's pitch is that activation I/O is *hidden* — the SSD traffic
happens while the accelerator computes, so the training loop never
waits. `analyze()` computes that as numbers from a window of trace
events (typically one step, fed from `Tracer.snapshot_new`):

  io_busy_s        union of backend I/O span time (writes + reads)
  exposed_wait_s   union of `spool.fetch_wait` spans — the time a
                   consumer was actually blocked on the spool
  io_hidden_frac   1 - exposed/io_busy, clamped to [0, 1] — the
                   fraction of I/O that compute paid for

plus stall attribution: each exposed fetch-wait interval is intersected
with the same-key backend read and codec decode spans, splitting the
wait into "waiting for the disk", "waiting for the decoder", and the
remainder "waiting in queue" (job not yet scheduled on a load worker).

Counters (from `Tracer.counters()` deltas) contribute prefetch
hit/late/ghost rates. Everything lands in `StepReport.to_metrics()` as
`obs_*` fields, and `predicted_vs_measured` closes the loop against the
dryrun planner's roofline.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.obs.tracer import TraceEvent

Interval = Tuple[int, int]      # (start_ns, end_ns]

#: span names produced by the instrumentation layer (single source of
#: truth so the analyzer and the call sites cannot drift apart)
IO_SPANS = ("io.write", "io.read")
DECODE_SPAN = "codec.decode"
ENCODE_SPAN = "codec.encode"
FETCH_WAIT_SPAN = "spool.fetch_wait"
STORE_SPAN = "spool.store"
LOAD_SPAN = "spool.load"


def _union(intervals: Iterable[Interval]) -> List[Interval]:
    """Merge overlapping intervals; returns a sorted disjoint list."""
    ivs = sorted(i for i in intervals if i[1] > i[0])
    out: List[Interval] = []
    for lo, hi in ivs:
        if out and lo <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], hi))
        else:
            out.append((lo, hi))
    return out


def _total(intervals: Iterable[Interval]) -> int:
    return sum(hi - lo for lo, hi in _union(intervals))


def _intersect(a: Sequence[Interval], b: Sequence[Interval]) -> int:
    """Total overlap (ns) between two disjoint sorted interval lists."""
    total = 0
    i = j = 0
    a = _union(a)
    b = _union(b)
    while i < len(a) and j < len(b):
        lo = max(a[i][0], b[j][0])
        hi = min(a[i][1], b[j][1])
        if hi > lo:
            total += hi - lo
        if a[i][1] < b[j][1]:
            i += 1
        else:
            j += 1
    return total


def _spans(events: Iterable[TraceEvent], names: Tuple[str, ...]
           ) -> List[TraceEvent]:
    return [ev for ev in events if ev[0] in names and ev[3] >= 0]


def _iv(ev: TraceEvent) -> Interval:
    return (ev[2], ev[2] + ev[3])


def analyze(events: Sequence[TraceEvent],
            counters: Optional[Dict[str, float]] = None
            ) -> Dict[str, Any]:
    """Analyze one window of trace events (see module docstring).

    `counters` is a delta of `Tracer.counters()` over the same window;
    prefetch rates are 0 when absent. All durations come back in
    seconds, fractions in [0, 1]."""
    io = _spans(events, IO_SPANS)
    waits = _spans(events, (FETCH_WAIT_SPAN,))
    decodes = _spans(events, (DECODE_SPAN,))
    encodes = _spans(events, (ENCODE_SPAN,))
    stores = _spans(events, (STORE_SPAN,))
    loads = _spans(events, (LOAD_SPAN,))

    io_busy_ns = _total(map(_iv, io))
    exposed_ns = _total(map(_iv, waits))

    # stall attribution: for each exposed wait, how much of it was the
    # same key's disk read vs. decode; the rest was queueing
    reads_by_key: Dict[Any, List[Interval]] = {}
    for ev in io:
        if ev[0] == "io.read":
            reads_by_key.setdefault(ev[4].get("key"), []).append(_iv(ev))
    dec_by_key: Dict[Any, List[Interval]] = {}
    for ev in decodes:
        dec_by_key.setdefault(ev[4].get("key"), []).append(_iv(ev))

    stall_read_ns = 0
    stall_decode_ns = 0
    for ev in waits:
        key = ev[4].get("key")
        w = [_iv(ev)]
        stall_read_ns += _intersect(w, reads_by_key.get(key, []))
        stall_decode_ns += _intersect(w, dec_by_key.get(key, []))
    stall_queue_ns = max(0, exposed_ns - stall_read_ns - stall_decode_ns)

    if io_busy_ns > 0:
        hidden = 1.0 - min(exposed_ns, io_busy_ns) / io_busy_ns
    else:
        hidden = 1.0 if exposed_ns == 0 else 0.0

    c = counters or {}
    issued = c.get("prefetch.issued", 0)
    res = {
        "io_busy_s": io_busy_ns / 1e9,
        "exposed_wait_s": exposed_ns / 1e9,
        "io_hidden_frac": hidden,
        "stall_read_s": stall_read_ns / 1e9,
        "stall_decode_s": stall_decode_ns / 1e9,
        "stall_queue_s": stall_queue_ns / 1e9,
        "encode_s": _total(map(_iv, encodes)) / 1e9,
        "decode_s": _total(map(_iv, decodes)) / 1e9,
        "store_s": _total(map(_iv, stores)) / 1e9,
        "load_s": _total(map(_iv, loads)) / 1e9,
        "prefetch_issued": int(issued),
        "prefetch_hit": int(c.get("prefetch.hit", 0)),
        "prefetch_late": int(c.get("prefetch.late", 0)),
        "prefetch_ghost": int(c.get("prefetch.ghost", 0)),
    }
    res["prefetch_hit_rate"] = (
        res["prefetch_hit"] / issued if issued else 0.0)
    return res


def predicted_vs_measured(predicted: Dict[str, Any],
                          measured: Dict[str, Any]) -> Dict[str, Any]:
    """Compare a dryrun `predicted_overlap` block against a measured
    `analyze()` result — the TierBandwidth calibration check. Returns
    the paired numbers plus the hidden-fraction error."""
    p_hidden = float(predicted.get("io_hidden_frac", 0.0))
    m_hidden = float(measured.get("io_hidden_frac", 0.0))
    return {
        "predicted_io_s": float(predicted.get("t_io_s", 0.0)),
        "measured_io_s": float(measured.get("io_busy_s", 0.0)),
        "predicted_hidden_frac": p_hidden,
        "measured_hidden_frac": m_hidden,
        "hidden_frac_error": m_hidden - p_hidden,
    }
