"""Lock-light per-thread ring-buffer event tracer (`repro.obs`).

SSDTrain's headline claim — activation I/O fully overlapped with
compute — is only provable from the inside with a timeline: when did
each store/fetch/prefetch run, on which thread, and how long was the
consumer actually blocked. This tracer is the substrate:

  * one bounded ring buffer PER THREAD, appended only by its owning
    thread — the hot path takes no lock and allocates one tuple per
    event; a global lock guards only ring creation and snapshots;
  * span (begin/end, recorded as one complete event at exit) and
    instant events, timestamped with `time.perf_counter_ns` (monotonic,
    comparable across threads of one process);
  * bounded memory: a full ring overwrites its oldest events and counts
    every overwrite (`dropped` is exact: `max(0, total - capacity)`);
  * a thread-safe counter/gauge table (`add`/`set_gauge`/`counters`)
    for rates the timeline cannot express (prefetch hit/late/ghost,
    pool hits, queue backlogs).

The module-level helpers (`span`, `instant`, `count`) are the
always-compiled-in call sites the rest of the repo uses: when no tracer
is enabled they cost one global read and a None check, so tracing can
stay wired into the spool/backend/engine hot paths permanently.

Event layout (plain tuples, no classes, for append speed):

    (name, cat, ts_ns, dur_ns, args)    dur_ns >= 0  -> complete span
    (name, cat, ts_ns, -1,     args)    instant event
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional, Tuple

#: default ring capacity per thread (events); one event is ~100 bytes,
#: so the default bounds each thread at roughly 6 MB
DEFAULT_RING_SIZE = 1 << 16

TraceEvent = Tuple[str, str, int, int, dict]


class _Ring:
    """One thread's bounded event buffer. Appended only by the owning
    thread; snapshot from other threads is lock-free and sees a
    consistent prefix (CPython list-slot stores are atomic)."""

    __slots__ = ("events", "capacity", "total", "ring_id", "tid",
                 "thread_name", "open_depth")

    def __init__(self, capacity: int, ring_id: int, tid: int,
                 thread_name: str):
        # grown by append until capacity, then overwritten in place —
        # pre-allocating [None]*capacity would put a multi-ms list
        # allocation on the first event of every thread
        self.events: List[Optional[TraceEvent]] = []
        self.capacity = capacity
        self.total = 0              # events ever pushed (monotonic)
        self.ring_id = ring_id
        self.tid = tid
        self.thread_name = thread_name
        self.open_depth = 0         # spans entered but not yet exited

    def push(self, ev: TraceEvent) -> None:
        if self.total < self.capacity:
            self.events.append(ev)
        else:
            self.events[self.total % self.capacity] = ev
        self.total += 1

    @property
    def dropped(self) -> int:
        """Events overwritten because the ring was full — exact."""
        return max(0, self.total - self.capacity)

    def snapshot(self, start: int = 0) -> List[TraceEvent]:
        """Events [start, total) still resident, in record order.
        Entries already overwritten are silently absent (they are
        accounted in `dropped`)."""
        total = self.total
        lo = max(start, total - self.capacity, 0)
        return [self.events[i % self.capacity] for i in range(lo, total)]


class _Span:
    """Context manager recording one complete ("X") event at exit."""

    __slots__ = ("_tracer", "_name", "_cat", "_args", "_t0", "_ring")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: dict):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._args = args

    def __enter__(self) -> "_Span":
        self._ring = self._tracer._ring()
        self._ring.open_depth += 1
        self._t0 = self._tracer._clock()
        return self

    def set(self, **args: Any) -> None:
        """Attach args discovered mid-span (e.g. bytes read)."""
        self._args.update(args)

    def __exit__(self, exc_type, exc, tb) -> None:
        t1 = self._tracer._clock()
        ring = self._ring
        ring.open_depth -= 1
        ring.push((self._name, self._cat, self._t0, t1 - self._t0,
                   self._args))


class _NullSpan:
    """Shared no-op span for the disabled fast path."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def set(self, **args: Any) -> None:
        pass

    def __exit__(self, exc_type, exc, tb) -> None:
        pass


_NULL_SPAN = _NullSpan()


class Tracer:
    """Process-wide event sink; see module docstring. Usually driven
    through the module-level `enable()` / `span()` / `instant()` /
    `count()` helpers rather than instantiated directly (unit tests
    instantiate directly to keep state local)."""

    def __init__(self, ring_size: int = DEFAULT_RING_SIZE,
                 clock: Optional[Any] = None):
        if ring_size < 1:
            raise ValueError(f"ring_size must be >= 1, got {ring_size}")
        self.ring_size = ring_size
        # one clock everywhere; injectable so tests can drive virtual
        # time instead of asserting against wall-clock under load
        self._clock = clock or time.perf_counter_ns
        self.t0_ns = self._clock()              # export epoch
        self._local = threading.local()
        self._rings: List[_Ring] = []
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}

    # -------------------------------------------------------- recording

    def _ring(self) -> _Ring:
        ring = getattr(self._local, "ring", None)
        if ring is None:
            t = threading.current_thread()
            with self._lock:
                ring = _Ring(self.ring_size, len(self._rings),
                             t.ident or 0, t.name)
                self._rings.append(ring)
            self._local.ring = ring
        return ring

    def span(self, name: str, cat: str = "", args: Optional[dict] = None
             ) -> _Span:
        return _Span(self, name, cat, args or {})

    def instant(self, name: str, cat: str = "",
                args: Optional[dict] = None) -> None:
        self._ring().push((name, cat, self._clock(), -1,
                           args or {}))

    def add(self, name: str, n: float = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + n

    def set_gauge(self, name: str, value: float) -> None:
        with self._lock:
            self._counters[name] = value

    # -------------------------------------------------------- snapshots

    def counters(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._counters)

    def rings(self) -> List[_Ring]:
        with self._lock:
            return list(self._rings)

    def open_spans(self) -> int:
        """Spans currently entered and not exited, across all threads —
        0 after a quiesced run means every begin had a matching end."""
        return sum(r.open_depth for r in self.rings())

    def dropped(self) -> int:
        """Total events overwritten across all rings."""
        return sum(r.dropped for r in self.rings())

    def total_events(self) -> int:
        """Total events ever recorded (resident + dropped)."""
        return sum(r.total for r in self.rings())

    def snapshot(self) -> List[TraceEvent]:
        """Every resident event, merged across threads, in start-time
        order."""
        out: List[TraceEvent] = []
        for ring in self.rings():
            out.extend(ring.snapshot())
        out.sort(key=lambda ev: ev[2])
        return out

    def snapshot_new(self, cursor: Optional[Dict[int, int]] = None
                     ) -> Tuple[List[TraceEvent], Dict[int, int]]:
        """Incremental snapshot: events recorded since `cursor` (a
        ring_id -> total map from the previous call), plus the new
        cursor. O(new events), so a per-step caller never rescans the
        whole run."""
        cursor = cursor or {}
        out: List[TraceEvent] = []
        new_cursor: Dict[int, int] = {}
        for ring in self.rings():
            out.extend(ring.snapshot(cursor.get(ring.ring_id, 0)))
            new_cursor[ring.ring_id] = ring.total
        out.sort(key=lambda ev: ev[2])
        return out, new_cursor


# ======================================================================
# Module-level tracer (the always-compiled-in call sites)
# ======================================================================

_TRACER: Optional[Tracer] = None


def enable(ring_size: int = DEFAULT_RING_SIZE) -> Tracer:
    """Install the process tracer (idempotent: an already-enabled
    tracer is kept, ring_size is ignored then)."""
    global _TRACER
    if _TRACER is None:
        _TRACER = Tracer(ring_size)
    return _TRACER


def disable() -> None:
    """Drop the process tracer (its events die with it)."""
    global _TRACER
    _TRACER = None


def get_tracer() -> Optional[Tracer]:
    return _TRACER


def is_enabled() -> bool:
    return _TRACER is not None


def span(name: str, cat: str = "", **args: Any):
    """`with obs.span("io.write", cat="io", key=k, bytes=n): ...` —
    a no-op singleton when tracing is disabled."""
    t = _TRACER
    if t is None:
        return _NULL_SPAN
    return _Span(t, name, cat, args)


def instant(name: str, cat: str = "", **args: Any) -> None:
    t = _TRACER
    if t is not None:
        t.instant(name, cat, args)


def count(name: str, n: float = 1) -> None:
    t = _TRACER
    if t is not None:
        t.add(name, n)


def gauge(name: str, value: float) -> None:
    t = _TRACER
    if t is not None:
        t.set_gauge(name, value)
