"""repro.session — the unified training front door.

One import surface for everything a training driver needs:

    from repro.session import TrainSession, AdaptivePolicy, SpoolIoConfig

    with TrainSession("small-gpt", engine="staged",
                      policy=AdaptivePolicy(),
                      io=SpoolIoConfig(backend="striped")) as sess:
        result = sess.run(100)

`TrainSession` owns config resolution, engine selection (staged | jit),
the ActivationSpool, checkpointing, and metrics; `OffloadPolicy` objects
replace the legacy `strategy: str` + `adaptive: bool` kwargs (which
still work everywhere as deprecation shims).
"""
from repro.configs.base import SpoolIoConfig
from repro.core.policies import (AdaptivePolicy, KeepPolicy,
                                 OffloadPolicy, RecomputePolicy,
                                 SpoolPolicy, resolve_policy)
from repro.core.report import StepReport
from repro.session.session import (ENGINES, SessionResult, TrainSession,
                                   resolve_config)

__all__ = [
    "TrainSession", "SessionResult", "ENGINES", "resolve_config",
    "OffloadPolicy", "KeepPolicy", "SpoolPolicy", "RecomputePolicy",
    "AdaptivePolicy", "resolve_policy",
    "StepReport", "SpoolIoConfig",
]
