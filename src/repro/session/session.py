"""TrainSession — one front door for both training engines.

The paper's interoperability claim (SSDTrain plugs into any framework
behind one hook-based API) maps here to a single facade that owns:

  * config resolution      — arch strings ("small-gpt", "qwen2.5-3b:reduced",
                             "gpt-h256-l4") or a ModelConfig
  * engine selection       — "staged" (per-module TBA path, real spool I/O)
                             or "jit" (whole-step XLA, fault-tolerant loop)
  * placement policy       — an `OffloadPolicy` object (staged engine)
  * the ActivationSpool    — built from one `SpoolIoConfig` for EITHER
                             engine: the staged engine spools per-module
                             residuals; the jit engine stages optimizer
                             state between steps
                             (`io.host_offload="opt_state"`) or streams
                             per-layer residuals from inside the jitted
                             step through repro.core.hooks
                             (`io.host_offload="activations"`)
  * checkpointing          — periodic async checkpoints + resume
  * metrics                — one unified `StepReport` stream / JSONL
                             schema regardless of engine

    with TrainSession("small-gpt", engine="staged",
                      policy=AdaptivePolicy()) as sess:
        result = sess.run(100)
    print(result.final_loss)
"""
from __future__ import annotations

import dataclasses
import json
import shutil
import tempfile
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Union

import numpy as np

import jax

from repro import obs
from repro.ckpt.checkpoint import (CheckpointManager, restore_train_state,
                                   save_train_state)
from repro.configs import ARCH_IDS, get_config, reduced
from repro.configs.base import ModelConfig, SpoolIoConfig
from repro.configs.paper_models import gpt, small_bert, small_gpt
from repro.core.policies import OffloadPolicy, resolve_policy
from repro.core.report import StepReport
from repro.core.spool import build_spool
from repro.core.staged import StagedTrainer
from repro.data.pipeline import ShardedLoader, SyntheticMarkovLM
from repro.launch.steps import make_host_train_step
from repro.models.api import build_model
from repro.models.transformer import RunSettings
from repro.parallel.sharding import (MeshAxes, param_specs,
                                     spec_tree_for_optstate)
from repro.optim.optimizers import Optimizer, adamw, sgd
from repro.runtime.trainer import (StragglerWatchdog, TrainLoop,
                                   TrainState, batch_tokens)

ENGINES = ("staged", "jit")


def resolve_config(name: str) -> ModelConfig:
    """Arch string -> ModelConfig. Accepts: assigned ids, '<id>:reduced',
    gpt-124m, small-gpt/small-bert, or gpt-h<H>-l<L>."""
    if name == "gpt-124m":
        return dataclasses.replace(
            gpt(768, 12, vocab=32768), num_heads=12, num_kv_heads=12,
            head_dim=64)
    if name == "small-gpt":
        return small_gpt()
    if name == "small-bert":
        return small_bert()
    if name.endswith(":reduced"):
        return reduced(get_config(name[:-len(":reduced")]))
    if name in ARCH_IDS:
        return get_config(name)
    if name.startswith("gpt-h"):
        h, l = name[5:].split("-l")
        return gpt(int(h), int(l))
    raise ValueError(f"unknown arch {name!r}")


def _resolve_optimizer(optimizer: Union[str, Optimizer],
                       lr: float) -> Optimizer:
    if isinstance(optimizer, Optimizer):
        return optimizer
    if optimizer == "adamw":
        return adamw(lr)
    if optimizer == "sgd":
        return sgd(lr)
    raise ValueError(f"unknown optimizer {optimizer!r}")


# one throughput rule for both engines (labels >= 0 are real targets)
_batch_tokens = batch_tokens


@dataclass
class SessionResult:
    """What a `TrainSession.run` hands back."""
    engine: str
    state: TrainState
    reports: List[StepReport] = field(default_factory=list)

    @property
    def losses(self) -> List[float]:
        return [r.loss for r in self.reports]

    @property
    def final_loss(self) -> float:
        return self.reports[-1].loss if self.reports else float("nan")


class TrainSession:
    """Facade over the staged (TBA) and jit engines; see module docstring.

    Every knob that used to be an engine-specific kwarg is one argument
    here, interpreted identically for both engines wherever it applies.
    """

    def __init__(self, arch: Union[str, ModelConfig] = "small-gpt", *,
                 engine: str = "staged",
                 policy: Union[OffloadPolicy, str, None] = None,
                 io: Optional[SpoolIoConfig] = None,
                 optimizer: Union[str, Optimizer] = "adamw",
                 lr: float = 3e-4,
                 batch_size: int = 8, seq_len: int = 256,
                 seed: int = 0, microbatches: int = 1,
                 settings: Optional[RunSettings] = None,
                 mesh: Any = None,
                 mesh_axes: Optional[MeshAxes] = None,
                 loader: Any = None,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
                 keep_last: int = 3,
                 metrics_path: Optional[str] = None,
                 spool_dir: Optional[str] = None,
                 min_offload_elements: Optional[int] = None,
                 trace: Optional[str] = None,
                 trace_ring: int = 0,
                 opt_overlap: Union[bool, str, None] = None,
                 install_signal_handlers: bool = False):
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine!r}; "
                             f"expected one of {ENGINES}")
        if engine == "jit" and policy is not None:
            raise ValueError(
                "OffloadPolicy applies to the staged engine; the jit "
                "engine fixes activation placement at trace time "
                "(RunSettings.activation_policy) and uses "
                "io.host_offload ('opt_state' between-step staging or "
                "'activations' per-layer hooks). To drive the jit "
                "engine from a profiled AdaptivePolicy, pass "
                "settings=policy.plan_for_jit().apply(settings)")
        if mesh is not None and engine != "jit":
            raise ValueError(
                "mesh-sharded training is a jit-engine feature; the "
                "staged engine runs per-module jit calls on one device")
        self.engine = engine
        self.mesh = mesh
        self.mesh_axes = None
        if mesh is not None:
            self.mesh_axes = mesh_axes or MeshAxes(
                dp=tuple(a for a in mesh.axis_names if a != "model"),
                tp=("model" if "model" in mesh.axis_names else None))
        self.cfg = (resolve_config(arch) if isinstance(arch, str)
                    else arch.validate())
        self.io = io.validate() if io is not None else None
        # eager per-layer optimizer overlap (repro.optim.overlap):
        # session kwarg wins, else the io config's knob. Truthy values:
        # True (overlapped worker) or "sync" (same kernels/taps, updates
        # applied in finish_step — the same-compile serial reference).
        if opt_overlap is None:
            opt_overlap = (self.io.opt_overlap
                           if self.io is not None else False)
        self.opt_overlap = opt_overlap
        if opt_overlap and engine != "jit":
            raise ValueError("opt_overlap is a jit-engine feature (the "
                             "staged engine already updates per stage)")
        self.api = build_model(self.cfg)
        self.optimizer = _resolve_optimizer(optimizer, lr)
        self.seed = seed
        self.microbatches = microbatches
        self.metrics_path = metrics_path
        self.ckpt_every = ckpt_every
        self.keep_last = keep_last
        self.install_signal_handlers = install_signal_handlers
        self.reports: List[StepReport] = []
        self._metrics_f = None
        self._state: Optional[TrainState] = None
        self._loop: Optional[TrainLoop] = None
        self._owned_tmpdirs: List[str] = []
        self._closed = False

        # repro.obs: trace export path + whether this session installed
        # the process tracer (and so must tear it down). The per-step
        # snapshot state feeds _step_deltas() so metrics rows are
        # per-step, not run-cumulative.
        self.trace_path = trace
        self._owns_tracer = False
        self._tracer = None
        if trace is not None or trace_ring:
            self._owns_tracer = not obs.is_enabled()
            self._tracer = obs.enable(trace_ring or obs.DEFAULT_RING_SIZE)
        self._stats_snapshot = None
        self._shard_snapshot: dict = {}
        self._obs_cursor = None
        self._counters_snapshot: dict = {}
        self._cache_snapshot = None
        self._resil_snapshot: dict = {}

        if loader is None:
            loader = ShardedLoader(
                SyntheticMarkovLM(self.cfg.vocab_size, seed=seed),
                global_batch=batch_size, seq_len=seq_len)
        self.loader = loader
        self._loader_iter = None

        if ckpt_dir is None:
            # the jit engine's TrainLoop always commits a final
            # checkpoint; park it somewhere we clean up
            ckpt_dir = tempfile.mkdtemp(prefix="session_ckpt_")
            self._owned_tmpdirs.append(ckpt_dir)
        self.ckpt_dir = ckpt_dir

        self._hook_bridge = None
        self._opt_bridge = None
        self._optb_snapshot: dict = {}
        if engine == "staged":
            self.policy = resolve_policy(policy)
            self.settings = settings or RunSettings(
                attn_impl="xla", attn_chunk=256,
                param_dtype=self.cfg.dtype)
            self.trainer = StagedTrainer(
                self.api, self.settings, self.optimizer,
                policy=self.policy, io_config=self.io,
                spool_dir=spool_dir,
                num_microbatches=microbatches,
                min_offload_elements=min_offload_elements)
            self.spool = self.trainer.spool
            self._ckpt = CheckpointManager(ckpt_dir, keep_last=keep_last)
        else:
            self.policy = None
            self.trainer = None
            self._ckpt = None       # TrainLoop owns its manager
            mode = self.io.host_offload if self.io is not None else "none"
            self.spool = None
            if mode != "none" or self.opt_overlap:
                # opt overlap needs a spool even when no host_offload
                # mode is set — the per-layer moment leases live on it
                self.spool, owned = build_spool(
                    self.io, spool_dir=spool_dir,
                    min_offload_elements=min_offload_elements)
                self._owned_tmpdirs += owned
            if mode == "activations" and settings is not None \
                    and settings.activation_policy != "spool":
                raise ValueError(
                    "io.host_offload='activations' requires "
                    "settings.activation_policy='spool' (got "
                    f"{settings.activation_policy!r}); either drop the "
                    "'activations' mode or let the session synthesize "
                    "the settings. A JitOffloadPlan that kept every "
                    "layer on device (activation_policy='keep') needs "
                    "no spool — run without host_offload='activations'")
            self.settings = settings or RunSettings(
                attn_impl="xla", attn_chunk=256,
                activation_policy=("spool" if mode == "activations"
                                   else "remat"),
                param_dtype=self.cfg.dtype)
            if self.mesh is not None and self.settings.mesh is None:
                # user settings (or the synthesized defaults) predate
                # the mesh choice: fill in the sharding hints so the
                # model partitions and the hooks see the mesh
                self.settings = dataclasses.replace(
                    self.settings, mesh=self.mesh,
                    tp_axis=self.mesh_axes.tp,
                    dp_axes=self.mesh_axes.dp)
            if mode == "activations" \
                    and self.settings.activation_policy == "spool":
                # per-layer residual streaming: the hooks inside the
                # jitted step talk to the spool through this bridge
                from repro.core.hooks import HookBridge
                self._hook_bridge = HookBridge(
                    self.spool,
                    dedupe_replicas=(self.io.dedupe_replicas
                                     if self.io is not None else True),
                    fetch_fallback=(
                        getattr(self.io, "on_fetch_fail", "recompute")
                        == "recompute" if self.io is not None else True))
                self.settings = dataclasses.replace(
                    self.settings, hook_bridge=self._hook_bridge)
            if self.opt_overlap:
                from repro.launch.steps import make_overlap_train_step
                from repro.optim.overlap import OptBridge
                self._opt_bridge = OptBridge(
                    self.optimizer, self.spool,
                    eager=(self.opt_overlap != "sync"))
                self.settings = dataclasses.replace(
                    self.settings, opt_sink=self._opt_bridge)
                self._step_fn = make_overlap_train_step(
                    self.api, self.optimizer, self.settings,
                    self._opt_bridge, mesh=self.mesh,
                    axes=self.mesh_axes)
            else:
                self._step_fn = make_host_train_step(
                    self.api, self.optimizer, self.settings,
                    mesh=self.mesh, axes=self.mesh_axes)

    # ------------------------------------------------------------ state

    def init(self) -> TrainState:
        """Initialise (or return the current) model/optimizer state.
        With a mesh, params are placed with the production sharding
        rules (fsdp+tp) and the optimizer state inherits them (ZeRO);
        the step counter replicates."""
        if self._state is None:
            params = self.api.init(jax.random.key(self.seed))
            if self.mesh is not None:
                from jax.sharding import NamedSharding
                from jax.sharding import PartitionSpec as P
                p_specs = param_specs(self.cfg, params, self.mesh,
                                      self.mesh_axes, fsdp=True)
                as_sh = lambda s: NamedSharding(self.mesh, s)  # noqa: E731
                params = jax.device_put(
                    params, jax.tree.map(
                        as_sh, p_specs,
                        is_leaf=lambda x: isinstance(x, P)))
                opt_state = self.optimizer.init(params)
                o_specs = spec_tree_for_optstate(p_specs, opt_state)
                opt_state = jax.device_put(
                    opt_state, jax.tree.map(
                        as_sh, o_specs,
                        is_leaf=lambda x: isinstance(x, P)))
            else:
                opt_state = self.optimizer.init(params)
            self._state = TrainState(0, params, opt_state)
        return self._state

    @property
    def state(self) -> Optional[TrainState]:
        return self._state

    @property
    def n_params(self) -> int:
        return sum(x.size for x in jax.tree.leaves(self.init().params))

    @property
    def watchdog(self) -> Optional[StragglerWatchdog]:
        return self._loop.watchdog if self._loop is not None else None

    # ------------------------------------------------------------- run

    def run(self, num_steps: int, *, resume: bool = False,
            on_report: Optional[Callable[[StepReport], None]] = None) \
            -> SessionResult:
        """Train for `num_steps` optimizer steps; returns the final
        state plus the unified per-step reports."""
        if self._closed:
            raise RuntimeError("session is closed")
        self.init()
        start = len(self.reports)   # result carries THIS run's reports
        if self.engine == "staged":
            self._run_staged(num_steps, resume=resume,
                             on_report=on_report)
        else:
            self._run_jit(num_steps, resume=resume, on_report=on_report)
        return SessionResult(self.engine, self._state,
                             list(self.reports[start:]))

    def _step_deltas(self):
        """Per-step observability snapshot-and-diff, called once at each
        step boundary: spool stats delta (fixes the old cumulative-in-
        JSONL rows), per-shard HookBridge traffic delta, and the overlap
        analysis of this step's (incremental) trace window."""
        stats_delta = None
        if self.spool is not None:
            cur = self.spool.stats.snapshot()
            prev = self._stats_snapshot
            stats_delta = cur.sub(prev) if prev is not None else cur
            self._stats_snapshot = cur
        shard_delta = None
        if self._hook_bridge is not None:
            cur_sh = self._hook_bridge.stats_by_shard()
            prev_sh = self._shard_snapshot
            shard_delta = {}
            for shard, rec in cur_sh.items():
                prev_rec = prev_sh.get(shard, {})
                d = {k: v - prev_rec.get(k, 0) for k, v in rec.items()}
                if any(d.values()):
                    name = "global" if shard is None else str(shard)
                    shard_delta[name] = d
            self._shard_snapshot = cur_sh
        obs_delta = None
        tracer = obs.get_tracer()
        if tracer is not None:
            from repro.obs import overlap
            events, self._obs_cursor = tracer.snapshot_new(
                self._obs_cursor)
            counters = tracer.counters()
            prev_c = self._counters_snapshot
            delta_c = {k: v - prev_c.get(k, 0)
                       for k, v in counters.items()}
            self._counters_snapshot = counters
            obs_delta = overlap.analyze(events, delta_c)
        cache_delta = None
        cm = getattr(self.spool, "cache_manager", None) \
            if self.spool is not None else None
        if cm is not None:
            cache_delta, self._cache_snapshot = \
                cm.metrics_delta(self._cache_snapshot)
        resil_delta = self._resilience_delta()
        return (stats_delta, shard_delta, obs_delta, cache_delta,
                resil_delta)

    #: resilience counters that grow monotonically and are emitted as
    #: per-step differences (gauges like health ride along un-diffed)
    _RESIL_MONOTONIC = ("store_retries", "load_retries",
                        "fetch_fallbacks", "replans",
                        "rebalanced_chunks", "chunk_write_failures")

    def _resilience_delta(self):
        """Per-step resilience block: retry / fallback / re-plan /
        rebalance counter deltas plus current backend-health gauges.
        Present on every step that has a spool (zeros on healthy runs),
        so consumers can rely on the columns existing."""
        if self.spool is None:
            return None
        from repro.resilience import unwrap_chain
        cur: dict = {}
        st = self.spool.stats
        cur["store_retries"] = st.store_retries
        cur["load_retries"] = st.load_retries
        cur["fetch_fallbacks"] = st.fetch_fallbacks
        if self.policy is not None and hasattr(self.policy, "replans"):
            cur["replans"] = self.policy.replans
        for b in unwrap_chain(self.spool.backend):
            if hasattr(b, "rebalanced_chunks"):
                cur["rebalanced_chunks"] = b.rebalanced_chunks
                cur["chunk_write_failures"] = b.chunk_write_failures
                break
        prev = self._resil_snapshot
        delta = {k: v - prev.get(k, 0) for k, v in cur.items()
                 if k in self._RESIL_MONOTONIC}
        self._resil_snapshot = cur
        health = getattr(self.spool, "health", None)
        if health is not None:
            delta["health"] = health.snapshot()["health"]
        for b in unwrap_chain(self.spool.backend):
            if hasattr(b, "devices_down"):
                delta["devices_down"] = sum(b.devices_down())
                break
        return delta

    def _emit(self, rep: StepReport,
              on_report: Optional[Callable]) -> None:
        self.reports.append(rep)
        if self.metrics_path:
            if self._metrics_f is None:
                self._metrics_f = open(self.metrics_path, "a")
            self._metrics_f.write(json.dumps(rep.to_metrics()) + "\n")
            self._metrics_f.flush()
        if on_report:
            on_report(rep)

    # ---------------------------------------------------- staged engine

    def _staged_resume(self) -> bool:
        restored = restore_train_state(
            self._ckpt, self._state.params, self._state.opt_state,
            self.loader)
        if restored is None:
            return False
        self._state = TrainState(*restored)
        return True

    def _staged_save(self, final: bool = False) -> None:
        save_train_state(self._ckpt, self._state.step,
                         self._state.params, self._state.opt_state,
                         self.loader, final=final)

    def _run_staged(self, num_steps, *, resume, on_report):
        if resume:
            self._staged_resume()
        if self._loader_iter is None:
            self._loader_iter = iter(self.loader)
        params, opt_state = self._state.params, self._state.opt_state
        step = self._state.step
        for _ in range(num_steps):
            batches = [next(self._loader_iter)
                       for _ in range(self.microbatches)]
            params, opt_state, rep = self.trainer.train_step(
                params, opt_state, batches)
            step += 1
            rep.step = step
            (rep.stats, rep.shard_stats, rep.obs, rep.cache,
             rep.resilience) = self._step_deltas()
            tokens = sum(_batch_tokens(b) for b in batches)
            rep.tokens_per_s = tokens / rep.step_time \
                if rep.step_time else 0.0
            self._state = TrainState(step, params, opt_state)
            self._emit(rep, on_report)
            if self.ckpt_every and step % self.ckpt_every == 0:
                self._staged_save()
        self._staged_save(final=True)

    # ------------------------------------------------------- jit engine

    def _run_jit(self, num_steps, *, resume, on_report):
        def on_step(step, dt, metrics, batch):
            tokens = _batch_tokens(batch)
            extra = {}
            for k, v in (metrics or {}).items():
                try:
                    extra[k] = float(v)
                except (TypeError, ValueError):
                    pass
            stats_d, shard_d, obs_d, cache_d, resil_d = \
                self._step_deltas()
            if self._opt_bridge is not None:
                cur = self._opt_bridge.stats()
                prev = self._optb_snapshot
                extra.update({k: cur[k] - prev.get(k, 0) for k in cur})
                self._optb_snapshot = cur
            rep = StepReport(
                loss=extra.get("loss", float("nan")),
                step_time=dt, step=step, engine="jit",
                stats=stats_d,
                tokens_per_s=tokens / dt if dt else 0.0,
                extra=extra, obs=obs_d, shard_stats=shard_d,
                cache=cache_d, resilience=resil_d)
            self._emit(rep, on_report)

        if self._loop is None:
            self._loop = TrainLoop(
                step_fn=self._step_fn, init_state=self._state,
                loader=self.loader, ckpt_dir=self.ckpt_dir,
                ckpt_every=self.ckpt_every, keep_last=self.keep_last,
                watchdog=StragglerWatchdog(),
                spool=self.spool,
                host_offload=(self.io.host_offload
                              if self.io is not None else "none"),
                opt_bridge=self._opt_bridge,
                install_signal_handlers=self.install_signal_handlers)
        self._loop.on_step = on_step
        self._loop.state = self._state
        if resume:
            self._loop.resume()
        self._state = self._loop.run(num_steps)

    # ----------------------------------------------------------- close

    def close(self) -> None:
        """Idempotent teardown: engines, spool, metrics file, and any
        temp directories this session created."""
        if self._closed:
            return
        self._closed = True
        if self.trainer is not None:
            self.trainer.close()
        if self._loop is not None:
            self._loop.close()
        if self._hook_bridge is not None:
            self._hook_bridge.close()      # drop aborted-step leases
        if self._opt_bridge is not None:
            self._opt_bridge.close()       # stop worker, drop moment leases
        if self.engine == "jit" and self.spool is not None:
            self.spool.close()
        if self._ckpt is not None:
            self._ckpt.wait()
        if self._metrics_f is not None:
            self._metrics_f.close()
        # export the trace after every engine/spool quiesced, so the
        # timeline is complete and all spans are closed
        if self._tracer is not None and self.trace_path:
            from repro.obs.export import write_chrome_trace
            write_chrome_trace(self.trace_path, self._tracer,
                               extra={"engine": self.engine,
                                      "arch": self.cfg.name})
        if self._owns_tracer:
            obs.disable()
        for d in self._owned_tmpdirs:
            shutil.rmtree(d, ignore_errors=True)

    def __enter__(self) -> "TrainSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
