from repro.optim.optimizers import (OptState, adamw, clip_by_global_norm,
                                    sgd, zero1_shardings)

__all__ = ["sgd", "adamw", "OptState", "clip_by_global_norm",
           "zero1_shardings"]
