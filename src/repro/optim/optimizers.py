"""Optimizers (functional, optax-style but dependency-free).

SGD matches the paper's evaluation choice (§4.1: "we use SGD instead of Adam
as the optimizer to reduce the memory use by optimizer states"); AdamW is the
production default. ZeRO-1 sharding of the optimizer state is expressed as a
PartitionSpec tree (zero1_shardings) consumed by the launcher.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any        # first moment (AdamW) or momentum (SGD); None-tree if off
    nu: Any        # second moment (AdamW only)


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any], Tuple[Any, OptState]]
    name: str = "opt"
    #: global-norm clip threshold the fused `update` applies (None = off).
    #: Exposed so schedulers can tell whether the update needs all grads
    #: at once — per-layer eager updates are only valid when this is None.
    clip_norm: Optional[float] = None
    #: per-leaf kernel `(p, m, v, g, step) -> (new_p, new_m, new_v)` with
    #: math identical to the fused `update` (step is the post-increment
    #: step index, i.e. `state.step + 1`). `m`/`v` are None for
    #: optimizers without that moment. Drives the eager overlapped path.
    leaf_update: Optional[Callable] = None


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale)
                        .astype(g.dtype), grads), gn


def sgd(lr: float = 1e-3, momentum: float = 0.0,
        clip_norm: Optional[float] = None) -> Optimizer:
    def init(params):
        mu = (jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
              if momentum else None)
        return OptState(jnp.zeros((), jnp.int32), mu, None)

    def update(grads, state, params):
        if clip_norm:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        if momentum:
            mu = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state.mu, grads)
            upd = mu
        else:
            mu, upd = None, grads
        new_params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32)
                          - lr * u.astype(jnp.float32)).astype(p.dtype),
            params, upd)
        return new_params, OptState(state.step + 1, mu, None)

    def leaf_update(p, m, v, g, step):
        del v, step
        if momentum:
            mu = momentum * m + g.astype(jnp.float32)
            u = mu
        else:
            mu, u = None, g
        new_p = (p.astype(jnp.float32)
                 - lr * u.astype(jnp.float32)).astype(p.dtype)
        return new_p, mu, None

    return Optimizer(init, update, "sgd", clip_norm=clip_norm,
                     leaf_update=leaf_update)


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          clip_norm: Optional[float] = 1.0,
          warmup_steps: int = 0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(jnp.zeros((), jnp.int32),
                        jax.tree.map(z, params), jax.tree.map(z, params))

    def update(grads, state, params):
        if clip_norm:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        sched = jnp.minimum(1.0, step / max(warmup_steps, 1)) \
            if warmup_steps else 1.0
        lr_t = lr * sched
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1)
                          * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                          * jnp.square(g.astype(jnp.float32)),
                          state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            u = mhat / (jnp.sqrt(vhat) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, OptState(step, mu, nu)

    def leaf_update(p, m, v, g, step):
        sched = jnp.minimum(1.0, step / max(warmup_steps, 1)) \
            if warmup_steps else 1.0
        lr_t = lr * sched
        mu = b1 * m + (1 - b1) * g.astype(jnp.float32)
        nu = b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32))
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        mhat = mu / bc1
        vhat = nu / bc2
        u = mhat / (jnp.sqrt(vhat) + eps)
        u = u + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)
        return new_p, mu, nu

    return Optimizer(init, update, "adamw", clip_norm=clip_norm,
                     leaf_update=leaf_update)


def zero1_shardings(params_specs, dp_axes: Tuple[str, ...]):
    """ZeRO-1: shard optimizer moments over the data axes on each leaf's
    largest unsharded dimension (falls back to the param's own spec)."""
    def shard_one(spec: P):
        parts = list(spec) if spec else []
        if not parts:
            return P(dp_axes)  # shard dim0 of an otherwise replicated leaf
        for i, p_ in enumerate(parts):
            if p_ is None:
                parts[i] = dp_axes
                return P(*parts)
        return P(*parts)

    return jax.tree.map(shard_one, params_specs,
                        is_leaf=lambda x: isinstance(x, P))
