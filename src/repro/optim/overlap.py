"""Eager per-layer optimizer updates overlapped with backward
(`repro.optim.overlap`).

The serial jit step runs fwd -> bwd -> one fused optimizer tail, so
with ``host_offload="opt_state"`` every step pays the whole opt-state
round trip exposed between steps. GreedySnake-style scheduling hides
it: the moment layer *i*'s parameter gradients materialize inside
backward (streamed out by the grad taps in `repro.core.hooks`), layer
*i*'s moments are fetched from the spool, the update runs, and the new
moments are staged back — all while XLA is still computing layer
*i-1*'s backward. `OptBridge` is that side stream:

  * `on_grads(step, stage, leaves)` is the tap endpoint. It runs on an
    XLA host-callback thread, so it does nothing but enqueue — the
    leaves were already copied by the tap and the callback must never
    touch the jax runtime (see `repro.core.hostcb`).
  * a plain Python worker thread drains the queue: per stage it peeks
    the stage's moment lease (`engine.opt_fetch` would be the exposed
    serial span — here the fetch hides under backward), prefetches the
    next stages in backward-arrival order `prefetch_depth` ahead
    (`reuse_horizon`, same hint path as activation fetches; the default
    depth of 2 keeps the read for a tap that fires right after the
    current one already in flight), applies the optimizer's
    per-leaf `leaf_update` kernel (jitted XLA — a numpy re-derivation
    is NOT bitwise-identical to the fused update, XLA contracts FMAs),
    and stages the new moments back under the next step's lease.
  * write-back policy: moments whose bytes did not change (zero-grad
    layers, frozen params) keep their existing lease instead of
    rewriting the SSD; the saved traffic is counted in
    `spool.stats.opt_skipped_bytes`.
  * `finish_step` joins the worker after the main thread has blocked
    on the grads (`engine.opt_join` — the only exposure the overlap
    leaves), updates the non-scanned rest of the tree with the same
    kernels, and reassembles the stacked parameters.

Bitwise contract: the per-leaf kernels share their math with the fused
`Optimizer.update`, and the update order per leaf is independent, so
eager (worker) and sync (``eager=False``, drain-in-finish_step) modes
produce identical bytes by construction. Global-norm clipping needs
every gradient before any update and is therefore incompatible with
eager per-layer updates — callers must hand the bridge a clip-free
optimizer (`TrainSession` raises otherwise).

Moment leases are per (step, stage): ``spool.step(f"opt{step}L{stage}")``
with the payload at stage key 0, so the spool keys
(``opt{step}L{stage}_s0``) keep the ``opt`` prefix the cache manager's
opt_state class and the obs overlap analyzer classify on.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs
from repro.cache.horizon import reuse_horizon
from repro.core.hooks import ENC_STAGE_BASE
from repro.optim.optimizers import Optimizer, OptState

#: how long finish_step waits for the worker to drain before declaring
#: the step lost (a tap that never fired or a wedged backend)
DEFAULT_JOIN_TIMEOUT_S = 120.0

_SCAN_KEYS = (("segments", 0), ("enc_segments", ENC_STAGE_BASE))


def _layout_from(params) -> Dict[int, tuple]:
    """stage -> (tree_key, segment index, in-segment layer index), for
    every scanned layer — stage numbering mirrors models.api
    (decoder 0-based, encoder offset by ENC_STAGE_BASE)."""
    layout: Dict[int, tuple] = {}
    for tree_key, base in _SCAN_KEYS:
        stacks = params.get(tree_key) if isinstance(params, dict) else None
        if not stacks:
            continue
        layer0 = 0
        for si, stack in enumerate(stacks):
            n = int(jax.tree.leaves(stack)[0].shape[0])
            for li in range(n):
                layout[base + layer0 + li] = (tree_key, si, li)
            layer0 += n
    return layout


def _arrival_order(layout) -> List[int]:
    """Expected backward arrival order of the grad taps: decoder stages
    descending (backward walks the decoder top-down first), then the
    encoder stages descending."""
    dec = sorted((s for s in layout if s < ENC_STAGE_BASE), reverse=True)
    enc = sorted((s for s in layout if s >= ENC_STAGE_BASE), reverse=True)
    return dec + enc


def _rest(tree) -> dict:
    """The non-scanned subtree (embed/unembed/norms/...)."""
    return {k: v for k, v in tree.items()
            if k not in ("segments", "enc_segments")}


class OptBridge:
    """Side-stream endpoint for eager per-layer optimizer updates.

    Lifecycle per step (driven by `launch.steps.make_overlap_train_step`):
    ``seed`` (once, lazily) -> ``begin_step`` -> taps arrive via
    ``on_grads`` while backward runs -> ``finish_step``. ``materialize``
    reassembles the full OptState for checkpoints and run end.
    """

    def __init__(self, optimizer: Optimizer, spool, *, eager: bool = True,
                 prefetch_depth: int = 2,
                 join_timeout: float = DEFAULT_JOIN_TIMEOUT_S):
        if optimizer.leaf_update is None:
            raise ValueError(
                f"optimizer {optimizer.name!r} has no per-leaf update "
                f"kernel — eager overlap needs Optimizer.leaf_update")
        if optimizer.clip_norm:
            raise ValueError(
                "eager per-layer updates are incompatible with global-norm "
                "clipping (the clip needs every gradient before any "
                "update) — build the optimizer with clip_norm=None")
        self.optimizer = optimizer
        self.spool = spool
        self.eager = eager
        self.prefetch_depth = prefetch_depth
        self.join_timeout = join_timeout
        self._leaf_fn = jax.jit(optimizer.leaf_update)
        self.seeded = False
        self._step: int = 0
        self._has_m = False
        self._has_n = False
        self._rest_m: Any = None
        self._rest_n: Any = None
        self._mom_tx: Dict[int, Any] = {}      # stage -> live lease
        self._layout: Dict[int, tuple] = {}
        self._order: List[int] = []
        self._pos: Dict[int, int] = {}
        self._seg_meta: Dict[tuple, tuple] = {}    # (key, si) -> (treedef, n)
        self._seg_leaves: Dict[tuple, tuple] = {}  # (key, si) -> (leaves, treedef, n)
        self._results: Dict[int, List[Any]] = {}   # stage -> new param leaves
        self._pending: set = set()
        self._error: Optional[BaseException] = None
        self._cv = threading.Condition()
        self._queue: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._closed = False
        self._moment_bytes = 0
        self.counters = {"opt_updates": 0, "opt_stage_skips": 0,
                         "opt_fetched_bytes": 0, "opt_staged_bytes": 0,
                         "opt_skipped_bytes": 0}

    # ------------------------------------------------------------ seeding

    def seed(self, opt_state: OptState, params) -> None:
        """Adopt a full OptState: scanned-layer moments are split per
        stage and staged to the spool; the rest of the tree stays in
        memory. Idempotent via `seeded`."""
        if self.seeded:
            return
        self._step = int(opt_state.step)
        self._layout = _layout_from(params)
        self._order = _arrival_order(self._layout)
        self._pos = {s: i for i, s in enumerate(self._order)}
        for tree_key, _ in _SCAN_KEYS:
            stacks = params.get(tree_key)
            if not stacks:
                continue
            for si, stack in enumerate(stacks):
                leaves, treedef = jax.tree.flatten(stack)
                self._seg_meta[(tree_key, si)] = (
                    treedef, int(leaves[0].shape[0]))
        self._has_m = opt_state.mu is not None
        self._has_n = opt_state.nu is not None
        if self._has_m:
            self._rest_m = _rest(opt_state.mu)
        if self._has_n:
            self._rest_n = _rest(opt_state.nu)
        if self._has_m:
            for stage, (key, si, li) in self._layout.items():
                payload = self._slice_moments(opt_state, key, si, li)
                tx = self.spool.step(f"opt{self._step}L{stage}")
                tx.offload(0, payload)
                self._mom_tx[stage] = tx
                self._moment_bytes += int(
                    sum(a.nbytes for a in payload))
        self.seeded = True
        if self.eager and self._worker is None:
            self._worker = threading.Thread(
                target=self._worker_loop, name="opt-overlap", daemon=True)
            self._worker.start()

    def ensure_seeded(self, opt_state: OptState, params) -> None:
        self.seed(opt_state, params)

    def _slice_moments(self, opt_state, key, si, li) -> List[np.ndarray]:
        out = [np.asarray(leaf[li], np.float32)
               for leaf in jax.tree.leaves(opt_state.mu[key][si])]
        if self._has_n:
            out += [np.asarray(leaf[li], np.float32)
                    for leaf in jax.tree.leaves(opt_state.nu[key][si])]
        return out

    # ------------------------------------------------------ per-step API

    def begin_step(self, params, step: int) -> None:
        """Arm the bridge for one step: record the stacked param leaves
        the worker will slice, reset the pending-stage set, and warm the
        first expected fetch."""
        if step != self._step:
            raise RuntimeError(
                f"opt bridge is at step {self._step}, got {step}")
        if self._error is not None:
            raise RuntimeError("opt bridge failed on a previous step") \
                from self._error
        self._seg_leaves = {}
        for tree_key, _ in _SCAN_KEYS:
            stacks = params.get(tree_key)
            if not stacks:
                continue
            for si, stack in enumerate(stacks):
                leaves, treedef = jax.tree.flatten(stack)
                self._seg_leaves[(tree_key, si)] = (
                    leaves, treedef, int(leaves[0].shape[0]))
        self._results = {}
        with self._cv:
            self._pending = set(self._layout)
        for s in reuse_horizon(self._order, depth=self.prefetch_depth):
            tx = self._mom_tx.get(s)
            if tx is not None:
                tx.prefetch(0)

    def on_grads(self, step: int, stage: int, leaves) -> None:
        """Grad-tap endpoint — XLA host-callback thread. Enqueue only:
        nothing here may touch jax or block."""
        self._queue.put((step, stage, leaves))

    def _worker_loop(self) -> None:
        while True:
            item = self._queue.get()
            if item is None:
                return
            step, stage, gleaves = item
            try:
                self._process(step, stage, gleaves)
            except BaseException as e:  # surfaced by finish_step
                with self._cv:
                    if self._error is None:
                        self._error = e
            finally:
                with self._cv:
                    self._pending.discard(stage)
                    self._cv.notify_all()

    def _process(self, step: int, stage: int, gleaves) -> None:
        info = self._layout.get(stage)
        if info is None:
            raise KeyError(f"grad tap for unknown stage {stage}")
        key, si, li = info
        p_leaves, _, _ = self._seg_leaves[(key, si)]
        new_step = step + 1
        n = len(gleaves)

        old_payload: Optional[List[np.ndarray]] = None
        if self._has_m:
            tx = self._mom_tx[stage]
            with obs.span("opt.fetch", cat="opt", step=step, stage=stage,
                          key=tx.step_id) as sp:
                old_payload = [np.asarray(a) for a in
                               tx.peek(0, to_device=False)]
                nbytes = int(sum(a.nbytes for a in old_payload))
                sp.set(bytes=nbytes)
            self.counters["opt_fetched_bytes"] += nbytes
            # one stage ahead (§3.3.2 applied to moments): warm the next
            # expected arrival while this stage's update computes
            pos = self._pos[stage]
            for nxt in reuse_horizon(self._order[pos + 1:],
                                     depth=self.prefetch_depth):
                ntx = self._mom_tx.get(nxt)
                if ntx is not None:
                    ntx.prefetch(0)

        step_arr = jnp.asarray(new_step, jnp.int32)
        new_p: List[Any] = []
        new_m: List[Any] = []
        new_v: List[Any] = []
        with obs.span("engine.opt_update", cat="engine", step=step,
                      stage=stage):
            for j in range(n):
                m_j = old_payload[j] if self._has_m else None
                v_j = old_payload[n + j] if self._has_n else None
                p_j, m_out, v_out = self._leaf_fn(
                    p_leaves[j][li], m_j, v_j, gleaves[j], step_arr)
                new_p.append(p_j)
                if self._has_m:
                    new_m.append(m_out)
                if self._has_n:
                    new_v.append(v_out)
        self._results[stage] = new_p
        self.counters["opt_updates"] += 1

        if not self._has_m:
            return
        payload = [np.asarray(a, np.float32) for a in new_m + new_v]
        unchanged = all(a.tobytes() == b.tobytes()
                        for a, b in zip(payload, old_payload))
        if unchanged:
            # write-back policy: the lease we already hold is
            # byte-identical — keep it instead of rewriting the SSD
            nbytes = int(sum(a.nbytes for a in payload))
            self.spool.stats.opt_skipped_bytes += nbytes
            self.counters["opt_stage_skips"] += 1
            self.counters["opt_skipped_bytes"] += nbytes
            obs.instant("opt.stage_skip", cat="opt", step=step,
                        stage=stage, bytes=nbytes)
            return
        with obs.span("opt.stage", cat="opt", step=step, stage=stage,
                      key=f"opt{new_step}L{stage}") as sp:
            ntx = self.spool.step(f"opt{new_step}L{stage}")
            ntx.offload(0, payload)
            nbytes = int(sum(a.nbytes for a in payload))
            sp.set(bytes=nbytes)
        self.counters["opt_staged_bytes"] += nbytes
        old_tx, self._mom_tx[stage] = self._mom_tx[stage], ntx
        old_tx.close()

    def finish_step(self, params, grads):
        """Join the side stream, update the non-scanned rest of the tree
        with the same kernels, and reassemble the stacked params.
        Returns ``(new_params, OptState(step+1, None, None))`` — the
        moments stay on the spool / in the bridge."""
        with obs.span("engine.opt_join", cat="engine", step=self._step):
            if self.eager:
                deadline = (threading.TIMEOUT_MAX if self.join_timeout
                            is None else self.join_timeout)
                with self._cv:
                    ok = self._cv.wait_for(
                        lambda: not self._pending or self._error,
                        timeout=deadline)
                    if not ok:
                        missing = sorted(self._pending)
                        raise RuntimeError(
                            f"opt overlap join timed out after "
                            f"{self.join_timeout:.0f}s; stages never "
                            f"tapped: {missing}")
            else:
                while self._pending and self._error is None:
                    try:
                        step, stage, gleaves = self._queue.get_nowait()
                    except queue.Empty:
                        missing = sorted(self._pending)
                        raise RuntimeError(
                            f"grad taps missing for stages {missing} — "
                            f"was the tapped program run?") from None
                    try:
                        self._process(step, stage, gleaves)
                    except BaseException as e:
                        self._error = e
                    finally:
                        self._pending.discard(stage)
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(
                "eager optimizer update failed mid-backward") from err

        new_step = self._step + 1
        step_arr = jnp.asarray(new_step, jnp.int32)
        rest_p, treedef = jax.tree.flatten(_rest(params))
        rest_g = jax.tree.leaves(_rest(grads))
        rest_m = (jax.tree.leaves(self._rest_m) if self._has_m
                  else [None] * len(rest_p))
        rest_n = (jax.tree.leaves(self._rest_n) if self._has_n
                  else [None] * len(rest_p))
        out_p, out_m, out_n = [], [], []
        for p, m, v, g in zip(rest_p, rest_m, rest_n, rest_g):
            np_, nm_, nv_ = self._leaf_fn(p, m, v, g, step_arr)
            out_p.append(np_)
            out_m.append(nm_)
            out_n.append(nv_)
        new_params = jax.tree.unflatten(treedef, out_p)
        if self._has_m:
            self._rest_m = jax.tree.unflatten(treedef, out_m)
        if self._has_n:
            self._rest_n = jax.tree.unflatten(treedef, out_n)

        for tree_key, _ in _SCAN_KEYS:
            if not params.get(tree_key):
                continue
            new_params[tree_key] = self._restack(tree_key)
        self._step = new_step
        return new_params, OptState(jnp.asarray(new_step, jnp.int32),
                                    None, None)

    def _restack(self, tree_key: str) -> list:
        """Reassemble one stream's stacked per-segment params from the
        per-stage update results."""
        stage_of = {(k, si, li): s for s, (k, si, li)
                    in self._layout.items()}
        out = []
        si = 0
        while (tree_key, si) in self._seg_leaves:
            leaves, treedef, n = self._seg_leaves[(tree_key, si)]
            per_layer = [self._results[stage_of[(tree_key, si, li)]]
                         for li in range(n)]
            stacked = [jnp.stack([per_layer[li][j] for li in range(n)])
                       for j in range(len(leaves))]
            out.append(jax.tree.unflatten(treedef, stacked))
            si += 1
        return out

    # ------------------------------------------------- materialization

    def materialize(self) -> OptState:
        """The full OptState (step, mu, nu), reassembled
        non-consumingly from the spool leases and the in-memory rest
        subtree — for checkpoints and run-end hand-back."""
        step = jnp.asarray(self._step, jnp.int32)
        if not self._has_m:
            return OptState(step, None, None)
        mu: dict = dict(self._rest_m)
        nu: dict = dict(self._rest_n) if self._has_n else None
        for tree_key, _ in _SCAN_KEYS:
            segs_m, segs_n = [], []
            si = 0
            while (tree_key, si) in self._seg_meta:
                treedef, n = self._seg_meta[(tree_key, si)]
                stage_of = {l_i: s for s, (k, s_i, l_i)
                            in self._layout.items()
                            if k == tree_key and s_i == si}
                payloads = []
                for li in range(n):
                    tx = self._mom_tx[stage_of[li]]
                    payloads.append([np.asarray(a) for a in
                                     tx.peek(0, to_device=False)])
                nl = len(payloads[0]) // (2 if self._has_n else 1)
                segs_m.append(jax.tree.unflatten(treedef, [
                    jnp.stack([payloads[li][j] for li in range(n)])
                    for j in range(nl)]))
                if self._has_n:
                    segs_n.append(jax.tree.unflatten(treedef, [
                        jnp.stack([payloads[li][nl + j]
                                   for li in range(n)])
                        for j in range(nl)]))
                si += 1
            if segs_m:
                mu[tree_key] = segs_m
                if self._has_n:
                    nu[tree_key] = segs_n
        return OptState(step, mu, nu)

    def moment_bytes(self) -> int:
        """Total bytes of seeded per-stage moment payloads — the write
        traffic one step's moment stage-back adds to the spool; feed
        this to `AdaptivePolicy.price_opt_io` so the activation planner
        budgets the shared write bandwidth. 0 before seeding and for
        moment-free optimizers (plain SGD)."""
        return self._moment_bytes

    def stats(self) -> Dict[str, int]:
        return dict(self.counters)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._worker is not None:
            self._queue.put(None)
            self._worker.join(timeout=10.0)
            self._worker = None
        for tx in self._mom_tx.values():
            tx.close()
        self._mom_tx = {}
