"""Production mesh builders (deliverable e).

Functions, not module-level constants, so importing this module never
touches jax device state. The dry-run entrypoint sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before importing jax;
tests and benches see the real single CPU device.
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro.parallel.sharding import MeshAxes

# TPU v5e hardware constants used by the roofline pass (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW_PER_LINK = 50e9          # bytes/s per link (~ one direction)


def _axis_type_kwargs(n: int) -> dict:
    """jax >= 0.5 wants explicit axis_types; older jax has no AxisType."""
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n} if at is not None else {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def mesh_axes(mesh) -> MeshAxes:
    """Logical roles for a production mesh: every non-"model" axis is a
    dp/fsdp axis; "model" is the TP/EP axis."""
    dp = tuple(a for a in mesh.axis_names if a != "model")
    return MeshAxes(dp=dp, tp="model")


def make_test_mesh(shape: Tuple[int, ...] = (2, 2),
                   axes: Tuple[str, ...] = ("data", "model")):
    """Small mesh for multi-device unit tests (subprocess with forced
    host device count)."""
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))
