"""Static HLO analysis for the roofline: trip-count-aware FLOPs, HBM
traffic, host-offload traffic and collective traffic.

Why not cost_analysis(): XLA's HloCostAnalysis visits a while-loop body
exactly once, so for scan-over-layers models it reports ~1/L of the real
cost (verified empirically). This module parses the compiled HLO text
structurally instead:

  * computations are parsed into instruction lists;
  * `while` ops carry backend_config known_trip_count (fallback: the max
    integer constant in the condition computation) — every computation
    gets a multiplier = product of enclosing trip counts;
  * FLOPs: 2 * prod(result_dims) * prod(lhs contracting dims) per `dot`,
    times the multiplier (elementwise FLOPs are ignored — matmuls dominate
    every cell by >100x);
  * HBM bytes: per top-level instruction (fusion/dot/copy/reduce/...),
    operand bytes + result bytes — the "every fusion reads inputs from HBM
    and writes outputs" model. Fusion-internal traffic is free;
  * host bytes: copies whose operand or result lives in host memory space
    (S(5) annotation) — this is the activation-offload tier's traffic;
  * collectives: ring-cost wire bytes per device with replica-group size n:
       all-gather / all-to-all   R*(n-1)/n
       all-reduce                R*2(n-1)/n
       reduce-scatter            R*(n-1)    (R = scattered result)
       collective-permute        R
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute", "collective-broadcast",
                  "ragged-all-to-all")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_HOST_SPACE_RE = re.compile(r"\{[^}]*S\(5\)[^}]*\}")
_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?(?P<name>[\w\.\-]+)\s*=\s*"
                       r"(?P<rest>.+)$")
_CALLS_RE = re.compile(
    r"(?:calls|body|condition|to_apply)=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

# Instructions with no HBM data movement of their own (or accounted at the
# caller: while/conditional bodies are walked separately).
_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "after-all", "optimization-barrier",
    "partition-id", "replica-id", "iota",
}


def cost_analysis_dict(compiled) -> dict:
    """compiled.cost_analysis() normalized across jax versions: newer
    jax returns one dict, older returns a per-device list of dicts."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):
        ca = ca[0] if ca else {}
    return ca


def _shape_dims(shape_text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt in DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_text):
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    shape_text: str
    op: str
    operands: List[str]
    line: str


@dataclass
class Computation:
    name: str
    is_entry: bool = False
    instrs: List[Instr] = field(default_factory=list)


def _split_shape_op(rest: str) -> Tuple[str, str, str]:
    """rest = '<shape> <op>(<operands>), attrs...'. Shape may be a
    parenthesised tuple containing spaces."""
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        shape, tail = rest[:i + 1], rest[i + 1:].lstrip()
    else:
        sp = rest.find(" ")
        shape, tail = rest[:sp], rest[sp + 1:]
    par = tail.find("(")
    op = tail[:par].strip()
    # operand region: up to matching close paren
    depth = 0
    for j in range(par, len(tail)):
        if tail[j] == "(":
            depth += 1
        elif tail[j] == ")":
            depth -= 1
            if depth == 0:
                break
    return shape, op, tail[par + 1:j]


def parse_module(hlo_text: str) -> Tuple[Dict[str, Computation],
                                         Dict[str, Instr], str]:
    comps: Dict[str, Computation] = {}
    by_name: Dict[str, Instr] = {}
    entry = ""
    cur: Optional[Computation] = None
    for line in hlo_text.splitlines():
        if not line.strip() or line.lstrip().startswith("//"):
            continue
        if not line.startswith(" ") and line.rstrip().endswith("{"):
            m = _COMP_HEADER_RE.match(line)
            if m:
                cur = Computation(m.group(2), is_entry=bool(m.group(1)))
                comps[cur.name] = cur
                if cur.is_entry:
                    entry = cur.name
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None or "=" not in line:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        rest = m.group("rest")
        try:
            shape, op, operand_text = _split_shape_op(rest)
        except Exception:
            continue
        ops = re.findall(r"%([\w\.\-]+)", operand_text)
        ins = Instr(m.group("name"), shape, op, ops, line)
        cur.instrs.append(ins)
        by_name.setdefault(ins.name, ins)
    return comps, by_name, entry


def _trip_count(instr: Instr, comps: Dict[str, Computation]) -> int:
    m = _TRIP_RE.search(instr.line)
    if m:
        return int(m.group(1))
    m2 = re.search(r"condition=%?([\w\.\-]+)", instr.line)
    if m2 and m2.group(1) in comps:
        consts = [int(c) for i in comps[m2.group(1)].instrs
                  for c in _CONST_RE.findall(i.line)]
        if consts:
            return max(consts)
    return 1


def _multipliers(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    """Call-site multiplier per computation (ENTRY=1, while body xN)."""
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    if entry in mult:
        mult[entry] = 1.0
    # iterate to fixpoint (call graph is a DAG; few levels deep)
    for _ in range(64):
        changed = False
        for cname, comp in comps.items():
            m = mult.get(cname, 0.0)
            if m == 0.0:
                continue
            for ins in comp.instrs:
                factor = m
                if ins.op == "while":
                    factor = m * _trip_count(ins, comps)
                for ref in _CALLS_RE.findall(ins.line):
                    if ref in mult and mult[ref] < factor:
                        mult[ref] = factor
                        changed = True
                bm = _BRANCHES_RE.search(ins.line)
                if bm:
                    for ref in re.findall(r"%([\w\.\-]+)", bm.group(1)):
                        if ref in mult and mult[ref] < m:
                            mult[ref] = m
                            changed = True
        if not changed:
            break
    return mult


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return total_devices


def _wire_bytes(op: str, result_bytes: int, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return result_bytes * 2 * (n - 1) / n
    if op == "reduce-scatter":
        return result_bytes * (n - 1)
    if op == "collective-permute":
        return float(result_bytes)
    return result_bytes * (n - 1) / n


def _dot_flops(ins: Instr, by_name: Dict[str, Instr]) -> float:
    result_elems = 1
    for _, dims in _shape_dims(ins.shape_text):
        for d in dims:
            result_elems *= d
    k = 1
    m = _CONTRACT_RE.search(ins.line)
    if m and ins.operands:
        lhs = by_name.get(ins.operands[0])
        if lhs is not None:
            ldims = _shape_dims(lhs.shape_text)
            if ldims:
                dims = ldims[0][1]
                for idx in (int(x) for x in m.group(1).split(",") if x):
                    if idx < len(dims):
                        k *= dims[idx]
    return 2.0 * result_elems * k


@dataclass
class CollectiveStats:
    count: float = 0
    result_bytes: float = 0
    wire_bytes: float = 0.0


@dataclass
class HloAnalysis:
    flops: float = 0.0
    hbm_bytes: float = 0.0       # upper bound: per-instruction traffic at
    #                              the compiled (CPU-backend) fusion
    #                              granularity
    hbm_bytes_lb: float = 0.0    # lower bound: dots + dus stacks only —
    #                              what a perfectly-fusing backend must
    #                              still move
    host_bytes: float = 0.0
    dot_count: float = 0
    collectives: Dict[str, CollectiveStats] = field(default_factory=dict)
    wire_by_group_size: Dict[int, float] = field(default_factory=dict)

    @property
    def collective_wire_bytes(self) -> float:
        return sum(s.wire_bytes for s in self.collectives.values())

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "hbm_bytes_lb": self.hbm_bytes_lb,
            "host_bytes": self.host_bytes,
            "dot_count": self.dot_count,
            "collective_wire_bytes": self.collective_wire_bytes,
            "collectives": {k: vars(v) for k, v in
                            self.collectives.items()},
            "wire_by_group_size": {str(k): v for k, v in
                                   self.wire_by_group_size.items()},
        }


def analyze_hlo(hlo_text: str, total_devices: int) -> HloAnalysis:
    comps, by_name, entry = parse_module(hlo_text)
    mult = _multipliers(comps, entry)
    # fusion-called computations: internal traffic is free, but dots inside
    # them still count (at the caller's multiplier, already propagated).
    fusion_comps = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op == "fusion":
                for ref in _CALLS_RE.findall(ins.line):
                    fusion_comps.add(ref)

    out = HloAnalysis()
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fusion = cname in fusion_comps
        for ins in comp.instrs:
            base_op = ins.op.replace("-start", "")
            if base_op in COLLECTIVE_OPS and not ins.op.endswith("-done"):
                rb = _shape_bytes(ins.shape_text)
                if ins.op.endswith("-start") and \
                        ins.shape_text.startswith("("):
                    # async tuple (operands..., result): halve double count
                    rb //= 2
                n = _group_size(ins.line, total_devices)
                st = out.collectives.setdefault(base_op, CollectiveStats())
                st.count += m
                st.result_bytes += rb * m
                wb = _wire_bytes(base_op, rb, n) * m
                st.wire_bytes += wb
                out.wire_by_group_size[n] = \
                    out.wire_by_group_size.get(n, 0.0) + wb
                continue
            if ins.op == "dot":
                out.dot_count += m
                out.flops += m * _dot_flops(ins, by_name)
                dot_traffic = _shape_bytes(ins.shape_text)
                for opnd in ins.operands:
                    src = by_name.get(opnd)
                    if src is not None and src.op != "constant":
                        dot_traffic += _shape_bytes(src.shape_text)
                out.hbm_bytes_lb += m * dot_traffic
            elif ins.op == "dynamic-update-slice" and not in_fusion:
                upd = by_name.get(ins.operands[1]) \
                    if len(ins.operands) > 1 else None
                if upd is not None:
                    out.hbm_bytes_lb += 2 * m * _shape_bytes(
                        upd.shape_text)
            if in_fusion:
                continue  # traffic accounted at the fusion call site
            if ins.op in _NO_TRAFFIC or ins.op.endswith("-done"):
                continue
            if ins.op == "dynamic-update-slice":
                # in-place in XLA buffer assignment: traffic = the
                # updated slice (read+write), not the whole buffer
                upd = by_name.get(ins.operands[1]) \
                    if len(ins.operands) > 1 else None
                traffic = 2 * _shape_bytes(upd.shape_text) if upd else \
                    _shape_bytes(ins.shape_text)
            elif ins.op == "dynamic-slice":
                traffic = 2 * _shape_bytes(ins.shape_text)
            else:
                traffic = _shape_bytes(ins.shape_text)
                for opnd in ins.operands:
                    src = by_name.get(opnd)
                    if src is not None and src.op not in ("constant",):
                        traffic += _shape_bytes(src.shape_text)
            is_host = bool(_HOST_SPACE_RE.search(ins.line))
            if not is_host:
                for opnd in ins.operands:
                    src = by_name.get(opnd)
                    if src is not None and \
                            _HOST_SPACE_RE.search(src.shape_text):
                        is_host = True
                        break
            if is_host and ins.op in ("copy", "copy-start"):
                out.host_bytes += m * _shape_bytes(ins.shape_text)
            else:
                out.hbm_bytes += m * traffic
    return out


def collect_collectives(hlo_text: str, total_devices: int) -> HloAnalysis:
    """Back-compat alias."""
    return analyze_hlo(hlo_text, total_devices)
