"""The shared ``--cache-*`` CLI family: one knob surface for the
`repro.cache` storage brain, used verbatim by the training driver
(`launch.train`, both engines) and the serving driver (`launch.serve`),
so a placement setup tuned on one carries to the other unchanged.
"""
from __future__ import annotations

import argparse
from typing import Dict


def add_cache_args(ap: argparse.ArgumentParser) -> None:
    g = ap.add_argument_group(
        "cache manager (repro.cache storage brain)")
    g.add_argument("--cache-managed", action="store_true",
                   help="route the spool through the CacheManager "
                        "('managed' backend): class- and reuse-"
                        "distance-aware placement over bounded host "
                        "RAM + SSD, with background promotion and "
                        "failing-SSD fallback")
    g.add_argument("--cache-host-bound-mb", type=int, default=None,
                   metavar="MB",
                   help="pinned-host-RAM bound of the managed cache in "
                        "MiB (default: the tiered budget, "
                        "--host-mem-budget-mb where present, else 256)")
    g.add_argument("--cache-ssd", default=None, metavar="SPEC",
                   help="SSD tier as a backend spec string, e.g. 'fs', "
                        "'striped:/a,/b', 'aio:/nvme@8' (default: fs "
                        "under the spool dir, or the stripe dirs)")
    g.add_argument("--cache-promote-depth", type=int, default=2,
                   metavar="N",
                   help="lowered blobs promoted back to host RAM per "
                        "reuse-horizon hint (0 disables promotion)")


def cache_overrides(args: argparse.Namespace) -> Dict[str, object]:
    """`SpoolIoConfig` field overrides implied by the parsed
    ``--cache-*`` flags (empty-ish when the family is unused)."""
    out: Dict[str, object] = {
        "cache_promote_depth": args.cache_promote_depth,
    }
    if args.cache_managed:
        out["backend"] = "managed"
    if args.cache_host_bound_mb is not None:
        out["host_mem_budget_bytes"] = args.cache_host_bound_mb << 20
    if args.cache_ssd:
        out["cache_ssd"] = args.cache_ssd
    return out
