"""End-to-end training driver (deliverable b).

Two execution paths, selected by --engine:

  jit     — whole-step jax.jit training (single host here; the same
            step builders drive the 256/512-chip dry-run), wrapped in the
            fault-tolerant TrainLoop (async checkpoints, preemption trap,
            straggler watchdog, resume).
  staged  — the TBA host-staged trainer (core/staged.py): per-module
            jitted stages with the ActivationSpool offloading real
            residuals to real disk, adaptive offloading enabled. This is
            the paper's runnable path on this container.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch gpt-124m \
      --steps 300 --batch 8 --seq 256 --engine jit --ckpt /tmp/ck
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b:reduced \
      --steps 20 --engine staged --strategy offload
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced
from repro.configs.paper_models import gpt, small_bert, small_gpt
from repro.data.pipeline import ShardedLoader, SyntheticMarkovLM
from repro.models.api import build_model
from repro.models.transformer import RunSettings
from repro.optim.optimizers import adamw, sgd
from repro.runtime.trainer import StragglerWatchdog, TrainLoop, TrainState


def resolve_config(name: str):
    """--arch accepts: assigned ids, '<id>:reduced', gpt-124m,
    small-gpt/small-bert, or gpt-h<H>-l<L>."""
    if name == "gpt-124m":
        return dataclasses.replace(
            gpt(768, 12, vocab=32768), num_heads=12, num_kv_heads=12,
            head_dim=64)
    if name == "small-gpt":
        return small_gpt()
    if name == "small-bert":
        return small_bert()
    if name.endswith(":reduced"):
        return reduced(get_config(name[:-len(":reduced")]))
    if name in ARCH_IDS:
        return get_config(name)
    if name.startswith("gpt-h"):
        h, l = name[5:].split("-l")
        return gpt(int(h), int(l))
    raise SystemExit(f"unknown --arch {name!r}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="small-gpt")
    ap.add_argument("--engine", choices=["jit", "staged"], default="jit")
    ap.add_argument("--strategy", default="offload",
                    choices=["keep", "offload", "recompute"],
                    help="staged engine: ROK placement strategy")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", choices=["adamw", "sgd"],
                    default="adamw")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--metrics", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--min-offload", type=int, default=None,
                    help="staged engine: min elements to offload "
                         "(default: paper's 2**20)")
    ap.add_argument("--spool-backend", default="fs",
                    choices=["fs", "striped", "mem", "tiered"],
                    help="staged engine: storage backend for the "
                         "activation spool (repro.io)")
    ap.add_argument("--spool-dir", default=None,
                    help="spool directory (default: fresh temp dir)")
    ap.add_argument("--stripe-dirs", default=None,
                    help="comma-separated stripe directories for "
                         "--spool-backend striped/tiered (default: 2 "
                         "subdirs of the spool dir)")
    ap.add_argument("--codec", default="raw", choices=["raw", "zlib"],
                    help="payload codec for spooled residuals")
    ap.add_argument("--host-mem-budget-mb", type=int, default=256,
                    help="tiered backend: host-RAM tier budget in MiB")
    args = ap.parse_args()

    cfg = resolve_config(args.arch)
    if jax.device_count() == 1 and cfg.num_layers > 16:
        print("note: full-size config on one CPU device — consider "
              "'<arch>:reduced'")
    api = build_model(cfg)
    opt = adamw(args.lr) if args.optimizer == "adamw" else sgd(args.lr)
    source = SyntheticMarkovLM(cfg.vocab_size, seed=args.seed)
    loader = ShardedLoader(source, global_batch=args.batch,
                           seq_len=args.seq)

    params = api.init(jax.random.key(args.seed))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M engine={args.engine}")

    if args.engine == "staged":
        from repro.configs.base import SpoolIoConfig
        from repro.core.staged import StagedTrainer
        settings = RunSettings(attn_impl="xla", attn_chunk=256,
                               param_dtype=cfg.dtype)
        stripe_dirs = tuple(d for d in (args.stripe_dirs or "").split(",")
                            if d)
        io_config = SpoolIoConfig(
            backend=args.spool_backend, directory=args.spool_dir,
            stripe_dirs=stripe_dirs, codec=args.codec,
            host_mem_budget_bytes=args.host_mem_budget_mb << 20)
        trainer = StagedTrainer(api, settings, opt,
                                strategy=args.strategy,
                                spool_dir=args.spool_dir,
                                io_config=io_config,
                                min_offload_elements=args.min_offload)
        print(f"spool backend={args.spool_backend} codec={args.codec}")
        opt_state = opt.init(params)
        for step in range(args.steps):
            batches = [next(loader) for _ in range(args.microbatches)]
            params, opt_state, rep = trainer.train_step(params, opt_state,
                                                        batches)
            print(f"step {step:4d} loss {rep.loss:.4f} "
                  f"t {rep.step_time:.2f}s "
                  f"act_peak {rep.peak_activation_bytes/1e6:.1f} MB "
                  f"offloaded {rep.stats.bytes_offloaded/1e6:.1f} MB",
                  flush=True)
        bk = trainer.spool.backend
        io = bk.stats
        if io.num_writes:
            print(f"backend[{bk.kind}] wrote {io.bytes_written/1e6:.1f} MB"
                  f" @ {io.write_bandwidth/1e9:.2f} GB/s, read "
                  f"{io.bytes_read/1e6:.1f} MB", flush=True)
        if hasattr(bk, "per_device_write_bytes"):
            per_dev = bk.per_device_write_bytes()
            print("stripe write balance:",
                  [f"{b/1e6:.1f}MB" for b in per_dev], flush=True)
        trainer.close()
        return

    settings = RunSettings(attn_impl="xla", attn_chunk=256,
                           activation_policy="remat",
                           param_dtype=cfg.dtype)

    @jax.jit
    def step_fn(params, opt_state, batch):
        batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
        (_, metrics), grads = jax.value_and_grad(
            api.loss, has_aux=True)(params, batch, settings)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, metrics

    loop = TrainLoop(
        step_fn=step_fn,
        init_state=TrainState(0, params, opt.init(params)),
        loader=loader, ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every,
        metrics_path=args.metrics,
        watchdog=StragglerWatchdog(),
        install_signal_handlers=True)
    if args.resume and loop.resume():
        print(f"resumed from step {loop.state.step}")

    t0 = time.time()
    final = loop.run(args.steps)
    dt = time.time() - t0
    print(f"done: {final.step} steps in {dt:.1f}s "
          f"({args.steps and dt/args.steps:.2f}s/step); "
          f"stragglers flagged: {len(loop.watchdog.flagged)}")
    loop.close()


if __name__ == "__main__":
    main()
