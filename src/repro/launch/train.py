"""End-to-end training driver (deliverable b).

Both execution paths now route through `repro.session.TrainSession`,
selected by --engine:

  jit     — whole-step jax.jit training wrapped in the fault-tolerant
            TrainLoop (async checkpoints, preemption trap, straggler
            watchdog, resume). With --host-offload opt_state, the
            optimizer state is staged through the SpoolIoConfig-selected
            backend between steps; with --host-offload activations,
            per-layer residuals stream through that backend from inside
            the jitted step (repro.core.hooks io_callback path) — both
            engines share backend/codec selection either way.
  staged  — the TBA host-staged trainer (core/staged.py): per-module
            jitted stages with the ActivationSpool offloading real
            residuals to real disk, placement decided by an
            OffloadPolicy (--strategy maps onto policy objects). This is
            the paper's runnable path on this container.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch gpt-124m \
      --steps 300 --batch 8 --seq 256 --engine jit --ckpt /tmp/ck
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b:reduced \
      --steps 20 --engine staged --strategy offload
  PYTHONPATH=src python -m repro.launch.train --engine jit \
      --spool-backend mem --host-offload --steps 20
"""
from __future__ import annotations

import argparse
import time

from repro.configs.base import SpoolIoConfig
from repro.launch.cacheargs import add_cache_args, cache_overrides
from repro.session import TrainSession, resolve_config  # noqa: F401
# resolve_config is re-exported for back-compat: it used to live here.


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="small-gpt")
    ap.add_argument("--engine", choices=["jit", "staged"], default="jit")
    ap.add_argument("--strategy", default="offload",
                    choices=["keep", "offload", "recompute", "adaptive",
                             "spool"],
                    help="staged engine: offload policy (maps onto "
                         "repro.session policy objects; 'offload' keeps "
                         "the seed meaning, adaptive planning)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", choices=["adamw", "sgd"],
                    default="adamw")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--metrics", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--min-offload", type=int, default=None,
                    help="min elements to offload through the spool "
                         "(default: paper's 2**20)")
    ap.add_argument("--spool-backend", default="fs",
                    help="storage backend for the activation spool "
                         "(repro.io); honored by BOTH engines. A bare "
                         "kind (fs|striped|mem|tiered|managed|aio) or a "
                         "full repro.io spec string like "
                         "'fault@3:striped@2' or 'tiered:64mb,aio'. "
                         "'aio' is the O_DIRECT zero-copy data plane; "
                         "'managed' is the repro.cache storage brain "
                         "(see the --cache-* family)")
    ap.add_argument("--spool-dir", default=None,
                    help="spool directory (default: fresh temp dir, "
                         "removed on close)")
    ap.add_argument("--stripe-dirs", default=None,
                    help="comma-separated stripe directories for "
                         "--spool-backend striped/tiered (default: 2 "
                         "subdirs of the spool dir)")
    ap.add_argument("--codec", default="raw",
                    choices=["raw", "zlib", "byteplane"],
                    help="payload codec for spooled payloads; "
                         "'byteplane' splits bf16/fp16 into byte planes "
                         "and DEFLATEs only the compressible one")
    ap.add_argument("--host-mem-budget-mb", type=int, default=256,
                    help="tiered backend: host-RAM tier budget in MiB")
    ap.add_argument("--spool-align", type=int, default=4096,
                    help="data plane: buffer-pool / O_DIRECT alignment "
                         "(power of two)")
    ap.add_argument("--spool-queue-depth", type=int, default=4,
                    help="aio backend: concurrent aligned segments "
                         "submitted per blob")
    ap.add_argument("--spool-pool-mb", type=int, default=256,
                    help="idle cap of the shared aligned buffer pool "
                         "in MiB")
    ap.add_argument("--clip-norm", type=float, default=None,
                    metavar="NORM",
                    help="global grad-norm clip (adamw defaults to "
                         "1.0); 0 disables clipping — use it to build "
                         "a serial baseline comparable bit-for-bit "
                         "with --opt-overlap")
    ap.add_argument("--opt-overlap", action="store_true",
                    help="jit engine: eager per-layer optimizer updates "
                         "overlapped with backward — moment leases "
                         "stream through the spool backend while the "
                         "next layer's gradients compute "
                         "(repro.optim.overlap). Bitwise-identical to "
                         "the serial step. Implies a clip-free "
                         "optimizer (global-norm clipping needs every "
                         "gradient before any update); supersedes "
                         "--host-offload opt_state")
    ap.add_argument("--host-offload", nargs="?", const="opt_state",
                    default="none",
                    choices=["none", "opt_state", "activations"],
                    help="jit engine: what to route through the spool "
                         "backend — 'opt_state' stages the optimizer "
                         "state between steps (bare --host-offload "
                         "keeps meaning this); 'activations' streams "
                         "per-layer residuals from inside the jitted "
                         "step (repro.core.hooks); works on a --mesh "
                         "too (per-shard callbacks)")
    ap.add_argument("--mesh", default=None,
                    help="jit engine: device mesh shape, e.g. '2x4' "
                         "(data x model) or '8' (data only). Needs "
                         "that many jax devices (forced host devices "
                         "work: XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=8). host-offload modes shard "
                         "their spool traffic per device")
    ap.add_argument("--spool-no-dedupe", action="store_true",
                    help="mesh activation offload: store one residual "
                         "copy PER DEVICE instead of one per replica "
                         "group (debugging / bandwidth experiments)")
    ap.add_argument("--retry-attempts", type=int, default=3,
                    help="resilience: total tries per spool I/O op "
                         "before the failure surfaces (1 disables "
                         "retry)")
    ap.add_argument("--retry-backoff-ms", type=float, default=10.0,
                    help="resilience: first retry delay in ms; doubles "
                         "per attempt, capped at 250 ms")
    ap.add_argument("--on-fetch-fail", default="recompute",
                    choices=["recompute", "raise"],
                    help="resilience: when a residual fetch ultimately "
                         "fails after retries, recompute the segment "
                         "from kept inputs (default) or raise and kill "
                         "the step")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="enable repro.obs tracing and write a Chrome/"
                         "Perfetto trace-event JSON here on exit "
                         "(load it at https://ui.perfetto.dev)")
    ap.add_argument("--trace-ring", type=int, default=0,
                    help="per-thread trace ring capacity in events "
                         "(default 65536; older events are dropped and "
                         "counted when a ring fills)")
    add_cache_args(ap)
    args = ap.parse_args()

    mesh = None
    if args.mesh:
        if args.engine != "jit":
            ap.error("--mesh is a jit-engine flag")
        import jax
        from repro.launch.mesh import make_test_mesh
        try:
            shape = tuple(int(d) for d in args.mesh.lower().split("x"))
        except ValueError:
            ap.error(f"bad --mesh {args.mesh!r}; expected e.g. 2x4")
        if any(d < 1 for d in shape) or len(shape) > 3:
            ap.error(f"bad --mesh {args.mesh!r}; expected e.g. 2x4")
        ndev = 1
        for d in shape:
            ndev *= d
        if ndev > jax.device_count():
            ap.error(f"--mesh {args.mesh} needs {ndev} devices, have "
                     f"{jax.device_count()} (hint: XLA_FLAGS="
                     f"--xla_force_host_platform_device_count={ndev})")
        names = {1: ("data",), 2: ("data", "model"),
                 3: ("pod", "data", "model")}[len(shape)]
        if ndev > 1:
            mesh = make_test_mesh(shape, names)

    optimizer = args.optimizer
    if args.opt_overlap:
        if args.engine != "jit":
            ap.error("--opt-overlap is a jit-engine flag")
        if args.clip_norm:
            ap.error("--opt-overlap needs a clip-free optimizer "
                     "(global-norm clipping requires every gradient "
                     "before any update); pass --clip-norm 0 or drop "
                     "the flag")
    if args.opt_overlap or args.clip_norm is not None:
        from repro.optim.optimizers import adamw, sgd
        clip = (None if args.opt_overlap or not args.clip_norm
                else args.clip_norm)
        if args.optimizer == "adamw":
            optimizer = adamw(args.lr, clip_norm=clip)
            if args.opt_overlap:
                print("opt-overlap: using clip-free adamw (global-norm "
                      "clipping is incompatible with eager per-layer "
                      "updates)")
        else:
            optimizer = sgd(args.lr, clip_norm=clip)

    stripe_dirs = tuple(d for d in (args.stripe_dirs or "").split(",")
                        if d)
    cache_ov = cache_overrides(args)
    io = SpoolIoConfig(
        backend=cache_ov.pop("backend", args.spool_backend),
        directory=args.spool_dir,
        stripe_dirs=stripe_dirs, codec=args.codec,
        host_mem_budget_bytes=cache_ov.pop(
            "host_mem_budget_bytes", args.host_mem_budget_mb << 20),
        host_offload=args.host_offload,
        dedupe_replicas=not args.spool_no_dedupe,
        alignment=args.spool_align,
        queue_depth=args.spool_queue_depth,
        pool_bytes=args.spool_pool_mb << 20,
        retry_attempts=args.retry_attempts,
        retry_backoff_s=args.retry_backoff_ms / 1e3,
        on_fetch_fail=args.on_fetch_fail,
        **cache_ov)

    # the context manager guarantees teardown (worker-thread join, temp
    # spool/ckpt dir removal) on exceptions and Ctrl-C too
    with TrainSession(
            args.arch, engine=args.engine,
            policy=args.strategy if args.engine == "staged" else None,
            io=io, optimizer=optimizer, lr=args.lr,
            opt_overlap=args.opt_overlap or None,
            batch_size=args.batch, seq_len=args.seq, seed=args.seed,
            microbatches=args.microbatches, mesh=mesh,
            ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every,
            metrics_path=args.metrics, spool_dir=args.spool_dir,
            min_offload_elements=args.min_offload,
            trace=args.trace, trace_ring=args.trace_ring,
            install_signal_handlers=(args.engine == "jit")) as session:

        print(f"arch={session.cfg.name} "
              f"params={session.n_params/1e6:.1f}M engine={args.engine}"
              + (f" mesh={dict(mesh.shape)}" if mesh is not None else ""))
        if session.cfg.num_layers > 16:
            import jax
            if jax.device_count() == 1:
                print("note: full-size config on one CPU device — "
                      "consider '<arch>:reduced'")
        if session.spool is not None:
            print(f"spool backend={args.spool_backend} "
                  f"codec={args.codec}")

        def on_report(rep):
            if args.engine == "staged":
                print(f"step {rep.step - 1:4d} loss {rep.loss:.4f} "
                      f"t {rep.step_time:.2f}s "
                      f"act_peak {rep.peak_activation_bytes/1e6:.1f} MB "
                      f"offloaded {rep.stats.bytes_offloaded/1e6:.1f} MB",
                      flush=True)

        t0 = time.time()
        result = session.run(args.steps, resume=args.resume,
                             on_report=on_report)
        dt = time.time() - t0

        if session.spool is not None:
            session.spool.wait_io()     # drain in-flight stores so the
            bk = session.spool.backend  # busy clocks below are closed
            io_stats = bk.stats
            if io_stats.num_writes:
                print(f"backend[{bk.kind}] wrote "
                      f"{io_stats.bytes_written/1e6:.1f} MB @ "
                      f"{io_stats.write_bandwidth/1e9:.2f} GB/s, read "
                      f"{io_stats.bytes_read/1e6:.1f} MB", flush=True)
                dp = session.spool.data_plane_stats()
                print(f"data plane: "
                      f"{dp['backend']['copies_per_byte']:.2f} host "
                      f"copies/byte, pool hit rate "
                      f"{dp['pool']['hit_rate']:.0%} "
                      f"({dp['pool']['bytes_allocated']/1e6:.1f} MB "
                      f"ever allocated)", flush=True)
            if hasattr(bk, "per_device_write_bytes"):
                per_dev = bk.per_device_write_bytes()
                print("stripe write balance:",
                      [f"{b/1e6:.1f}MB" for b in per_dev], flush=True)
            rs = session.spool.stats
            if rs.store_retries or rs.load_retries or rs.fetch_fallbacks:
                print(f"resilience: {rs.store_retries} store retries, "
                      f"{rs.load_retries} load retries, "
                      f"{rs.fetch_fallbacks} recompute fallbacks; "
                      f"backend health={session.spool.health.status}",
                      flush=True)
        if session._opt_bridge is not None and session._opt_bridge.seeded:
            st = session._opt_bridge.stats()
            print(f"opt-overlap: {st['opt_updates']} per-layer updates, "
                  f"fetched {st['opt_fetched_bytes']/1e6:.1f} MB, staged "
                  f"{st['opt_staged_bytes']/1e6:.1f} MB, skipped "
                  f"{st['opt_stage_skips']} unchanged stage-backs "
                  f"({st['opt_skipped_bytes']/1e6:.1f} MB not rewritten)",
                  flush=True)
        if args.trace:
            last_obs = next((r.obs for r in reversed(result.reports)
                             if r.obs), None)
            if last_obs and last_obs["io_busy_s"] > 0:
                print(f"overlap (last step): "
                      f"{last_obs['io_hidden_frac']:.0%} of "
                      f"{last_obs['io_busy_s']*1e3:.1f} ms I/O hidden "
                      f"under compute; exposed stalls: read "
                      f"{last_obs['stall_read_s']*1e3:.1f} ms, decode "
                      f"{last_obs['stall_decode_s']*1e3:.1f} ms, queue "
                      f"{last_obs['stall_queue_s']*1e3:.1f} ms; "
                      f"prefetch hit rate "
                      f"{last_obs['prefetch_hit_rate']:.0%}", flush=True)
            if last_obs and last_obs.get("opt_io_busy_s", 0) > 0:
                print(f"opt overlap (last step): "
                      f"{last_obs['opt_hidden_frac']:.0%} of "
                      f"{last_obs['opt_io_busy_s']*1e3:.1f} ms opt-state "
                      f"I/O hidden under backward", flush=True)
        if args.engine == "jit":
            flagged = (len(session.watchdog.flagged)
                       if session.watchdog else 0)
            print(f"done: {result.state.step} steps in {dt:.1f}s "
                  f"({args.steps and dt/args.steps:.2f}s/step); "
                  f"stragglers flagged: {flagged}")

    # the session just closed — the trace file exists now
    if args.trace:
        print(f"trace written to {args.trace}")


if __name__ == "__main__":
    main()
