import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Everything else follows.

import argparse
import dataclasses
import gc
import json
import subprocess
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.configs.base import SHAPES
from repro.configs.registry import (ARCH_IDS, cell_skip_reason, get_config,
                                    get_shape)
from repro.launch.hlo_stats import analyze_hlo, cost_analysis_dict
from repro.launch.mesh import (HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16,
                               make_production_mesh, mesh_axes)
from repro.launch.steps import make_step
from repro.cache.manager import plan_residency
from repro.io.backend import NOMINAL_WRITE_BW
from repro.models.api import build_model
from repro.optim.optimizers import adamw, sgd

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# AdamW fp32 moments for a ~1T-param model cannot fit any per-chip HBM at
# this scale; the paper hit the same wall on A100-40GB and switched to SGD
# (§4.1) — we do the same for the trillion-param cell.
SGD_PARAM_THRESHOLD = 400e9


def _slug(arch: str, shape: str, mesh_name: str) -> str:
    return f"{arch.replace('.', '_')}__{shape}__{mesh_name}"


def model_flops(kind: str, n_params: int, n_active: int,
                tokens: int) -> float:
    """6ND for training (fwd+bwd), 2ND for forward-only serving."""
    n = n_active
    return (6.0 if kind == "train" else 2.0) * n * tokens


def _predict_overlap(host_bytes: float, write_bw: float,
                     t_compute: float, *,
                     opt_bytes: float = 0.0,
                     opt_update_flops: float = 0.0) -> Dict[str, Any]:
    """Roofline prediction of how much activation I/O the step can hide.

    SSDTrain's schedule writes each layer's residuals during the forward
    pass and reads them back during the backward pass, so the store
    window is the forward compute time and the fetch window the backward
    compute time (fwd:bwd ~ 1:2 of the 6ND step). Whatever part of each
    transfer does not fit its window is exposed stall; the keys match
    `repro.obs.overlap.analyze()` so `predicted_vs_measured()` can pair
    this block with a traced run.

    With `opt_bytes > 0` (per-device optimizer-moment bytes, the
    opt-overlap bridge's traffic) the prediction also times the eager
    per-layer optimizer schedule: as each layer's gradients materialize
    in backward, its moments are fetched, the update computed on the
    side stream, and new moments staged back — all inside the backward
    window, sharing bandwidth with activation fetches. Keyword-only so
    existing positional call sites keep their meaning.
    """
    t_store = host_bytes / write_bw          # offload: fwd-side writes
    t_fetch = host_bytes / write_bw          # fetch: bwd-side reads
    t_io = t_store + t_fetch
    t_fwd = t_compute / 3.0                  # 2ND of the 6ND step
    t_bwd = t_compute * 2.0 / 3.0            # 4ND of the 6ND step
    exposed = (max(0.0, t_store - t_fwd) + max(0.0, t_fetch - t_bwd))
    # eager opt schedule: fetch + stage ride the backward window, on the
    # same spool bandwidth the activation fetches use; the side-stream
    # update itself is host compute, bandwidth-free
    t_opt_fetch = opt_bytes / write_bw
    t_opt_stage = opt_bytes / write_bw
    t_opt_update = (opt_update_flops / PEAK_FLOPS_BF16
                    if opt_update_flops else 0.0)
    t_opt_io = t_opt_fetch + t_opt_stage
    opt_window = max(0.0, t_bwd - max(0.0, t_fetch))  # leftover bwd room
    opt_exposed = max(0.0, t_opt_io + t_opt_update - opt_window) \
        if t_opt_io > 0 else 0.0
    return {
        "t_store_s": t_store,
        "t_fetch_s": t_fetch,
        "t_io_s": t_io,
        "t_fwd_s": t_fwd,
        "t_bwd_s": t_bwd,
        "per_stage_io_s": {"fwd_store": t_store, "bwd_fetch": t_fetch,
                           "bwd_opt_fetch": t_opt_fetch,
                           "bwd_opt_stage": t_opt_stage},
        "exposed_wait_s": exposed,
        "io_hidden_frac": (1.0 - exposed / t_io) if t_io > 0 else 1.0,
        "t_opt_io_s": t_opt_io,
        "t_opt_update_s": t_opt_update,
        "opt_exposed_wait_s": min(opt_exposed, t_opt_io),
        "opt_hidden_frac": ((1.0 - min(opt_exposed, t_opt_io) / t_opt_io)
                            if t_opt_io > 0 else 1.0),
    }


def _predict_residency(kind: str, host_bytes: float, n_params: int,
                       chips: int, optimizer: Optional[str],
                       host_bound_bytes: int) -> Dict[str, Any]:
    """Predicted per-class bytes per storage tier at this cell's planned
    micro-batch, from the cache manager's own placement model
    (`repro.cache.plan_residency`): nearest-reuse classes keep the
    bounded pinned-host tier, overflow lands on SSD. The per-class keys
    match the `cache_residency` block a managed-backend run emits in the
    metrics JSONL, so prediction and measurement pair row-for-row, the
    way `predicted_overlap` pairs with the obs tracer."""
    # fp32 moment state staged through the spool between steps: AdamW
    # carries two moments (8 B/param), plain SGD carries none
    opt_b = {"adamw": 8, "sgd": 0}.get(optimizer or "", 0)
    class_bytes = {
        "activation": int(host_bytes),
        "opt_state": (int(n_params / chips) * opt_b
                      if kind == "train" else 0),
        # train cells serve no decode traffic; serving predictions get
        # their KV footprint from the live kvcache, not the dry run
        "kv_page": 0,
    }
    return {
        "host_bound_bytes": int(host_bound_bytes),
        "per_class": plan_residency(class_bytes,
                                    host_bound_bytes=host_bound_bytes),
    }


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str, dump_hlo: bool = False,
             policy: Optional[str] = None, attn_chunk: int = 1024,
             force: bool = False, tag: str = "",
             baseline: bool = False,
             io_backend: str = "fs",
             cache_host_bound_mb: int = 256) -> Dict[str, Any]:
    if baseline:
        os.environ["REPRO_NO_BLOCKED_ATTN"] = "1"
        tag = tag or "paperbase"
    mesh_name = ("multi" if multi_pod else "single") + (f"-{tag}" if tag
                                                        else "")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, _slug(arch, shape_name, mesh_name)
                        + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "multi_pod": multi_pod, "kind": shape.kind,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "policy": policy, "attn_chunk": attn_chunk,
    }
    skip = cell_skip_reason(cfg, shape)
    if skip:
        rec.update(status="skip", skip_reason=skip)
        _write(path, rec)
        return rec

    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        axes = mesh_axes(mesh)
        chips = mesh.size
        api = build_model(cfg)
        if shape.kind == "train":
            from repro.launch.steps import _params_sds, count_params
            n_total = count_params(_params_sds(api), exclude=())
            opt = sgd() if n_total > SGD_PARAM_THRESHOLD else adamw()
            rec["optimizer"] = opt.name
            bundle = make_step(api, mesh, axes, shape, optimizer=opt,
                               activation_policy=policy,
                               ce_chunk=0 if baseline else 512)
        else:
            bundle = make_step(api, mesh, axes, shape)

        t0 = time.time()
        with mesh:
            lowered = jax.jit(bundle.fn,
                              out_shardings=bundle.out_shardings) \
                .lower(*bundle.args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        ca = cost_analysis_dict(compiled)
        hlo_text = compiled.as_text()
        ana = analyze_hlo(hlo_text, chips)
        if dump_hlo:
            with open(path.replace(".json", ".hlo.txt"), "w") as f:
                f.write(hlo_text)

        mf = model_flops(shape.kind, bundle.n_params, bundle.n_active,
                         bundle.tokens_per_step)
        flops_dev = ana.flops
        t_compute = flops_dev / PEAK_FLOPS_BF16
        t_memory = ana.hbm_bytes / HBM_BW
        t_coll = ana.collective_wire_bytes / ICI_BW_PER_LINK
        dominant = max(("compute", t_compute), ("memory", t_memory),
                       ("collective", t_coll), key=lambda kv: kv[1])[0]
        rec.update(
            status="ok",
            fsdp=bundle.fsdp,
            n_params=bundle.n_params, n_active=bundle.n_active,
            tokens_per_step=bundle.tokens_per_step,
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            memory_analysis={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "host_argument_bytes": mem.host_argument_size_in_bytes,
                "host_temp_bytes": mem.host_temp_size_in_bytes,
                "peak_device_bytes": (mem.argument_size_in_bytes
                                      + mem.output_size_in_bytes
                                      + mem.temp_size_in_bytes
                                      - mem.alias_size_in_bytes),
            },
            xla_cost_analysis={"flops": ca.get("flops"),
                               "bytes_accessed": ca.get("bytes accessed")},
            hlo={**ana.as_dict()},
            roofline={
                "chips": chips,
                "flops_per_device": flops_dev,
                "hbm_bytes_per_device": ana.hbm_bytes,
                "wire_bytes_per_device": ana.collective_wire_bytes,
                "host_bytes_per_device": ana.host_bytes,
                "t_compute_s": t_compute,
                "t_memory_s": t_memory,
                "t_collective_s": t_coll,
                "dominant": dominant,
                "model_flops_global": mf,
                "useful_flops_ratio": (mf / (flops_dev * chips)
                                       if flops_dev else None),
                # Offloaded-activation traffic projected onto the chosen
                # repro.io storage backend at its nominal write rate:
                # would the store path keep up with this cell?
                "io_backend": io_backend,
                "io_write_bw": NOMINAL_WRITE_BW[io_backend],
                "t_host_io_s": (ana.host_bytes
                                / NOMINAL_WRITE_BW[io_backend]),
            },
            # Predicted overlap for the SSDTrain schedule: stores overlap
            # the forward pass, fetches overlap the backward pass. The
            # fields mirror repro.obs.overlap.analyze() so a --trace run
            # can be checked against this prediction with
            # repro.obs.overlap.predicted_vs_measured().
            predicted_overlap=_predict_overlap(
                ana.host_bytes, NOMINAL_WRITE_BW[io_backend], t_compute,
                # fp32 moments per device, fetched+staged every step by
                # the eager per-layer schedule (adamw: 8 B/param)
                opt_bytes=(int(bundle.n_params / chips)
                           * {"adamw": 8, "sgd": 0}.get(
                               rec.get("optimizer") or "", 0))),
            # Predicted tier residency per tensor class under the
            # managed cache's placement model — pairs with the
            # cache_residency block of a --cache-managed run's metrics
            predicted_residency=_predict_residency(
                shape.kind, ana.host_bytes, bundle.n_params, chips,
                rec.get("optimizer"), cache_host_bound_mb << 20),
        )
    except Exception as e:  # record the failure, don't kill the sweep
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    _write(path, rec)
    return rec


def _write(path: str, rec: Dict) -> None:
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)


def _cell_cmd(arch: str, shape: str, mesh: str, out_dir: str,
              extra) -> list:
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--out", out_dir]
    return cmd + extra


def sweep(meshes, out_dir: str, force: bool, timeout: int,
          extra_args) -> int:
    """Run every runnable cell in its own subprocess (isolates compile
    memory; a crash doesn't kill the sweep)."""
    failures = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            for mesh_name in meshes:
                slug = _slug(arch, shape.name, mesh_name)
                path = os.path.join(out_dir, slug + ".json")
                if os.path.exists(path) and not force:
                    continue
                if cell_skip_reason(cfg, shape):
                    run_cell(arch, shape.name,
                             multi_pod=(mesh_name == "multi"),
                             out_dir=out_dir)
                    continue
                print(f"[sweep] {slug}", flush=True)
                t0 = time.time()
                try:
                    r = subprocess.run(
                        _cell_cmd(arch, shape.name, mesh_name, out_dir,
                                  extra_args),
                        timeout=timeout, capture_output=True, text=True)
                    if r.returncode != 0:
                        failures += 1
                        _write(path, {
                            "arch": arch, "shape": shape.name,
                            "mesh": mesh_name, "status": "error",
                            "error": "subprocess failed",
                            "stderr": r.stderr[-4000:]})
                except subprocess.TimeoutExpired:
                    failures += 1
                    _write(path, {"arch": arch, "shape": shape.name,
                                  "mesh": mesh_name, "status": "error",
                                  "error": f"timeout after {timeout}s"})
                print(f"[sweep] {slug} done in {time.time()-t0:.0f}s",
                      flush=True)
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="sweep every cell (subprocess per cell)")
    ap.add_argument("--out", default=os.path.normpath(RESULTS_DIR))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--dump-hlo", action="store_true")
    ap.add_argument("--policy", default=None,
                    choices=["keep", "remat", "offload", "save_names"])
    ap.add_argument("--attn-chunk", type=int, default=1024)
    ap.add_argument("--tag", default="", help="suffix for variant runs")
    ap.add_argument("--baseline", action="store_true",
                    help="disable beyond-paper graph opts (blocked "
                         "attention, chunked CE) for before/after runs")
    ap.add_argument("--io-backend", default="fs",
                    choices=sorted(NOMINAL_WRITE_BW),
                    help="repro.io backend whose nominal write bandwidth "
                         "prices the projected host-offload traffic")
    ap.add_argument("--cache-host-bound-mb", type=int, default=256,
                    help="pinned-host bound used by the "
                         "predicted_residency block (pair with the "
                         "--cache-host-bound-mb of the measured run)")
    ap.add_argument("--timeout", type=int, default=2400)
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    extra = []
    if args.policy:
        extra += ["--policy", args.policy]
    if args.dump_hlo:
        extra += ["--dump-hlo"]
    if args.force:
        extra += ["--force"]
    if args.attn_chunk != 1024:
        extra += ["--attn-chunk", str(args.attn_chunk)]
    if args.tag:
        extra += ["--tag", args.tag]
    if args.io_backend != "fs":
        extra += ["--io-backend", args.io_backend]
    if args.cache_host_bound_mb != 256:
        extra += ["--cache-host-bound-mb", str(args.cache_host_bound_mb)]

    if args.all:
        n = sweep(meshes, args.out, args.force, args.timeout, extra)
        sys.exit(1 if n else 0)

    assert args.arch and args.shape, "--arch and --shape (or --all)"
    for mesh_name in meshes:
        rec = run_cell(args.arch, args.shape,
                       multi_pod=(mesh_name == "multi"), out_dir=args.out,
                       dump_hlo=args.dump_hlo, policy=args.policy,
                       attn_chunk=args.attn_chunk, force=args.force,
                       tag=args.tag, baseline=args.baseline,
                       io_backend=args.io_backend,
                       cache_host_bound_mb=args.cache_host_bound_mb)
        status = rec.get("status")
        if status == "ok":
            rl = rec["roofline"]
            print(f"{args.arch} x {args.shape} [{mesh_name}] OK "
                  f"lower={rec['lower_s']}s compile={rec['compile_s']}s "
                  f"dominant={rl['dominant']} "
                  f"t=(c {rl['t_compute_s']:.3e}, m {rl['t_memory_s']:.3e},"
                  f" coll {rl['t_collective_s']:.3e})s")
            print("memory:", rec["memory_analysis"])
            po = rec.get("predicted_overlap")
            if po:
                print(f"predicted overlap [{rl['io_backend']}]: "
                      f"{po['io_hidden_frac']:.0%} of "
                      f"{po['t_io_s']:.3e}s I/O hidden "
                      f"(store {po['t_store_s']:.3e}s in fwd "
                      f"{po['t_fwd_s']:.3e}s, fetch "
                      f"{po['t_fetch_s']:.3e}s in bwd "
                      f"{po['t_bwd_s']:.3e}s)")
            pr = rec.get("predicted_residency")
            if pr:
                per = {cls: (f"{b['host_ram_bytes'] >> 20}MiB host + "
                             f"{b['ssd_bytes'] >> 20}MiB ssd")
                       for cls, b in pr["per_class"].items()}
                print(f"predicted residency (host bound "
                      f"{pr['host_bound_bytes'] >> 20}MiB): {per}")
        elif status == "skip":
            print(f"{args.arch} x {args.shape} [{mesh_name}] SKIP: "
                  f"{rec['skip_reason']}")
        else:
            print(f"{args.arch} x {args.shape} [{mesh_name}] ERROR: "
                  f"{rec.get('error')}")
            print(rec.get("traceback", "")[-2000:])
            sys.exit(1)


if __name__ == "__main__":
    main()
